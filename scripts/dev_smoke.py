"""Dev scratchpad: tiny forward/decode for every family (not part of tests)."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import model_zoo as zoo


def batch_for(cfg, b=2, s=16):
    rng = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (b, cfg.num_audio_frames,
                                                  cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (b, cfg.num_patches,
                                                   cfg.d_model))
    return batch


def main():
    names = sys.argv[1:] or list(ARCHS)
    for name in names:
        cfg = ARCHS[name].reduced()
        rng = jax.random.PRNGKey(0)
        params = zoo.init_params(rng, cfg)
        batch = batch_for(cfg)
        total, metrics = jax.jit(
            lambda p, b: zoo.loss(p, cfg, b))(params, batch)
        assert jnp.isfinite(total), (name, total)
        # decode one token
        cache = zoo.init_cache(cfg, 2, 32)
        if cfg.family == "encdec":
            from repro.models import whisper
            cache = whisper.precompute_cross(params, cfg, batch["frames"], cache)
        logits, cache = jax.jit(
            lambda p, t, c: zoo.decode_step(p, cfg, t, c))(
                params, batch["tokens"][:, :1], cache)
        assert jnp.isfinite(logits).all(), name
        print(f"OK {name}: loss={float(total):.3f} "
              f"decode_logits_shape={logits.shape}")


if __name__ == "__main__":
    main()
