"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [module ...]
"""
import sys
import traceback

from benchmarks import (bench_fig8, bench_kernels, bench_partitioning,
                        bench_reb, bench_roofline, bench_serving,
                        bench_table1, bench_table3)

ALL = {
    "table1": bench_table1,        # paper Table 1 (CIFAR-10 HI costs)
    "table3": bench_table3,        # paper Table 3 (dog filter)
    "fig8": bench_fig8,            # paper Fig 8 (5-approach comparison)
    "partitioning": bench_partitioning,   # appendix Tables 4-6
    "reb": bench_reb,              # §3 Figs 4-5 (REB thresholds, bandwidth)
    "kernels": bench_kernels,      # Pallas kernels vs oracles
    "roofline": bench_roofline,    # dry-run roofline table (deliverable g)
    "serving": bench_serving,      # HI engine: device-resident vs legacy
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            ALL[name].run()
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
