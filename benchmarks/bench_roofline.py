"""Roofline table from the multi-pod dry-run artifacts (deliverable g).

Reads dryrun_results.json (produced by launch/dryrun.py --all --both-meshes)
and prints the three-term roofline per (arch x shape x mesh): compute /
memory / collective seconds, the dominant term, MODEL_FLOPS/HLO_FLOPS, and
per-device peak memory.
"""
import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline_missing", 0.0,
             f"run `python -m repro.launch.dryrun --all --both-meshes "
             f"--out {RESULTS}` first")
        return
    with open(RESULTS) as f:
        results = json.load(f)
    for r in results:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] == "skipped":
            emit(name, 0.0, "SKIP: " + r["reason"])
            continue
        if r["status"] != "ok":
            emit(name, 0.0, "ERROR: " + r.get("error", "?"))
            continue
        roof = r["roofline"]
        step_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        emit(name, step_s * 1e6,
             f"c={roof['compute_s']:.4f}s m={roof['memory_s']:.4f}s "
             f"coll={roof['collective_s']:.4f}s dom={roof['dominant']} "
             f"useful={roof['useful_ratio']:.2f} "
             f"peak={r['memory']['peak_gb_per_device']:.1f}GB")
