"""Paper Figure 8: throughput / accuracy / offloaded images of the five
approaches (tinyML, OMD, OMA, OMA-worst, DNN-partitioning=full-offload, HI)
as a function of beta — reproduced from the paper's timing model and its
published S/L accuracy statistics."""
import numpy as np

from benchmarks.common import emit
from repro.core import replay
from repro.core.baselines import (TimingModel, full_offload, oma, omd, tinyml)
from repro.core.metrics import hi_baseline_result


def _population(n=10_000, seed=0):
    """Sample a correctness population matching the paper's S/L stats:
    S-ML 62.58%, L-ML 95%."""
    rng = np.random.default_rng(seed)
    s_ok = rng.random(n) < 0.6258
    l_ok = rng.random(n) < 0.95
    return s_ok, l_ok


def run() -> None:
    tm = TimingModel()
    s_ok, l_ok = _population()

    rows = []
    for beta in (0.1, 0.3, 0.5, 0.7, 0.9):
        hi_rep = replay.table1(beta)["hi"]
        hi_res = hi_baseline_result(hi_rep, tm)
        budget = hi_res.makespan_ms
        results = [
            tinyml(s_ok, tm),
            full_offload(l_ok, tm),             # == DNN-partitioning (appendix)
            omd(s_ok, l_ok, tm),
            oma(s_ok, l_ok, budget, tm),
            oma(s_ok, l_ok, budget, tm, worst_case=True),
            hi_res,
        ]
        rows.append((beta, results))

    beta, results = rows[2]                      # headline row at beta=0.5
    for r in results:
        emit(f"fig8_{r.name}_beta{beta}", r.makespan_ms * 1000 / r.n,
             f"throughput {r.throughput:.1f}/s acc {r.accuracy:.2%} "
             f"offloaded {r.n_offloaded}")

    # the paper's §6 headline: HI vs full offload at beta=0.5
    f = replay.fig8_hi_vs_full_offload(0.5)
    emit("fig8_headline", 0.0,
         f"latency -{f['latency_reduction_pct']:.1f}% (paper 63.15%) "
         f"offloads -{f['offload_reduction_pct']:.1f}% (paper 64.45%) "
         f"acc {f['hi_accuracy_pct']:.2f}%")

    # full sweep (derived only)
    for beta, results in rows:
        hi = results[-1]
        best_other_acc = max(r.accuracy for r in results[:-1]
                             if r.makespan_ms <= hi.makespan_ms * 1.01)
        emit(f"fig8_sweep_beta{beta}", hi.makespan_ms * 1000 / hi.n,
             f"HI acc {hi.accuracy:.2%} vs best-equal-latency "
             f"{best_other_acc:.2%}")
