"""Paper Table 3: the binary relevance-filter cascade (dog breeds)."""
import jax

from benchmarks.common import emit, time_us
from repro.configs.base import HIConfig
from repro.core import replay
from repro.core.cascade import classifier_cascade
from repro.models import cnn


def run() -> None:
    rng = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(rng, 3)
    ps = cnn.init_cnn(k1, cnn.SML_BINARY)
    pl = cnn.init_cnn(k2, cnn.LML_CIFAR)
    x = jax.random.normal(k3, (256, 32, 32, 3))

    # §5 rule: offload iff p >= theta (positives are complex)
    hi = HIConfig(theta=0.5, capacity_factor=0.5, binary_relevance=True)
    casc = classifier_cascade(
        lambda p, xx: cnn.apply_cnn(p, cnn.SML_BINARY, xx),
        lambda p, xx: cnn.apply_cnn(p, cnn.LML_CIFAR, xx),
        hi)
    infer = casc.infer_jit()
    us = time_us(lambda: infer(ps, pl, x))

    d = replay.DogReplay()
    emit("table3_binary_filter_b256", us,
         f"paper: offloaded {d.n_offloaded}/10000 acc {d.accuracy:.1%} "
         f"cost 912b+3521; reduction@b=0.5 {d.cost_reduction(0.5):.1f}%")
