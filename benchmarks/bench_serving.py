"""Serving hot-path benchmark: device-resident cascade vs the legacy
token-by-token loop.

Measures end-to-end requests/sec on the ISSUE's reference workload (reduced
``qwen2-1.5b``, CPU, 32 requests, batch 8) for both paths, plus the
prefill-vs-decode time split of the batched path, and writes the
machine-readable ``BENCH_serving.json`` next to the repo root so the perf
trajectory is tracked PR-over-PR.

  PYTHONPATH=src python -m benchmarks.bench_serving [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.models import model_zoo
from repro.serving.batcher import Batcher, Request
from repro.serving.engine import build_engine

ARCH = "qwen2-1.5b"
REQUESTS = 32
BATCH = 8
MAX_NEW = 8
CACHE_LEN = 96
BUCKETS = (32, 64)


def _make_batches(cfg, seed: int = 0):
    rng = np.random.default_rng(seed)
    batcher = Batcher(batch_size=BATCH, buckets=BUCKETS)
    for i in range(REQUESTS):
        plen = int(rng.integers(16, 64))
        batcher.submit(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32)))
    batches = []
    while batcher.queue:
        batches.append(batcher.next_batch())
    return batches


def _time_path(serve, batches, iters: int = 5) -> float:
    """Best wall seconds to drain the whole request set (post-warmup).

    min-of-N: both paths are deterministic compiled programs, so the minimum
    is the least noise-contaminated estimate on a shared CPU box."""
    for b in batches:                      # warm every (batch, bucket) shape
        serve(b.tokens)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for b in batches:
            serve(b.tokens)
        times.append(time.perf_counter() - t0)
    return min(times)


def _prefill_decode_split(cfg, bucket: int, iters: int = 10):
    """Per-batch prefill vs decode milliseconds for the batched path."""
    params = model_zoo.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (BATCH, bucket)), jnp.int32)
    cache0 = model_zoo.init_cache(cfg, BATCH, CACHE_LEN)

    prefill = jax.jit(lambda p, t, c: model_zoo.prefill(p, cfg, t, c))

    def decode(p, logits, cache):
        def body(carry, _):
            cache, logits = carry
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            logits, cache = model_zoo.decode_step(p, cfg, tok[:, None], cache)
            return (cache, logits), tok
        (_, _), toks = jax.lax.scan(body, (cache, logits), None,
                                    length=MAX_NEW)
        return toks
    decode = jax.jit(decode)

    logits, cache = prefill(params, tokens, cache0)
    jax.block_until_ready(decode(params, logits, cache))

    def med(fn, *args):
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2] * 1e3

    return med(prefill, params, tokens, cache0), \
        med(decode, params, logits, cache)


def run(out_path: str = "BENCH_serving.json") -> dict:
    cfg = ARCHS[ARCH].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=0.5)
    batches = _make_batches(cfg)
    bucket = max(b.bucket for b in batches)

    eng_new = build_engine(cfg, hi, max_new_tokens=MAX_NEW,
                           cache_len=CACHE_LEN)
    eng_old = build_engine(cfg, hi, max_new_tokens=MAX_NEW,
                           cache_len=CACHE_LEN)
    t_new = _time_path(eng_new.serve, batches)
    t_old = _time_path(eng_old.serve_legacy, batches)

    prefill_ms, decode_ms = _prefill_decode_split(cfg, bucket)

    result = {
        "arch": ARCH,
        "requests": REQUESTS,
        "batch": BATCH,
        "max_new_tokens": MAX_NEW,
        "buckets": list(BUCKETS),
        "new_rps": REQUESTS / t_new,
        "legacy_rps": REQUESTS / t_old,
        "speedup": t_old / t_new,
        "prefill_ms_per_batch": prefill_ms,
        "decode_ms_per_batch": decode_ms,
        "compiled_shapes": int(eng_new.stats["compiles"]),
        "backend": jax.default_backend(),
    }
    path = pathlib.Path(out_path)
    path.write_text(json.dumps(result, indent=2) + "\n")

    emit("serving_new", t_new / REQUESTS * 1e6,
         f"{result['new_rps']:.1f} req/s device-resident cascade")
    emit("serving_legacy", t_old / REQUESTS * 1e6,
         f"{result['legacy_rps']:.1f} req/s token-by-token loop")
    emit("serving_speedup", 0.0,
         f"{result['speedup']:.2f}x end-to-end; prefill {prefill_ms:.1f}ms "
         f"vs decode {decode_ms:.1f}ms per batch -> {path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    r = run(args.out)
    print(json.dumps(r, indent=2))


if __name__ == "__main__":
    main()
