"""Serving hot-path benchmark: continuous batching vs drained batches vs the
legacy token-by-token loop.

Three paths over the ISSUE's reference workload (reduced ``qwen2-1.5b``,
CPU):

* ``legacy``  — per-token scan prefill + NumPy routing (``serve_legacy``);
* ``drain``   — the device-resident cascade, whole (B, bucket) batches
  (``serve``): one executable per (batch, bucket), slots idle until the
  slowest sequence in the batch finishes, engine-wide max_new_tokens;
* ``stream``  — the continuous scheduler over the paged KV pool
  (``serve_stream``): slot-level admission, per-request output lengths, ONE
  executable across all buckets.

The stream-vs-drain comparison runs MIXED-length traffic in seeded
Poisson-arrival order (backlogged: arrival order = submission order, so the
drain batcher sees realistically mixed buckets per batch): prompt lengths
span the bucket ladder and per-request max_new_tokens is heterogeneous —
the regime continuous batching exists for.

The REPEATED-PREFIX scenario measures the prefix-sharing pool: a shared
system prompt + repeated user prompts (every repeat also replays its S→L
escalation against the L tier's own index), served with sharing ON vs OFF
at a calibrated ~40% offload rate.  Steady state (warm index) is what's
timed — the regime a production front-end with a fixed system prompt lives
in — and the prefill tokens saved per pass are reported alongside req/s.

The LONG-PROMPT scenario measures chunked prefill admission: mixed traffic
where a quarter of the prompts are ~16x longer than the rest, served with
``chunk_prefill`` ON vs OFF.  With chunking on, long prompts stream through
the chunk lane C tokens per tick (interleaved with decode) and the batched
admit lane shrinks to one chunk's width — time-to-first-token p50/p99 across
the whole trace is what's reported, plus req/s.

The SPECULATIVE scenario measures the fused S→L draft-verify cascade on the
calibrated ~25%-offload mixed trace: req/s speculative ON vs OFF, the draft
acceptance rate, and the escalated-block fraction.  NOTE the reference
models are random-init, so the S tier's drafts rarely match the L tier's
choices (the measured ~12% acceptance is the structural floor: the agreed
prefix of an escalated block).  Speculation's win scales with acceptance —
i.e. with how well S approximates L on real checkpoints — so this scenario
is primarily the acceptance-rate instrument; req/s on random weights is the
worst case (every block pays draft + verify).  Results land in
``BENCH_serving.json`` so the perf trajectory is tracked PR-over-PR.

The OUTAGE scenario measures fail-local resilience (``serving/faults.py``):
the calibrated mixed trace is replayed with an L-tier outage window sized
off the observed fault-free tick count.  Escalations failing into the
window open the circuit breaker; requests degrade to their S-tier answers
(``status="degraded_local"``) instead of stalling, and after the window +
cooldown the half-open probe restores remote serving.  Reported: req/s with
vs without the outage (throughput sustained), the degraded-local fraction,
the S-vs-L serve mix against the fault-free run (recovery of the offload
rate), and breaker open/close counts.

The KV-QUANT scenario measures the int8 paged-pool option (``kv_dtype``):
req/s on the calibrated mixed trace bf16 vs int8, the pool-byte footprint
(the int8 pages + per-page-per-head fp32 scales must fit in <= 0.55x the
bf16 bytes at the same slot/page config — asserted, not just reported), the
max concurrent slots a fixed HBM budget admits in each mode, and greedy
top-1 fidelity.  Fidelity is TEACHER-FORCED per-decision agreement (both
pools fed the bf16 argmax each step): free-running agreement compounds a
single early flip into total divergence, which measures trajectory
stability, not quantization quality.  On random-init weights the dense
families' top1-top2 logit margins (~1e-2) sit inside the int8 noise floor
(~1e-1 logit error), so the >= 99% agreement GATE runs on the hybrid
family, whose decisions are dominated by the full-precision recurrent path
while its shared-attention K/V pages really are int8-quantized; the dense
families' agreement is reported alongside.

The TELEMETRY scenario measures the collector's cost on the calibrated
mixed trace: req/s with the span/phase/histogram collector ON vs OFF (the
acceptance budget is <2% overhead; disabled costs nothing — the scheduler's
hooks are ``if self.tel`` guards on the host side of an already host-bound
tick loop), plus the latency histograms (TTFT/TPOT/queue-wait/escalation
p50/p95/p99) from an instrumented pass.  ``--trace-out PATH`` additionally
exports that pass as Chrome trace_event JSON — one track per slot per tier
with S→L flow arrows — loadable in chrome://tracing or Perfetto.

  PYTHONPATH=src python -m benchmarks.bench_serving [--out BENCH_serving.json]
  PYTHONPATH=src python -m benchmarks.bench_serving --smoke        # CI tier-1
  PYTHONPATH=src python -m benchmarks.bench_serving --smoke --trace-out t.json
  PYTHONPATH=src python -m benchmarks.bench_serving --chaos-smoke  # CI chaos
                    # gate: seeded fault schedules + per-tick pool invariants
  PYTHONPATH=src python -m benchmarks.bench_serving --telemetry-smoke
                    # gate: span completeness + <2% instrumented overhead
  PYTHONPATH=src python -m benchmarks.bench_serving --quant-smoke
                    # gate: int8 pool <= 0.55x bf16 bytes, >= 99% greedy
                    # top-1 agreement, 1 compiled shape per dtype
  PYTHONPATH=src python -m benchmarks.bench_serving --audit-smoke
                    # gate: decision-audit stream token-identical, bins ==
                    # p_histogram oracle, <2% overhead, ECE reported
  PYTHONPATH=src python -m benchmarks.bench_serving --mesh-smoke
                    # gate: data=4 S replicas >= 1.5x single-device req/s,
                    # nonzero transfer_overlap tick phase, 1 compiled shape

The MESH scenario measures the data-parallel tier split (scheduler
``mesh=``): R=4 S replicas shard_map'd over the ``data`` axis, each owning
a disjoint slot slice + its own paged-pool shard, escalations staged
through the double-buffered device transfer written at tick top.  It runs
in a subprocess with forced host devices (theta=0, the S-resident regime —
on a CI host the mesh "devices" share one core, so the L tier's GSPMD
replication would measure the host, not the design) and reports req/s vs
the single-device scheduler, tick counts, and the per-tick phase buckets
including ``transfer_overlap``.

Full runs append a compact per-run ``history`` entry (git rev, date, req/s
per scenario) into the output JSON instead of clobbering the trajectory —
cross-PR perf lives in ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.core.calibrate import p_histogram
from repro.models import model_zoo
from repro.serving.audit import GateAudit
from repro.serving.batcher import Batcher, Request, pad_to_bucket
from repro.serving.engine import build_engine
from repro.serving.faults import STATUSES, FaultSchedule, RetryPolicy
from repro.serving.flight_recorder import FlightRecorder
from repro.serving.telemetry import Telemetry
from repro.serving.trace_export import chrome_trace, write_chrome_trace

ARCH = "qwen2-1.5b"
REQUESTS = 32
BATCH = 8
MAX_NEW = 8
CACHE_LEN = 96
BUCKETS = (32, 64)
STREAM_BUCKETS = (16, 32, 64)
PAGE_SIZE = 16
NUM_SLOTS = 8


def _make_batches(cfg, seed: int = 0):
    rng = np.random.default_rng(seed)
    batcher = Batcher(batch_size=BATCH, buckets=BUCKETS)
    for i in range(REQUESTS):
        plen = int(rng.integers(16, 64))
        batcher.submit(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32)))
    batches = []
    while batcher.queue:
        batches.append(batcher.next_batch())
    return batches


def _poisson_mixed_requests(cfg, n: int, max_new: int, seed: int = 0):
    """Mixed-length traffic in seeded Poisson-arrival order: prompt lengths
    span the bucket ladder, output lengths are heterogeneous (2..max_new).
    The exponential inter-arrival draws fix the ORDER (backlogged system:
    every request has arrived by t=0 of the measurement)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0, n))
    order = np.argsort(arrivals, kind="stable")
    reqs = []
    for i in range(n):
        plen = int(rng.integers(8, STREAM_BUCKETS[-1]))
        steps = int(rng.integers(2, max_new + 1))
        reqs.append(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=steps))
    return [reqs[i] for i in order]


def _time_path(serve, batches, iters: int = 5) -> float:
    """Best wall seconds to drain the whole request set (post-warmup).

    min-of-N: both paths are deterministic compiled programs, so the minimum
    is the least noise-contaminated estimate on a shared CPU box."""
    for b in batches:                      # warm every (batch, bucket) shape
        serve(b.tokens)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for b in batches:
            serve(b.tokens)
        times.append(time.perf_counter() - t0)
    return min(times)


def _time_drain_mixed(eng, reqs, iters: int) -> float:
    """Drain the mixed trace through ``serve``: FIFO batching in arrival
    order (mixed buckets pad up; the engine's fixed max_new runs for all)."""
    def one_pass():
        batcher = Batcher(batch_size=BATCH, buckets=STREAM_BUCKETS)
        for r in reqs:
            batcher.submit(r)
        while batcher.queue:
            eng.serve(batcher.next_batch().tokens)
    one_pass()                             # warm all (batch, bucket) shapes
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        one_pass()
        times.append(time.perf_counter() - t0)
    return min(times)


def _time_stream_mixed(eng, reqs, iters: int, decode_block: int,
                       prefix_sharing: bool = False) -> float:
    def one_pass():
        eng.serve_stream(reqs, buckets=STREAM_BUCKETS, num_slots=NUM_SLOTS,
                         l_slots=NUM_SLOTS // 2, page_size=PAGE_SIZE,
                         decode_block=decode_block,
                         prefix_sharing=prefix_sharing)
    one_pass()                             # warm the (single) tick executable
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        one_pass()
        times.append(time.perf_counter() - t0)
    return min(times)


# repeated-prefix scenario: long shared system prompt + short generations —
# the prefill-bound regime prefix caching exists for (classification,
# extraction, templated chat); escalations replay against the L tier's index
REP_SYS_LEN = 224
REP_BUCKETS = (256,)
REP_MAX_NEW = 4
REP_DECODE_BLOCK = 3
REP_CACHE_LEN = 288


def _repeated_prefix_requests(cfg, n: int, seed: int = 0):
    """Shared-system-prompt traffic: every prompt starts with the same
    224-token system prefix; a handful of unique user prompts repeat through
    the trace (chat replays, retries, templated queries).  Repeats give full
    restores on BOTH tiers — every repeated escalation replays on the
    L tier's own index."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, REP_SYS_LEN).astype(np.int32)
    n_unique = max(2, n // 8)
    uniq = []
    for _ in range(n_unique):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, 31))).astype(np.int32)
        uniq.append(np.concatenate([sys_prompt, tail]))
    order = rng.permutation(n)
    return [Request(int(i), uniq[int(i) % n_unique],
                    max_new_tokens=REP_MAX_NEW) for i in order]


def _time_rep(eng, reqs, iters: int, sharing: bool) -> float:
    def one_pass():
        eng.serve_stream(reqs, buckets=REP_BUCKETS, num_slots=NUM_SLOTS,
                         l_slots=NUM_SLOTS // 2, page_size=PAGE_SIZE,
                         decode_block=REP_DECODE_BLOCK,
                         prefix_sharing=sharing)
    one_pass()          # warm: compiles the executable AND fills the index
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        one_pass()
        times.append(time.perf_counter() - t0)
    return min(times)


def _bench_repeated_prefix(cfg, n: int, iters: int):
    """Sharing-on vs sharing-off req/s on the repeated-prefix trace (steady
    state: warm index), plus the prefill tokens saved per pass."""
    reqs = _repeated_prefix_requests(cfg, n)
    # calibrate theta for ~40% offload with a sharing-off stream probe
    # (confidences are theta-independent)
    eng_p = build_engine(cfg, HIConfig(theta=0.0, capacity_factor=1.0),
                         max_new_tokens=REP_MAX_NEW, cache_len=REP_CACHE_LEN)
    probe = eng_p.serve_stream(reqs, buckets=REP_BUCKETS,
                               num_slots=NUM_SLOTS, l_slots=NUM_SLOTS // 2,
                               page_size=PAGE_SIZE,
                               decode_block=REP_DECODE_BLOCK,
                               prefix_sharing=False)
    theta = float(np.quantile(
        np.asarray([r["confidence"] for r in probe.values()]), 0.4))
    hi = HIConfig(theta=theta, capacity_factor=1.0)

    eng_off = build_engine(cfg, hi, max_new_tokens=REP_MAX_NEW,
                           cache_len=REP_CACHE_LEN)
    t_off = _time_rep(eng_off, reqs, iters, sharing=False)
    eng_on = build_engine(cfg, hi, max_new_tokens=REP_MAX_NEW,
                          cache_len=REP_CACHE_LEN)
    t_on = _time_rep(eng_on, reqs, iters, sharing=True)
    # prefill tokens saved in ONE steady-state (warm-index) pass
    saved0 = eng_on.stats["prefill_tokens_saved"]
    eng_on.serve_stream(reqs, buckets=REP_BUCKETS, num_slots=NUM_SLOTS,
                        l_slots=NUM_SLOTS // 2, page_size=PAGE_SIZE,
                        decode_block=REP_DECODE_BLOCK, prefix_sharing=True)
    sched = eng_on._stream[1]
    # padded (bucket) tokens are what admission actually prefills — the
    # denominator "tokens saved" is measured against
    prompt_tokens = sum(pad_to_bucket(len(r.prompt), REP_BUCKETS)
                        for r in reqs)
    return {
        "requests": n,
        "buckets": list(REP_BUCKETS),
        "system_prompt_len": REP_SYS_LEN,
        "max_new_tokens": REP_MAX_NEW,
        "num_slots": NUM_SLOTS,
        "page_size": PAGE_SIZE,
        "theta_calibrated": theta,
        "offload_frac": eng_on.stats["offloaded"]
        / max(eng_on.stats["requests"], 1),
        "sharing_rps": n / t_on,
        "no_sharing_rps": n / t_off,
        "sharing_speedup": t_off / t_on,
        "prefill_tokens_saved_per_pass":
            int(eng_on.stats["prefill_tokens_saved"] - saved0),
        "prompt_tokens_per_pass": prompt_tokens,
        "prefix_stats_cumulative": sched.prefix_stats,
        "sharing_compiled_shapes": int(eng_on.stats["stream_compiles"]),
    }


# long-prompt scenario: most traffic is short with heterogeneous output
# lengths (slots free at staggered ticks, so admission pressure is
# continuous), a quarter of prompts is ~16x longer — the admission-monopoly
# regime chunked prefill exists for: without chunking EVERY admission tick
# pays an (A, 512) prefill pass (shapes are static, shorts pad up) and a
# long admission stalls all decode for its duration
LONG_BUCKETS = (32, 512)
LONG_CHUNK = 128
LONG_CHUNK_WIDTH = 4
LONG_MAX_NEW = 16
LONG_DECODE_BLOCK = 3


def _long_prompt_requests(cfg, n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(384, 512)) if i % 4 == 0 \
            else int(rng.integers(8, 32))
        reqs.append(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, LONG_MAX_NEW))))
    return [reqs[i] for i in rng.permutation(n)]


def _bench_long_prompt(cfg, n: int, iters: int):
    """TTFT p50/p99 + req/s with chunked prefill admission on vs off."""
    reqs = _long_prompt_requests(cfg, n)
    hi = HIConfig(theta=0.0, capacity_factor=1.0)   # S-only: isolate prefill

    def measure(chunked: bool):
        eng = build_engine(cfg, hi, max_new_tokens=LONG_MAX_NEW,
                           cache_len=LONG_BUCKETS[-1] + 16)
        kw = dict(buckets=LONG_BUCKETS, num_slots=NUM_SLOTS,
                  l_slots=NUM_SLOTS // 2, page_size=PAGE_SIZE,
                  decode_block=LONG_DECODE_BLOCK, prefix_sharing=False,
                  chunk_prefill=chunked, chunk_size=LONG_CHUNK,
                  chunk_width=LONG_CHUNK_WIDTH)
        eng.serve_stream(reqs, **kw)               # warm the tick executable
        best, ttfts = None, None
        for _ in range(iters):
            t0 = time.perf_counter()
            out = eng.serve_stream(reqs, **kw)
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best = dt
                ttfts = np.asarray([out[r.request_id]["ttft"] for r in reqs])
        return best, ttfts

    t_off, ttft_off = measure(False)
    t_on, ttft_on = measure(True)
    return {
        "requests": n,
        "buckets": list(LONG_BUCKETS),
        "chunk_size": LONG_CHUNK,
        "chunk_width": LONG_CHUNK_WIDTH,
        "long_fraction": 0.25,
        "chunked_rps": n / t_on,
        "unchunked_rps": n / t_off,
        "chunked_speedup": t_off / t_on,
        "ttft_p50_ms": {"chunked": float(np.percentile(ttft_on, 50) * 1e3),
                        "unchunked": float(np.percentile(ttft_off, 50) * 1e3)},
        "ttft_p99_ms": {"chunked": float(np.percentile(ttft_on, 99) * 1e3),
                        "unchunked": float(np.percentile(ttft_off, 99) * 1e3)},
    }


def _bench_speculative(cfg, reqs, theta: float, iters: int):
    """Fused draft-verify cascade vs the plain scheduler on the calibrated
    mixed trace: req/s, draft acceptance rate, escalated-block fraction."""
    hi = HIConfig(theta=theta, capacity_factor=1.0)
    k = MAX_NEW - 1

    def measure(spec: bool):
        eng = build_engine(cfg, hi, max_new_tokens=MAX_NEW,
                           cache_len=CACHE_LEN)
        kw = dict(buckets=STREAM_BUCKETS, num_slots=NUM_SLOTS,
                  l_slots=None if spec else NUM_SLOTS // 2,
                  page_size=PAGE_SIZE, decode_block=k, speculative=spec)
        eng.serve_stream(reqs, **kw)
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            eng.serve_stream(reqs, **kw)
            times.append(time.perf_counter() - t0)
        return min(times), eng._stream[1].stats

    t_off, _ = measure(False)
    t_on, stats = measure(True)
    return {
        "requests": len(reqs),
        "buckets": list(STREAM_BUCKETS),
        "draft_block": k,
        "theta_calibrated": theta,
        "speculative_rps": len(reqs) / t_on,
        "non_speculative_rps": len(reqs) / t_off,
        "speculative_speedup": t_off / t_on,
        "draft_accept_rate": stats["accepted"] / max(stats["drafted"], 1),
        "escalated_block_frac": stats["escalated_blocks"]
        / max(stats["blocks"], 1),
    }


# outage scenario: a fast-failing retry policy so the breaker's open/close
# arc fits inside the trace (production would run longer timeouts)
OUTAGE_RETRY = dict(ack_timeout_ticks=2, max_retries=1,
                    breaker_threshold=2, breaker_cooldown_ticks=2)


def _bench_outage(cfg, reqs, iters: int):
    """Fail-local resilience on the mixed trace at a ~50% offload rate: an
    L outage window (sized off the observed fault-free tick count) opens
    the breaker, throughput is sustained on degraded-local answers, and
    remote serving recovers after the window.

    Half the slots and a small decode block stretch the trace over many
    admission waves — the tick axis needs room for a during-outage phase
    AND a post-recovery phase, or the breaker arc can't be observed."""
    kw = dict(buckets=STREAM_BUCKETS, num_slots=NUM_SLOTS // 2,
              l_slots=NUM_SLOTS // 4, page_size=PAGE_SIZE, decode_block=2)
    eng = build_engine(cfg, HIConfig(theta=0.0, capacity_factor=1.0),
                       max_new_tokens=MAX_NEW, cache_len=CACHE_LEN)
    probe = eng.serve_stream(reqs, **kw)       # warm + confidence probe
    # median confidence -> ~half the trace escalates: enough L traffic for
    # the breaker arc to be visible even in the smoke sizing
    theta = float(np.quantile(np.asarray(
        [r["confidence"] for r in probe.values()]), 0.5))
    eng.hi = HIConfig(theta=theta, capacity_factor=1.0)
    ticks0 = int(eng.stats["stream_ticks"])
    ref = eng.serve_stream(reqs, **kw)         # fault-free reference
    ticks = int(eng.stats["stream_ticks"]) - ticks0   # size the window off
    outage = (max(1, ticks // 6), max(3, ticks // 3))  # observed reality
    faults = FaultSchedule(seed=0, outages=(outage,))
    retry = RetryPolicy(**OUTAGE_RETRY)

    def timed(f=None, r=None):
        best, last = None, None
        for _ in range(iters):
            t0 = time.perf_counter()
            last = eng.serve_stream(reqs, faults=f, retry=r, **kw)
            dt = time.perf_counter() - t0
            if best is None or dt < best:
                best = dt
        return best, last

    t_free, _ = timed()
    opens0 = eng.stats["breaker_opens"]
    ticks0 = eng.stats["breaker_open_ticks"]
    retries0 = eng.stats["esc_retries"]
    t_out, out = timed(faults, retry)

    n = len(reqs)
    remote_ref = sum(r["served_remote"] for r in ref.values())
    remote = sum(r["served_remote"] for r in out.values())
    degraded = sum(r["status"] == "degraded_local" for r in out.values())
    # the recovery criterion proper: escalations CREATED after the window +
    # cooldown must all reach L (fault-free offload behaviour restored)
    recovered_after = outage[1] + OUTAGE_RETRY["breaker_cooldown_ticks"]
    post = [r for r in out.values()
            if r["esc_created_tick"] >= recovered_after]
    post_remote = (sum(r["served_remote"] for r in post) / len(post)
                   if post else None)
    return {
        "requests": n,
        "theta_calibrated": theta,
        "outage_window_ticks": list(outage),
        "fault_free_ticks": ticks,
        "retry_policy": dict(OUTAGE_RETRY),
        "fault_free_rps": n / t_free,
        "outage_rps": n / t_out,
        "throughput_sustained_frac": t_free / t_out,
        "offload_frac": sum(r["offloaded"] for r in out.values()) / n,
        "degraded_local_frac": degraded / n,
        # S-vs-L serve mix: during the window escalations fail local, after
        # it they land on L again — recovery shows up as remote serves
        # approaching the fault-free count
        "remote_frac_fault_free": remote_ref / n,
        "remote_frac_outage": remote / n,
        "remote_recovery_frac": remote / max(remote_ref, 1),
        "post_window_escalations": len(post),
        "post_window_remote_frac": post_remote,
        "breaker_opens": int(eng.stats["breaker_opens"] - opens0) / iters,
        "breaker_open_ticks":
            int(eng.stats["breaker_open_ticks"] - ticks0) / iters,
        "esc_retries": int(eng.stats["esc_retries"] - retries0) / iters,
        "stream_compiled_shapes": int(eng.stats["stream_compiles"]),
    }


def _bench_telemetry(cfg, reqs, theta: float, iters: int, decode_block: int,
                     trace_out: str | None = None):
    """Telemetry overhead on the calibrated mixed trace: req/s with the
    collector ON vs OFF (min-of-N, same engine, same compiled tick), plus
    the latency histograms from an instrumented pass and — when
    ``trace_out`` is given — the Chrome trace_event export of that pass."""
    hi = HIConfig(theta=theta, capacity_factor=1.0)
    eng = build_engine(cfg, hi, max_new_tokens=MAX_NEW, cache_len=CACHE_LEN)
    kw = dict(buckets=STREAM_BUCKETS, num_slots=NUM_SLOTS,
              l_slots=NUM_SLOTS // 2, page_size=PAGE_SIZE,
              decode_block=decode_block)
    eng.serve_stream(reqs, **kw)               # warm the tick executable

    def best(tel_factory):
        times = []
        for _ in range(iters):
            tel = tel_factory()
            t0 = time.perf_counter()
            eng.serve_stream(reqs, telemetry=tel, **kw)
            times.append(time.perf_counter() - t0)
        return min(times)

    t_off = best(lambda: None)
    t_on = best(Telemetry)
    # one final instrumented pass feeds the exporters (histograms + trace)
    tel = Telemetry()
    eng.serve_stream(reqs, telemetry=tel, **kw)
    doc = write_chrome_trace(tel, trace_out) if trace_out \
        else chrome_trace(tel)
    return {
        "requests": len(reqs),
        "enabled_rps": len(reqs) / t_on,
        "disabled_rps": len(reqs) / t_off,
        "overhead_frac": max(0.0, t_on / t_off - 1.0),
        "histograms": tel.histogram_summary(),
        "tick_phase_seconds": tel.phase_summary(),
        "trace_out": trace_out,
        "trace_events": len(doc["traceEvents"]),
        "stream_compiled_shapes": int(eng.stats["stream_compiles"]),
    }


# kv-quant scenario: the hybrid family carries the >= 99% agreement gate —
# its shared-attention pages are genuinely int8 while random-init decisions
# keep usable top-1 margins (see module docstring); qwen2 is reported
QUANT_GATE_ARCH = "zamba2-2.7b"
QUANT_PAGE = 8


def _teacher_forced_agreement(arch: str, slots: int, steps: int,
                              prompt_len: int = 16, seed: int = 1):
    """Per-decision greedy top-1 agreement between a bf16 and an int8 paged
    cache on the same prompts, teacher-forced on the bf16 argmax.  Returns
    (matching decisions, total decisions, max abs logit error)."""
    cfg = ARCHS[arch].reduced()
    params = model_zoo.init_params(jax.random.PRNGKey(0), cfg)
    npg = (prompt_len + steps) // QUANT_PAGE + 1
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (slots, prompt_len)),
                       jnp.int32)
    lens = jnp.full((slots,), prompt_len, jnp.int32)
    block = jnp.asarray(np.arange(1, slots * npg + 1,
                                  dtype=np.int32).reshape(slots, npg))
    caches, logits = {}, {}
    for dt in (jnp.bfloat16, jnp.int8):
        cache = model_zoo.init_paged_cache(cfg, slots, slots * npg + 1,
                                           QUANT_PAGE, dt)
        lg, cache = model_zoo.prefill_paged(
            params, cfg, toks, lens, jnp.arange(slots, dtype=jnp.int32),
            block, cache)
        caches[dt], logits[dt] = cache, lg
    lg_b, lg_q = logits[jnp.bfloat16], logits[jnp.int8]
    match = int(jnp.sum(jnp.argmax(lg_b, -1) == jnp.argmax(lg_q, -1)))
    total = slots
    max_err = float(jnp.max(jnp.abs(lg_b - lg_q)))
    pos = jnp.full((slots,), prompt_len, jnp.int32)
    tok = jnp.argmax(lg_b, -1)[:, None].astype(jnp.int32)
    for i in range(steps):
        lg_b, caches[jnp.bfloat16] = model_zoo.decode_step_paged(
            params, cfg, tok, pos + i, block, caches[jnp.bfloat16])
        lg_q, caches[jnp.int8] = model_zoo.decode_step_paged(
            params, cfg, tok, pos + i, block, caches[jnp.int8])
        match += int(jnp.sum(jnp.argmax(lg_b, -1) == jnp.argmax(lg_q, -1)))
        total += slots
        max_err = max(max_err, float(jnp.max(jnp.abs(lg_b - lg_q))))
        tok = jnp.argmax(lg_b, -1)[:, None].astype(jnp.int32)
    return match, total, max_err


def _pool_footprint(eng) -> dict:
    g = eng._stream[1].srt.pool.gauges()
    return {k: g[k] for k in ("kv_bytes_total", "bytes_per_slot", "kv_bits")}


def _bench_kv_quant(cfg, reqs, theta: float, iters: int,
                    decode_block: int) -> dict:
    """bf16 vs int8 pools on the calibrated mixed trace: req/s, pool bytes
    (the <= 0.55x footprint contract is ASSERTED here), slots admitted by a
    fixed HBM budget, and greedy fidelity (teacher-forced gate on the
    hybrid family + reported dense agreement)."""
    hi = HIConfig(theta=theta, capacity_factor=1.0)

    def measure(kv_dtype: str):
        eng = build_engine(cfg, hi, max_new_tokens=MAX_NEW,
                           cache_len=CACHE_LEN)
        kw = dict(buckets=STREAM_BUCKETS, num_slots=NUM_SLOTS,
                  l_slots=NUM_SLOTS // 2, page_size=PAGE_SIZE,
                  decode_block=decode_block, kv_dtype=kv_dtype)
        out = eng.serve_stream(reqs, **kw)         # warm the tick executable
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = eng.serve_stream(reqs, **kw)
            times.append(time.perf_counter() - t0)
        assert eng.stats["stream_compiles"] == 1, kv_dtype
        return min(times), out, _pool_footprint(eng)

    t16, out16, fp16 = measure("bf16")
    t8, out8, fp8 = measure("int8")
    ratio = fp8["kv_bytes_total"] / fp16["kv_bytes_total"]
    assert ratio <= 0.55, \
        f"int8 pool is {ratio:.3f}x bf16 bytes (contract: <= 0.55x)"
    # max concurrent slots a fixed HBM budget admits: budget = what the
    # bf16 config provisions for NUM_SLOTS slots
    budget = NUM_SLOTS * fp16["bytes_per_slot"]
    slots_at_budget = {"bf16": NUM_SLOTS,
                       "int8": int(budget // fp8["bytes_per_slot"])}
    # free-running sequence agreement (reported): compounding, see docstring
    agree_seq = float(np.mean([
        np.mean(np.asarray(out16[r.request_id]["tokens"]) ==
                np.asarray(out8[r.request_id]["tokens"]))
        for r in reqs
        if len(out16[r.request_id]["tokens"]) ==
        len(out8[r.request_id]["tokens"])]))
    # teacher-forced per-decision agreement: the gated fidelity metric
    g_match, g_total, g_err = _teacher_forced_agreement(
        QUANT_GATE_ARCH, slots=8, steps=16)
    d_match, d_total, d_err = _teacher_forced_agreement(ARCH, slots=8,
                                                        steps=16)
    return {
        "requests": len(reqs),
        "buckets": list(STREAM_BUCKETS),
        "num_slots": NUM_SLOTS,
        "page_size": PAGE_SIZE,
        "theta_calibrated": theta,
        "bf16_rps": len(reqs) / t16,
        "int8_rps": len(reqs) / t8,
        "int8_vs_bf16_rps": t16 / t8,
        "pool_bytes": {"bf16": fp16, "int8": fp8},
        "int8_bytes_ratio": ratio,
        "hbm_budget_bytes": budget,
        "slots_at_budget": slots_at_budget,
        "freerun_token_agreement": agree_seq,
        "teacher_forced_agreement": {
            QUANT_GATE_ARCH: {"rate": g_match / g_total,
                              "decisions": g_total,
                              "max_logit_err": g_err},
            ARCH: {"rate": d_match / d_total, "decisions": d_total,
                   "max_logit_err": d_err},
        },
    }


def run_quant_smoke() -> dict:
    """CI quantization gate (``--quant-smoke``): the int8 pool option must
    (1) fit pages + scales in <= 0.55x the bf16 pool bytes at the same
    slot/page config, (2) keep >= 99% teacher-forced greedy top-1 agreement
    on the smoke trace (gate family: hybrid — see module docstring), and
    (3) preserve the serving contract in BOTH dtypes: one compiled stream
    executable and per-tick pool invariants (scale-row accounting
    included).  Exits nonzero (via AssertionError) on any violation."""
    cfg = ARCHS[ARCH].reduced()
    reqs = _poisson_mixed_requests(cfg, 8, 4)
    kw = dict(buckets=STREAM_BUCKETS, num_slots=4, l_slots=2,
              page_size=PAGE_SIZE, validate=True)
    footprint = {}
    for kv_dtype in ("bf16", "int8"):
        eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                           max_new_tokens=4, cache_len=CACHE_LEN)
        eng.serve_stream(reqs, kv_dtype=kv_dtype, **kw)
        assert eng.stats["stream_compiles"] == 1, \
            f"{kv_dtype}: expected 1 compiled shape"
        sched = eng._stream[1]
        sched.srt.pool.check_invariants()
        sched.lrt.pool.check_invariants()
        footprint[kv_dtype] = _pool_footprint(eng)
    ratio = (footprint["int8"]["kv_bytes_total"]
             / footprint["bf16"]["kv_bytes_total"])
    assert ratio <= 0.55, \
        f"int8 pool is {ratio:.3f}x bf16 bytes (contract: <= 0.55x)"
    match, total, max_err = _teacher_forced_agreement(QUANT_GATE_ARCH,
                                                      slots=8, steps=16)
    rate = match / total
    assert rate >= 0.99, \
        f"greedy top-1 agreement {match}/{total} = {rate:.4f} < 0.99"
    emit("serving_quant_smoke", 0.0,
         f"kv-quant gate PASS: int8 pool {ratio:.3f}x bf16 bytes, "
         f"teacher-forced agreement {match}/{total} ({rate:.1%}), max "
         f"logit err {max_err:.3f}, 1 compiled shape per dtype")
    return {"int8_bytes_ratio": ratio, "pool_bytes": footprint,
            "gate_arch": QUANT_GATE_ARCH,
            "teacher_forced_agreement": rate, "decisions": total,
            "max_logit_err": max_err, "stream_compiled_shapes": 1}


def run_telemetry_smoke(trace_out: str | None = None) -> dict:
    """CI telemetry gate (``--telemetry-smoke``): replay the smoke trace
    with the collector ON and assert the zero-cost contract — one compiled
    shape, a complete span tree per terminating request whose terminal
    status matches the result record, token-identical output to the
    uninstrumented run, and req/s within the 2% overhead budget.  Exits
    nonzero (via AssertionError) on any violation."""
    cfg = ARCHS[ARCH].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=4, cache_len=CACHE_LEN)
    reqs = _poisson_mixed_requests(cfg, 16, 4)
    kw = dict(buckets=STREAM_BUCKETS, num_slots=4, l_slots=2,
              page_size=PAGE_SIZE)
    ref = eng.serve_stream(reqs, **kw)         # warm + reference tokens
    tel = Telemetry()
    out = eng.serve_stream(reqs, telemetry=tel, **kw)
    assert eng.stats["stream_compiles"] == 1, "telemetry changed a shape"
    assert set(tel.traces) == set(out), "span tree per terminating request"
    for rid, rec in out.items():
        tr = tel.traces[rid]
        assert tr.complete, f"request {rid}: dangling span"
        assert tr.status == rec["status"], f"request {rid}: status mismatch"
        np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])

    def best(tel_factory):
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            eng.serve_stream(reqs, telemetry=tel_factory(), **kw)
            times.append(time.perf_counter() - t0)
        return min(times)

    t_off = best(lambda: None)
    t_on = best(Telemetry)
    overhead = max(0.0, t_on / t_off - 1.0)
    assert overhead < 0.02, \
        f"telemetry overhead {overhead:.2%} exceeds the 2% budget"
    if trace_out:
        write_chrome_trace(tel, trace_out)
    emit("serving_telemetry_smoke", 0.0,
         f"telemetry gate PASS: {len(out)} span trees, overhead "
         f"{overhead:.2%} (< 2%), 1 compiled shape")
    return {"requests": len(out), "overhead_frac": overhead,
            "enabled_rps": len(reqs) / t_on,
            "disabled_rps": len(reqs) / t_off,
            "stream_compiled_shapes": 1, "trace_out": trace_out}


def run_audit_smoke(trace_out: str | None = None) -> dict:
    """CI decision-audit gate (``--audit-smoke``): replay the smoke trace
    with the :class:`GateAudit` stream ON and assert its zero-cost contract
    — one compiled shape, greedy output token-identical to audit-off,
    streaming reliability bins matching the ``core/calibrate.p_histogram``
    NumPy oracle on the recorded decision stream, the ``hi_audit_*``
    Prometheus families present, and req/s within the 2% overhead budget.
    The running ECE is reported.  Exits nonzero (via AssertionError) on any
    violation."""
    cfg = ARCHS[ARCH].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=4, cache_len=CACHE_LEN)
    reqs = _poisson_mixed_requests(cfg, 16, 4)
    for r in reqs:
        r.tclass = ("interactive", "batch")[r.request_id % 2]
    kw = dict(buckets=STREAM_BUCKETS, num_slots=4, l_slots=2,
              page_size=PAGE_SIZE)
    ref = eng.serve_stream(reqs, **kw)         # warm + reference tokens
    aud = GateAudit()
    tel = Telemetry()
    out = eng.serve_stream(reqs, audit=aud, telemetry=tel, **kw)
    assert eng.stats["stream_compiles"] == 1, "the audit changed a shape"
    for rid, rec in out.items():
        np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])
    assert aud.decisions > 0, "the gate stream recorded nothing"
    truthed = [r for r in aud.records if r.ok is not None]
    assert truthed, "completed escalations must yield ground truth"
    oracle = p_histogram(np.array([r.conf for r in truthed]),
                         np.array([r.ok for r in truthed], np.float32),
                         bins=aud.overall.bins)
    np.testing.assert_array_equal(aud.overall.correct, oracle["correct"])
    np.testing.assert_array_equal(aud.overall.incorrect,
                                  oracle["incorrect"])
    txt = tel.prometheus_text()
    assert "hi_audit_ece" in txt and "hi_audit_decisions_total" in txt

    def best(extra):
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            eng.serve_stream(reqs, **extra(), **kw)
            times.append(time.perf_counter() - t0)
        return min(times)

    t_off = best(dict)
    t_on = best(lambda: {"audit": GateAudit()})
    overhead = max(0.0, t_on / t_off - 1.0)
    assert overhead < 0.02, \
        f"audit overhead {overhead:.2%} exceeds the 2% budget"
    if trace_out:
        write_chrome_trace(tel, trace_out)
    emit("serving_audit_smoke", 0.0,
         f"audit gate PASS: {aud.decisions} decisions, {aud.outcomes} "
         f"ground-truthed, ECE {aud.ece():.4f}, offload rate "
         f"{aud.offload_rate():.2f}, overhead {overhead:.2%} (< 2%), "
         f"bins == p_histogram oracle, 1 compiled shape")
    return {"requests": len(out), "decisions": aud.decisions,
            "outcomes": aud.outcomes, "ece": aud.ece(),
            "offload_rate": aud.offload_rate(),
            "regret_cost": aud.regret_cost,
            "overhead_frac": overhead,
            "enabled_rps": len(reqs) / t_on,
            "disabled_rps": len(reqs) / t_off,
            "stream_compiled_shapes": 1, "trace_out": trace_out}


def run_chaos_smoke(dump_out: str | None = None) -> dict:
    """CI chaos gate (``--chaos-smoke``): replay the smoke trace under
    seeded loss / outage / jitter schedules with PER-TICK pool invariants
    (``validate=True``) and assert the no-corruption property — every
    request terminates with exactly one valid-status record, S answers are
    token-identical to the fault-free run, degraded requests answer with
    their S tokens, no page leaks, one compiled shape.  A
    :class:`FlightRecorder` rides every faulted run; the outage schedule
    must freeze a breaker-open postmortem, written to ``dump_out`` (CI
    uploads it as a workflow artifact when this gate fails).  Exits nonzero
    (via AssertionError) on any violation."""
    cfg = ARCHS[ARCH].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=4, cache_len=CACHE_LEN)
    reqs = _poisson_mixed_requests(cfg, 8, 4)
    kw = dict(buckets=STREAM_BUCKETS, num_slots=4, l_slots=2,
              page_size=PAGE_SIZE, validate=True)
    ref = eng.serve_stream(reqs, **kw)
    schedules = [
        ("loss", FaultSchedule(seed=1, loss_prob=1.0),
         RetryPolicy(ack_timeout_ticks=1, max_retries=1)),
        ("outage", FaultSchedule(seed=2, outages=((1, 5),)),
         RetryPolicy(ack_timeout_ticks=2, max_retries=1,
                     breaker_threshold=2, breaker_cooldown_ticks=4)),
        ("jitter", FaultSchedule(seed=3, delay_ticks=1, delay_jitter=2),
         RetryPolicy(ack_timeout_ticks=6)),
    ]
    fr = FlightRecorder(capacity=16, path=dump_out)
    summary = {}
    for name, faults, retry in schedules:
        out = eng.serve_stream(reqs, faults=faults, retry=retry,
                               flight_recorder=fr, **kw)
        assert set(out) == {r.request_id for r in reqs}, name
        for rid, rec in out.items():
            assert rec["status"] in STATUSES, (name, rid, rec["status"])
            np.testing.assert_array_equal(rec["s_tokens"],
                                          ref[rid]["s_tokens"])
            if not rec["offloaded"] or rec["served_remote"]:
                np.testing.assert_array_equal(rec["tokens"],
                                              ref[rid]["tokens"])
            else:
                np.testing.assert_array_equal(rec["tokens"],
                                              rec["s_tokens"])
        sched = eng._stream[1]
        sched.srt.pool.check_invariants()
        sched.lrt.pool.check_invariants()
        assert not sched.srt.pool.held_slots, name
        assert not sched.lrt.pool.held_slots, name
        counts = {}
        for rec in out.values():
            counts[rec["status"]] = counts.get(rec["status"], 0) + 1
        summary[name] = counts
    assert eng.stats["stream_compiles"] == 1, "faults changed compiled shapes"
    opens = [d for d in fr.dumps if d["reason"] == "breaker_open"]
    assert opens, "the outage schedule must freeze a breaker-open dump"
    summary["stream_compiled_shapes"] = 1
    summary["flight_recorder_dumps"] = [d["reason"] for d in fr.dumps]
    summary["dump_out"] = dump_out
    emit("serving_chaos_smoke", 0.0,
         "chaos gate PASS: " + "; ".join(
             f"{k} {v}" for k, v in summary.items() if isinstance(v, dict))
         + f"; {len(fr.dumps)} flight-recorder dump(s)"
         + (f" -> {dump_out}" if dump_out else ""))
    return summary


def _calibrate_theta(eng, reqs, quantile: float = 0.25) -> float:
    """Paper §4 theta* calibration, serving-style: probe the S-tier's
    confidence distribution on the actual traffic through ``eng`` (theta is
    a runtime operand — confidences don't depend on it, and the probe doubles
    as executable warm-up) and place the threshold at the target offload
    fraction.  Both schedulers then see the SAME (paper regime) escalation
    rate — the drain path's static L capacity runs every batch regardless,
    which is exactly the cost continuous batching sheds."""
    confs = []
    batcher = Batcher(batch_size=BATCH, buckets=STREAM_BUCKETS)
    for r in reqs:
        batcher.submit(r)
    while batcher.queue:
        confs.extend(eng.serve(batcher.next_batch().tokens)["confidence"])
    return float(np.quantile(np.asarray(confs), quantile))


# -- mesh-sharded tier-split serving -----------------------------------------
# the data-parallel S-replica bench runs in a subprocess with forced host
# devices (XLA_FLAGS must be set before jax import, so the parent — already
# holding an initialized single-device jax — re-execs this module)
MESH_DATA = 4                  # S replicas on the `data` axis
MESH_SLOTS = 4                 # decode slots per replica
MESH_STEPS = 8
MESH_REQUESTS = 48


def _mesh_worker(smoke: bool) -> dict:
    """Runs INSIDE the forced-multi-device subprocess (``--mesh-worker``):
    time the mesh-sharded scheduler (``serve_stream(mesh=...)``, data=4) vs
    the single-device scheduler on the same host, same workload.

    The workload is theta=0 (every request finishes on its S replica): on a
    CI host whose "devices" are forced slices of ONE core, the GSPMD
    replication of the L tier across mesh devices serializes and would
    measure the host, not the design — the S-resident regime is the paper's
    common case and is where data parallelism pays.  The escalation staging
    path still runs every tick (the double-buffer copy + shard_map lanes are
    structural), so the ``transfer_overlap`` phase bucket is reported from
    the same run."""
    from repro.launch.mesh import make_serving_mesh

    assert len(jax.devices()) >= MESH_DATA, \
        f"worker needs >= {MESH_DATA} devices, got {len(jax.devices())}"
    cfg = ARCHS[ARCH].reduced()
    iters = 2 if smoke else 3
    kw = dict(buckets=(16,), num_slots=MESH_SLOTS, page_size=PAGE_SIZE)
    hi = HIConfig(theta=0.0, capacity_factor=1.0)

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, cfg.vocab_size, 12)
                        .astype(np.int32), max_new_tokens=MESH_STEPS)
                for i in range(MESH_REQUESTS)]

    def measure(mesh):
        eng = build_engine(cfg, hi, max_new_tokens=MESH_STEPS, cache_len=64)
        eng.serve_stream(reqs(), mesh=mesh, **kw)       # compile + warm
        best, ticks, tel = float("inf"), 0, None
        for _ in range(iters):
            t = Telemetry()
            tick0 = eng.stats["stream_ticks"]   # counter is cumulative
            t0 = time.perf_counter()
            eng.serve_stream(reqs(), mesh=mesh, telemetry=t, **kw)
            dt = time.perf_counter() - t0
            if dt < best:
                best = dt
                ticks = eng.stats["stream_ticks"] - tick0
                tel = t
        assert eng.stats["stream_compiles"] == 1
        return best, int(ticks), tel

    t_base, ticks_base, _ = measure(None)
    t_mesh, ticks_mesh, tel = measure(make_serving_mesh(MESH_DATA, 1))
    phase_ms = {}
    for tick in tel.ticks:
        for phase, t0, t1 in tick.segments:
            phase_ms[phase] = phase_ms.get(phase, 0.0) + (t1 - t0) * 1e3
    return {
        "mesh_shape": {"data": MESH_DATA, "model": 1},
        "requests": MESH_REQUESTS,
        "max_new_tokens": MESH_STEPS,
        "slots_per_replica": MESH_SLOTS,
        "theta": 0.0,
        "single_rps": MESH_REQUESTS / t_base,
        "mesh_rps": MESH_REQUESTS / t_mesh,
        "mesh_speedup": t_base / t_mesh,
        "single_ticks": ticks_base,
        "mesh_ticks": ticks_mesh,
        "phase_ms_per_tick": {k: v / max(ticks_mesh, 1)
                              for k, v in phase_ms.items()},
        "stream_compiled_shapes": 1,
    }


def _bench_mesh(smoke: bool) -> dict:
    """Parent-side driver: re-exec this module with forced host devices and
    ``--mesh-worker``, parse the marker line it prints."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{MESH_DATA}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(root / "src"), str(root),
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.bench_serving", "--mesh-worker"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(cmd, env=env, cwd=str(root), capture_output=True,
                         text=True, timeout=1200)
    for line in out.stdout.splitlines():
        if line.startswith("MESH_BENCH_JSON:"):
            return json.loads(line[len("MESH_BENCH_JSON:"):])
    raise RuntimeError("mesh bench worker produced no result:\n"
                       + out.stdout[-2000:] + out.stderr[-2000:])


def run_mesh_smoke() -> dict:
    """CI mesh gate (``--mesh-smoke``): the data-parallel mesh path must
    (1) serve >= 1.5x the single-device req/s at data=4 on the S-resident
    workload, (2) spend measurable wall time in the ``transfer_overlap``
    phase (the escalation staging copy is issued at tick top, overlapping
    S-side compute), and (3) keep ONE compiled stream executable.  Exits
    nonzero (via AssertionError) on any violation."""
    r = _bench_mesh(smoke=True)
    assert r["mesh_speedup"] >= 1.5, \
        f"mesh speedup {r['mesh_speedup']:.2f}x < 1.5x at data={MESH_DATA}"
    overlap = r["phase_ms_per_tick"].get("transfer_overlap", 0.0)
    assert overlap > 0.0, "transfer_overlap phase absent from tick buckets"
    assert r["stream_compiled_shapes"] == 1
    emit("serving_mesh_smoke", 0.0,
         f"mesh gate PASS: {r['mesh_rps']:.1f} req/s at data={MESH_DATA} vs "
         f"{r['single_rps']:.1f} single-device ({r['mesh_speedup']:.2f}x >= "
         f"1.5x), ticks {r['single_ticks']} -> {r['mesh_ticks']}, "
         f"transfer_overlap {overlap:.3f}ms/tick, 1 compiled shape")
    return r


def _prefill_decode_split(cfg, bucket: int, iters: int = 10):
    """Per-batch prefill vs decode milliseconds for the batched path."""
    params = model_zoo.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (BATCH, bucket)), jnp.int32)
    cache0 = model_zoo.init_cache(cfg, BATCH, CACHE_LEN)

    prefill = jax.jit(lambda p, t, c: model_zoo.prefill(p, cfg, t, c))

    def decode(p, logits, cache):
        def body(carry, _):
            cache, logits = carry
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            logits, cache = model_zoo.decode_step(p, cfg, tok[:, None], cache)
            return (cache, logits), tok
        (_, _), toks = jax.lax.scan(body, (cache, logits), None,
                                    length=MAX_NEW)
        return toks
    decode = jax.jit(decode)

    logits, cache = prefill(params, tokens, cache0)
    jax.block_until_ready(decode(params, logits, cache))

    def med(fn, *args):
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2] * 1e3

    return med(prefill, params, tokens, cache0), \
        med(decode, params, logits, cache)


def run(out_path: str = "BENCH_serving.json", smoke: bool = False,
        trace_out: str | None = None) -> dict:
    global REQUESTS, MAX_NEW
    iters = 1 if smoke else 5
    if smoke:
        REQUESTS, MAX_NEW = 6, 4

    cfg = ARCHS[ARCH].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=0.5)
    batches = _make_batches(cfg)
    bucket = max(b.bucket for b in batches)

    eng_new = build_engine(cfg, hi, max_new_tokens=MAX_NEW,
                           cache_len=CACHE_LEN)
    eng_old = build_engine(cfg, hi, max_new_tokens=MAX_NEW,
                           cache_len=CACHE_LEN)
    t_new = _time_path(eng_new.serve, batches, iters)
    t_old = _time_path(eng_old.serve_legacy, batches, iters)

    prefill_ms, decode_ms = _prefill_decode_split(cfg, bucket,
                                                  iters=3 if smoke else 10)

    # -- continuous vs drain on mixed-length Poisson-order traffic ----------
    # calibrated theta (~25% offload, the paper's operating regime);
    # capacity_factor 1.0 keeps escalation semantics identical between the
    # two schedulers (the stream path has no drop policy — it queues)
    reqs = _poisson_mixed_requests(cfg, REQUESTS, MAX_NEW)
    decode_block = MAX_NEW - 1
    eng_drain = build_engine(cfg, HIConfig(theta=0.0, capacity_factor=1.0),
                             max_new_tokens=MAX_NEW, cache_len=CACHE_LEN)
    theta = _calibrate_theta(eng_drain, reqs)     # probe + warm-up in one
    hi_mixed = HIConfig(theta=theta, capacity_factor=1.0)
    eng_drain.hi = hi_mixed                       # theta is a runtime operand
    eng_stream = build_engine(cfg, hi_mixed, max_new_tokens=MAX_NEW,
                              cache_len=CACHE_LEN)
    t_drain = _time_drain_mixed(eng_drain, reqs, iters)
    t_stream = _time_stream_mixed(eng_stream, reqs, iters, decode_block)

    # -- repeated-prefix traffic: prefix-sharing pool on vs off -------------
    repeated = _bench_repeated_prefix(cfg, REQUESTS, iters)

    # -- long-prompt admission: chunked prefill on vs off -------------------
    long_prompt = _bench_long_prompt(cfg, REQUESTS, iters)

    # -- fused speculative S->L cascade vs plain scheduling -----------------
    speculative = _bench_speculative(cfg, reqs, theta, iters)

    # -- L-tier outage: breaker -> fail-local -> recovery -------------------
    outage = _bench_outage(cfg, reqs, iters)

    # -- quantized KV pool: bf16 vs int8 footprint / throughput / fidelity --
    kv_quant = _bench_kv_quant(cfg, reqs, theta, iters, decode_block)

    # -- telemetry collector: overhead on vs off + Chrome trace export ------
    telemetry = _bench_telemetry(cfg, reqs, theta, iters, decode_block,
                                 trace_out=trace_out)

    # -- mesh-sharded tier split: data=4 S replicas vs single device --------
    mesh = _bench_mesh(smoke)

    result = {
        "arch": ARCH,
        "requests": REQUESTS,
        "batch": BATCH,
        "max_new_tokens": MAX_NEW,
        "buckets": list(BUCKETS),
        "new_rps": REQUESTS / t_new,
        "legacy_rps": REQUESTS / t_old,
        "speedup": t_old / t_new,
        "prefill_ms_per_batch": prefill_ms,
        "decode_ms_per_batch": decode_ms,
        "compiled_shapes": int(eng_new.stats["compiles"]),
        "mixed_poisson": {
            "requests": REQUESTS,
            "buckets": list(STREAM_BUCKETS),
            "max_new_tokens": [2, MAX_NEW],
            "num_slots": NUM_SLOTS,
            "l_slots": NUM_SLOTS // 2,
            "page_size": PAGE_SIZE,
            "decode_block": decode_block,
            "theta_calibrated": theta,
            "offload_frac": eng_stream.stats["offloaded"]
            / max(eng_stream.stats["requests"], 1),
            "drain_rps": REQUESTS / t_drain,
            "stream_rps": REQUESTS / t_stream,
            "stream_vs_drain_speedup": t_drain / t_stream,
            "drain_compiled_shapes": int(eng_drain.stats["compiles"]),
            "stream_compiled_shapes": int(
                eng_stream.stats["stream_compiles"]),
            "stream_ticks": int(eng_stream.stats["stream_ticks"]),
        },
        "repeated_prefix": repeated,
        "long_prompt": long_prompt,
        "speculative": speculative,
        "outage": outage,
        "kv_quant": kv_quant,
        "telemetry": telemetry,
        "mesh": mesh,
        "smoke": smoke,
        "backend": jax.default_backend(),
    }
    # -- longitudinal history: append this run instead of clobbering --------
    # each entry pins the git rev + date + headline req/s per scenario so
    # successive CI runs accumulate a regression series in one JSON file
    path = pathlib.Path(out_path)
    history = []
    if path.exists():
        try:
            prev = json.loads(path.read_text())
            history = list(prev.get("history", []))
        except (json.JSONDecodeError, OSError):
            history = []
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=pathlib.Path(__file__).resolve().parent,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        rev = "unknown"
    history.append({
        "rev": rev,
        "date": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "smoke": smoke,
        "rps": {
            "new": result["new_rps"],
            "legacy": result["legacy_rps"],
            "stream": result["mixed_poisson"]["stream_rps"],
            "drain": result["mixed_poisson"]["drain_rps"],
            "prefix_sharing": repeated["sharing_rps"],
            "chunked_prefill": long_prompt["chunked_rps"],
            "speculative": speculative["speculative_rps"],
            "outage": outage["outage_rps"],
            "kv_int8": kv_quant["int8_rps"],
            "mesh": mesh["mesh_rps"],
        },
    })
    result["history"] = history
    path.write_text(json.dumps(result, indent=2) + "\n")

    m = result["mixed_poisson"]
    emit("serving_new", t_new / REQUESTS * 1e6,
         f"{result['new_rps']:.1f} req/s device-resident cascade")
    emit("serving_legacy", t_old / REQUESTS * 1e6,
         f"{result['legacy_rps']:.1f} req/s token-by-token loop")
    emit("serving_speedup", 0.0,
         f"{result['speedup']:.2f}x end-to-end; prefill {prefill_ms:.1f}ms "
         f"vs decode {decode_ms:.1f}ms per batch -> {path}")
    emit("serving_stream", t_stream / REQUESTS * 1e6,
         f"{m['stream_rps']:.1f} req/s continuous (paged, "
         f"{m['stream_compiled_shapes']} compiled shape) vs "
         f"{m['drain_rps']:.1f} drained ({m['drain_compiled_shapes']} "
         f"shapes): {m['stream_vs_drain_speedup']:.2f}x on mixed traffic")
    r = repeated
    emit("serving_prefix_sharing", 0.0,
         f"{r['sharing_rps']:.1f} req/s shared-prefix pool vs "
         f"{r['no_sharing_rps']:.1f} without: {r['sharing_speedup']:.2f}x, "
         f"{r['prefill_tokens_saved_per_pass']}/{r['prompt_tokens_per_pass']}"
         f" prefill tokens saved/pass")
    lp = long_prompt
    emit("serving_chunked_prefill", 0.0,
         f"TTFT p50 {lp['ttft_p50_ms']['chunked']:.0f}ms chunked vs "
         f"{lp['ttft_p50_ms']['unchunked']:.0f}ms whole-prompt (p99 "
         f"{lp['ttft_p99_ms']['chunked']:.0f} vs "
         f"{lp['ttft_p99_ms']['unchunked']:.0f}ms); "
         f"{lp['chunked_rps']:.1f} vs {lp['unchunked_rps']:.1f} req/s")
    sp = speculative
    emit("serving_speculative", 0.0,
         f"{sp['speculative_rps']:.1f} req/s fused draft-verify vs "
         f"{sp['non_speculative_rps']:.1f} plain "
         f"({sp['speculative_speedup']:.2f}x); accept rate "
         f"{sp['draft_accept_rate']:.2f}, escalated-block frac "
         f"{sp['escalated_block_frac']:.2f}")
    ot = outage
    emit("serving_outage", 0.0,
         f"L outage ticks {ot['outage_window_ticks']}: "
         f"{ot['outage_rps']:.1f} req/s vs {ot['fault_free_rps']:.1f} "
         f"fault-free ({ot['throughput_sustained_frac']:.2f}x sustained), "
         f"{ot['degraded_local_frac']:.2f} degraded-local, remote serve "
         f"{ot['remote_frac_outage']:.2f} vs {ot['remote_frac_fault_free']:.2f}"
         f" fault-free, post-window escalations "
         f"{ot['post_window_remote_frac'] if ot['post_window_remote_frac'] is not None else 'n/a'}"
         f" remote ({ot['post_window_escalations']}), "
         f"breaker opened {ot['breaker_opens']:.0f}x")
    kq = kv_quant
    gate = kq["teacher_forced_agreement"][QUANT_GATE_ARCH]
    emit("serving_kv_quant", 0.0,
         f"int8 pool {kq['int8_bytes_ratio']:.3f}x bf16 bytes "
         f"({kq['slots_at_budget']['int8']} vs "
         f"{kq['slots_at_budget']['bf16']} slots at the bf16 HBM budget); "
         f"{kq['int8_rps']:.1f} vs {kq['bf16_rps']:.1f} req/s; "
         f"teacher-forced agreement {gate['rate']:.1%} "
         f"({QUANT_GATE_ARCH}, {gate['decisions']} decisions)")
    tm = telemetry
    emit("serving_telemetry", 0.0,
         f"{tm['enabled_rps']:.1f} req/s instrumented vs "
         f"{tm['disabled_rps']:.1f} off ({tm['overhead_frac']:.2%} "
         f"overhead), {tm['trace_events']} trace events"
         + (f" -> {tm['trace_out']}" if tm["trace_out"] else ""))
    ms = mesh
    emit("serving_mesh", 0.0,
         f"{ms['mesh_rps']:.1f} req/s at data={ms['mesh_shape']['data']} vs "
         f"{ms['single_rps']:.1f} single-device ({ms['mesh_speedup']:.2f}x), "
         f"ticks {ms['single_ticks']} -> {ms['mesh_ticks']}, "
         f"transfer_overlap "
         f"{ms['phase_ms_per_tick'].get('transfer_overlap', 0.0):.3f}ms/tick")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload, 1 iteration — the CI tier-1 mode")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="fault-injection gate: seeded loss/outage/jitter "
                         "schedules with per-tick pool invariants; asserts "
                         "the no-corruption property instead of timing")
    ap.add_argument("--telemetry-smoke", action="store_true",
                    help="telemetry gate: span-tree completeness, terminal "
                         "statuses matching result records, one compiled "
                         "shape, and req/s overhead under the 2%% budget")
    ap.add_argument("--audit-smoke", action="store_true",
                    help="decision-audit gate: audit-on output token-"
                         "identical to off with one compiled shape, "
                         "streaming bins matching the p_histogram oracle, "
                         "hi_audit_* Prometheus families present, and "
                         "req/s overhead under the 2%% budget")
    ap.add_argument("--dump-out", default=None, metavar="PATH",
                    help="chaos-smoke: write the flight recorder's last "
                         "postmortem dump here (CI uploads it as an "
                         "artifact when the gate fails)")
    ap.add_argument("--quant-smoke", action="store_true",
                    help="kv-quant gate: int8 pool bytes <= 0.55x bf16 at "
                         "the same slot/page config, >= 99%% teacher-forced "
                         "greedy top-1 agreement, one compiled shape and "
                         "clean pool invariants in both dtypes")
    ap.add_argument("--mesh-smoke", action="store_true",
                    help="mesh gate: data=4 S replicas serve >= 1.5x the "
                         "single-device req/s on the S-resident workload, "
                         "the transfer_overlap tick phase is nonzero, one "
                         "compiled shape (runs a forced-multi-device "
                         "subprocess)")
    ap.add_argument("--mesh-worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the instrumented pass's Chrome trace_event "
                         "JSON here (load in chrome://tracing or Perfetto)")
    args = ap.parse_args()
    if args.mesh_worker:
        print("MESH_BENCH_JSON:" + json.dumps(_mesh_worker(args.smoke)))
        return
    if args.chaos_smoke:
        r = run_chaos_smoke(dump_out=args.dump_out)
    elif args.quant_smoke:
        r = run_quant_smoke()
    elif args.telemetry_smoke:
        r = run_telemetry_smoke(trace_out=args.trace_out)
    elif args.audit_smoke:
        r = run_audit_smoke(trace_out=args.trace_out)
    elif args.mesh_smoke:
        r = run_mesh_smoke()
    else:
        r = run(args.out, smoke=args.smoke, trace_out=args.trace_out)
    print(json.dumps(r, indent=2))


if __name__ == "__main__":
    main()
