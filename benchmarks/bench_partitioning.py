"""Paper Appendix Tables 4-6: DNN partitioning degenerates to full offload.

Derived entirely from the paper's measured per-layer compute/transfer times
(kept as the calibrated timing model in core/baselines.py); additionally
measures our L-CNN's actual per-layer CPU time for the analogous analysis.
"""
import time

import jax

from benchmarks.common import emit
from repro.core.baselines import T_OFFLOAD_MS, partition_per_sample_ms
from repro.models import cnn


def run() -> None:
    # Table 6 reproduction: per-split total latency
    best_layer, best_ms = 0, T_OFFLOAD_MS
    for layer in range(8):
        ms = partition_per_sample_ms(layer)
        emit(f"partition_split_L{layer}", ms * 1000,
             f"per-inference {ms:.1f}ms (paper L1 range [618.1,651.83])")
        if ms < best_ms:
            best_layer, best_ms = layer, ms
    emit("partition_optimal_split", best_ms * 1000,
         f"optimal split = layer {best_layer} (full offload) — appendix claim "
         f"holds: {best_layer == 0}")

    # our L-CNN per-layer timing analog (Table 4 style, CPU)
    params = cnn.init_cnn(jax.random.PRNGKey(0), cnn.LML_CIFAR)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))

    fn = jax.jit(lambda p, xx: cnn.apply_cnn(p, cnn.LML_CIFAR, xx))
    fn(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        fn(params, x).block_until_ready()
    per = (time.perf_counter() - t0) / 20
    emit("partition_our_lcnn_full", per * 1e6,
         f"single-image L-CNN inference {per*1e3:.2f}ms on this host")
