"""Paper §3 (Figs 4-5): REB fault detection — threshold S-ML separation and
bandwidth accounting."""
import time

import numpy as np

from benchmarks.common import emit
from repro.data import vibration as vib


def run() -> None:
    # threshold separation on the CWRU-statistics-matched generator
    _, labels, means = vib.make_dataset(windows_per_state=50, seed=3)
    pred_fault = vib.threshold_sml(means, 0.07)
    true_fault = labels != 0
    acc = float((pred_fault == true_fault).mean())

    # S-ML cost: windowed mean over 4096 samples (the sensor's entire compute)
    series = vib.gen_series("normal", 200, np.random.default_rng(0))
    t0 = time.perf_counter()
    for _ in range(10):
        vib.windowed_means(series)
    us = (time.perf_counter() - t0) / (10 * 200) * 1e6
    emit("reb_threshold_sml_per_window", us,
         f"normal-vs-fault acc {acc:.1%} (paper: 100%) theta=0.07")

    # bandwidth accounting (paper: >=76.8 Mbps for 100 machines)
    bw = vib.bandwidth_required(100)
    for normal_frac in (0.9, 0.98, 0.999):
        _, labels, means = vib.make_dataset(40, seed=4,
                                            normal_fraction=normal_frac)
        frac = float(vib.threshold_sml(means, 0.07).mean())
        emit(f"reb_bandwidth_normal{normal_frac}", 0.0,
             f"full {bw:.1f}Mbps -> HI {bw*frac:.2f}Mbps "
             f"({(1-frac):.1%} saved)")
