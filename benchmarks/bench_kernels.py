"""Kernel microbenchmarks: Pallas (interpret) vs the pure-jnp oracle.

On this CPU container the interpreter overhead dominates, so the derived
column reports the analytic VMEM working set / FLOP counts that govern the
TPU target rather than claiming CPU speedups.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(0)

    # hi_gate over a serving batch of logits
    for n, c in [(1024, 10), (256, 32000)]:
        logits = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
        us_k = time_us(lambda: ops.hi_gate(logits, 0.607))
        ref_jit = jax.jit(lambda l: ref.hi_gate_ref(l, 0.607))
        us_r = time_us(lambda: ref_jit(logits))
        emit(f"hi_gate_{n}x{c}", us_k,
             f"oracle {us_r:.0f}us; fuses 4 HBM passes -> 1 "
             f"({n*c*4/1e6:.1f}MB logits)")

    # decode attention over a long cache
    b, s, h, k, d = 4, 4096, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.bfloat16)
    ck = jnp.asarray(rng.normal(size=(b, s, k, d)), jnp.bfloat16)
    cv = jnp.asarray(rng.normal(size=(b, s, k, d)), jnp.bfloat16)
    valid = jnp.arange(s) < 3000
    us_k = time_us(lambda: ops.decode_attention(q, ck, cv, valid))
    ref_jit = jax.jit(lambda *a: ref.decode_attention_ref(*a))
    us_r = time_us(lambda: ref_jit(q, ck, cv, valid))
    emit(f"decode_attn_b{b}_s{s}", us_k,
         f"oracle {us_r:.0f}us; VMEM/step {2*512*d*2/1024:.0f}KB "
         f"(vs {2*s*d*2/1e6:.1f}MB unblocked)")

    # SSD chunk kernel
    b, l, hh, p, n = 2, 512, 8, 64, 64
    x = jnp.asarray(rng.normal(size=(b, l, hh, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, l, hh)), jnp.float32) * 0.5
    A = -jnp.asarray(rng.random(hh), jnp.float32) - 0.2
    B = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    us_k = time_us(lambda: ops.ssd(x, dt, A, B, C, chunk=128))
    ref_jit = jax.jit(lambda *a: ref.ssd_ref(*a, chunk=128))
    us_r = time_us(lambda: ref_jit(x, dt, A, B, C))
    emit(f"ssd_b{b}_l{l}_h{hh}", us_k,
         f"oracle {us_r:.0f}us; intra-chunk 128x128 MXU tiles, "
         f"decay buffer bounded to chunk (vs whole-seq in jnp)")
