"""Paper Table 1: CIFAR-10 HI vs no-offload vs full-offload.

Measures the HI cascade mechanism (S-CNN + fused hi_gate + router + L-CNN,
one jit program) per-batch latency, and derives the paper's exact Table-1
cost accounting from the replay module.
"""
import jax

from benchmarks.common import emit, time_us
from repro.configs.base import HIConfig
from repro.core import replay
from repro.core.cascade import classifier_cascade
from repro.models import cnn


def run() -> None:
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    ps = cnn.init_cnn(k1, cnn.SML_CIFAR)
    pl = cnn.init_cnn(k2, cnn.LML_CIFAR)
    x = jax.random.normal(k3, (256, 32, 32, 3))

    hi = HIConfig(theta=0.607, beta=0.5, capacity_factor=0.5)
    casc = classifier_cascade(
        lambda p, xx: cnn.apply_cnn(p, cnn.SML_CIFAR, xx),
        lambda p, xx: cnn.apply_cnn(p, cnn.LML_CIFAR, xx),
        hi, use_kernel=True)
    infer = casc.infer_jit()

    us = time_us(lambda: infer(ps, pl, x))
    t = replay.table1(0.5)
    emit("table1_hi_cascade_b256", us,
         f"paper: HI cost {t['hi'].cost:.0f} vs full "
         f"{t['full_offload'].cost:.0f} vs local {t['no_offload'].cost:.0f}; "
         f"HI acc {t['hi'].accuracy:.2%} offload 35.5%")

    # S-only and L-only reference points (the no-offload / full-offload rows)
    s_only = jax.jit(lambda p, xx: cnn.apply_cnn(p, cnn.SML_CIFAR, xx))
    l_only = jax.jit(lambda p, xx: cnn.apply_cnn(p, cnn.LML_CIFAR, xx))
    emit("table1_no_offload_b256", time_us(lambda: s_only(ps, x)),
         "paper acc 62.58% cost 3742")
    emit("table1_full_offload_b256", time_us(lambda: l_only(pl, x)),
         "paper acc 95% cost 10000b+500")
