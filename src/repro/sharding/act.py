"""Activation sharding constraints (no-ops outside a mesh context).

GSPMD left to itself shards the residual stream's d_model over `model` and
replicates batch (observed: +80GB/dev on granite train_4k from replicated
logits/scores).  Production frameworks pin activation layouts at layer
boundaries; these helpers do that, keyed off the ambient `with mesh:` context
so model code stays mesh-agnostic and tests on 1 CPU device are unaffected.
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from jax._src import mesh as _mesh_lib


def current_mesh() -> Optional[Mesh]:
    env = _mesh_lib.thread_resources.env
    m = env.physical_mesh
    return None if m.empty else m


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def shard_activation(x, *, extra: Tuple[Optional[str], ...] = ()):
    """Constrain a batch-leading activation over the WIDEST dividing set of
    batch axes.  (pod, data, model) when the batch divides all three — the
    ZeRO-DP layout — else (pod, data), else (data,), else unconstrained."""
    mesh = current_mesh()
    if mesh is None:
        return x
    candidates = [("pod", "data", "model"), ("pod", "data"), ("data",)]
    for cand in candidates:
        axes = tuple(a for a in cand if a in mesh.shape)
        if not axes:
            continue
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if x.shape[0] % n == 0:
            rest = list(extra) + [None] * (x.ndim - 1 - len(extra))
            # never reuse an axis already consumed by the batch dim
            rest = [None if r in axes else r for r in rest]
            return lax.with_sharding_constraint(x, P(axes, *rest))
    return x


def shard_hidden(h):
    """(B, S, D) residual stream: batch over (pod, data), D replicated."""
    return shard_activation(h)


def shard_experts(buf):
    """(E, C, D) MoE dispatch buffers: experts over `model`."""
    mesh = current_mesh()
    if mesh is None:
        return buf
    if buf.shape[0] % mesh.shape["model"]:
        return buf
    return lax.with_sharding_constraint(
        buf, P("model", *(None,) * (buf.ndim - 1)))


def shard_logits(logits):
    """(B, S, V) or (B, V): batch over (pod, data); V over model when it
    divides (most vocabs here don't divide 16 — then replicated-V with
    batch sharding is what keeps it small)."""
    mesh = current_mesh()
    if mesh is None:
        return logits
    v = logits.shape[-1]
    v_ax = "model" if v % mesh.shape["model"] == 0 else None
    return shard_activation(logits, extra=(None,) * (logits.ndim - 2) + (v_ax,))
