"""Partition rules: param-path -> PartitionSpec.

Name-based rules on the trailing dims of each leaf (leading layer-stack dims
are always unsharded).  A dim is only sharded when the mesh axis size divides
it — otherwise the rule degrades to replication for that dim (GSPMD could pad,
but uneven shards waste the pad fraction; we prefer explicit replication and
report it).

Axes:
  data  — batch / FSDP axis
  model — tensor-parallel / expert-parallel axis
  pod   — multi-pod data-parallel axis (batch is sharded over ("pod","data"))
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _div(dim: int, mesh: Mesh, axis: Optional[str]) -> Optional[str]:
    """axis if it divides dim, else None (replicate)."""
    if axis is None:
        return None
    if dim % mesh.shape[axis] == 0:
        return axis
    return None


def _trailing_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
                   fsdp: bool, tp: bool = True) -> P:
    """Spec for the *trailing* (semantic) dims; leading stack dims -> None.

    ``tp=False`` turns off tensor parallelism for the dense blocks (attention
    / MLP compute replicated over `model`, FSDP storage only) — the right
    layout for small-d_model MoE models where expert parallelism is the only
    `model`-axis consumer (EXPERIMENTS.md §Perf, deepseek hillclimb)."""
    name = path[-1]
    parents = set(path[:-1])
    d_axis = "data" if fsdp else None
    m_axis = "model" if tp else None

    def spec2(rows: Optional[str], cols: Optional[str]) -> P:
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _div(shape[-2], mesh, rows), _div(shape[-1], mesh, cols))

    def spec1(ax: Optional[str]) -> P:
        lead = (None,) * (len(shape) - 1)
        return P(*lead, _div(shape[-1], mesh, ax))

    # ---- embeddings / head --------------------------------------------------
    # embed is NOT vocab-sharded: a gather over a vocab-sharded table trips
    # GSPMD "involuntary full rematerialization" (replicated (B,S,D) + logits)
    # — observed +78GB/dev on granite train_4k.  D shards over data (FSDP);
    # the lm_head vocab-shards over model so logits come out (data, -, model).
    if name == "embed":
        return spec2(None, d_axis)
    if name == "lm_head":
        return spec2(None, "model")
    if name in ("enc_pos", "dec_pos"):
        return P(*(None,) * len(shape))

    # ---- experts: expert-parallel over model, FF-hidden over data -----------
    # (F-sharded storage matches the shard_map dispatch's token-move schedule;
    #  see models/moe.py)
    if "experts" in parents:
        lead = (None,) * (len(shape) - 3)
        e = shape[-3]
        if name in ("wi", "wg"):        # (E, D, F): F is dim -1
            return P(*lead, _div(e, mesh, "model"), None,
                     _div(shape[-1], mesh, d_axis))
        if name == "wo":                 # (E, F, D): F is dim -2
            return P(*lead, _div(e, mesh, "model"),
                     _div(shape[-2], mesh, d_axis), None)
        return P(*lead, _div(e, mesh, "model"), None, None)
    if name == "router":
        return P(*(None,) * len(shape))

    # ---- attention -----------------------------------------------------------
    if parents & {"attn", "self_attn", "cross_attn"}:
        if name in ("wq", "wk", "wv"):
            return spec2(d_axis, m_axis)
        if name == "wo":
            return spec2(m_axis, d_axis)
        if name in ("bq", "bk", "bv"):
            return spec1(m_axis)

    # ---- MLPs ------------------------------------------------------------------
    if parents & {"mlp", "shared", "dense"}:
        if name in ("wi", "wg"):
            return spec2(d_axis, m_axis)
        if name == "wo":
            return spec2(m_axis, d_axis)
        if name == "bi":
            return spec1(m_axis)
        if name == "bo":
            return spec1(None)

    # ---- mamba2 ---------------------------------------------------------------
    if name == "in_proj":       # (D, 2*di+2n+h): shard the mixed output dim is
        return spec2(d_axis, None)   # unsafe (crosses z/x/B/C); FSDP rows only
    if name == "out_proj":      # (di, D): di is head-major -> TP over model
        return spec2("model", d_axis)
    if name in ("conv_w", "conv_b", "A_log", "D", "dt_bias"):
        return P(*(None,) * len(shape))

    # ---- norms / scalars -------------------------------------------------------
    return P(*(None,) * len(shape))


def param_specs(params_tree: Any, mesh: Mesh, *, fsdp: bool = False,
                tp: bool = True) -> Any:
    """Pytree of PartitionSpec matching ``params_tree`` (arrays or SDS)."""

    def rule(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        return _trailing_spec(names, leaf.shape, mesh, fsdp, tp)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def param_shardings(params_tree: Any, mesh: Mesh, *, fsdp: bool = False,
                    tp: bool = True) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_tree, mesh, fsdp=fsdp, tp=tp))


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Batch-leading input: shard dim 0 over (pod, data) when divisible."""
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    lead = axes if (total and batch % total == 0) else None
    return P(lead, *(None,) * (ndim - 1))


def cache_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> Any:
    """Sharding for the decode cache pytree.

    decode_32k: batch over (pod, data).  long_500k (batch=1): KV-cache
    *sequence parallelism* — the seq dim shards over data instead.
    """
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    batch_ok = shape.global_batch % total == 0

    def rule(path, leaf):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        name = names[-1]
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name in ("k", "v", "ck", "cv"):
            # (L|G, B, S, K, Dh) — sequence-parallel KV cache: S shards over
            # `model` (batch already over data/pod).  Decode attention over a
            # sharded S costs only tiny softmax-stat + output all-reduces,
            # while cache HBM and the (B,H,S) score row spread over all chips.
            if nd != 5:
                return P(*(None,) * nd)
            s_dim = leaf.shape[2]
            if batch_ok:
                s_ax = "model" if s_dim % mesh.shape["model"] == 0 else None
                return P(None, axes, s_ax, None, None)
            # batch=1 (long_500k): spread S over every available axis
            flat = tuple(a for a in ("data", "model") if a in mesh.shape)
            tot = 1
            for a in flat:
                tot *= mesh.shape[a]
            s_ax2 = flat if s_dim % tot == 0 else "data"
            return P(None, None, s_ax2, None, None)
        if name == "state":
            # ssm state (L, B, H, P, N) or (G, k, B, H, P, N)
            lead = (None,) * (nd - 4)
            return P(*lead, axes if batch_ok else None,
                     _div(leaf.shape[-3], mesh, "model"), None, None)
        if name == "conv":
            lead = (None,) * (nd - 3)
            return P(*lead, axes if batch_ok else None, None, None)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(rule, shape_tree(cfg, shape))


def shape_tree(cfg: ModelConfig, shape: ShapeConfig):
    from repro.models import model_zoo
    return model_zoo.cache_spec(cfg, shape.global_batch, shape.seq_len)


def paged_cache_specs(cfg: ModelConfig, mesh: Mesh, buffers: Any) -> Any:
    """Sharding for a serving-tier PAGED pool pytree (``KVPool.buffers``).

    The L tier of the mesh-sharded scheduler keeps ONE pool whose page
    tensors shard over ``model`` on the KV-head dim — the same tensor-
    parallel cut as the attention projections, so the page-gather feeding a
    head group reads only that group's local pages.  Per leaf:

    * ``kp`` / ``vp`` (L, P, page, K, Dh): K over ``model`` when divisible
      (same ``_div`` degrade-to-replicate rule as the param specs);
    * ``ks`` / ``vs`` int8-pool scales (L, P, K): K over ``model``;
    * everything else (recurrent ``state`` / ``conv`` rows, logits) —
      replicated: per-slot state is small and the slot dim is the DATA-axis
      concern, which the S tier handles by replica-stacking, not sharding.
    """
    del cfg

    def rule(path, leaf):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        name = names[-1]
        nd = len(leaf.shape)
        if name in ("kp", "vp") and nd == 5:
            return P(None, None, None, _div(leaf.shape[3], mesh, "model"),
                     None)
        if name in ("ks", "vs") and nd == 3:
            return P(None, None, _div(leaf.shape[2], mesh, "model"))
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(rule, buffers)


def paged_cache_shardings(cfg: ModelConfig, mesh: Mesh, buffers: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        paged_cache_specs(cfg, mesh, buffers))
