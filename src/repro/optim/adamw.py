"""AdamW with optional bf16 moment storage, global-norm clipping, schedules.

Self-contained (no optax in this environment).  State is a pytree mirroring
params: {"m": ..., "v": ..., "step": scalar}.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Params = Any


def _factorable(shape) -> bool:
    """Factor the second moment for >=2D weights (Adafactor rule)."""
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def init_state(params: Params, cfg: TrainConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.bf16_state else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)

    def v_init(p):
        if cfg.factored_v and _factorable(p.shape):
            # row/col mean-square stats — O(rows+cols) instead of O(rows*cols)
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(v_init, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to 10%."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def apply_updates(params: Params, grads: Params, state: Dict[str, Any],
                  cfg: TrainConfig) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"]
    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        mh = m32 / c1
        if isinstance(v, dict):                       # factored second moment
            g2 = jnp.square(g32) + eps * eps
            vr = b2 * v["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * v["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction: v ~ vr vc^T / mean(vr)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = (vr[..., None] * vc[..., None, :] / denom[..., None]) / c2
            delta = mh / (jnp.sqrt(vhat) + eps)
            new_v = {"vr": vr, "vc": vc}
        else:
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            delta = mh / (jnp.sqrt(v32 / c2) + eps)
            new_v = v32.astype(v.dtype)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), new_v

    # traverse v first: its factored {vr, vc} dicts are leaves, and params/
    # grads/m hold plain arrays at the corresponding positions
    is_vleaf = lambda x: isinstance(x, dict) and set(x) == {"vr", "vc"}
    triples = jax.tree.map(lambda v, p, g, m: upd(p, g, m, v),
                           state["v"], params, grads, state["m"],
                           is_leaf=is_vleaf)
    leaf3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_p = jax.tree.map(lambda t: t[0], triples, is_leaf=leaf3)
    new_m = jax.tree.map(lambda t: t[1], triples, is_leaf=leaf3)
    new_v = jax.tree.map(lambda t: t[2], triples, is_leaf=leaf3)
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
