"""Checkpointing: msgpack-serialised pytrees with dtype/shape manifest.

No orbax in this environment; this is a small, dependency-light format:
  <dir>/manifest.msgpack   — tree structure, shapes, dtypes, step
  <dir>/arrays.npz         — flattened leaves by index
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save(directory: str, tree: Any, step: int = 0,
         extra: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(directory, exist_ok=True)
    flat, treedef = _paths(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
    manifest = {
        "treedef": str(treedef),
        "num_leaves": len(flat),
        "step": step,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "extra": extra or {},
    }
    with open(os.path.join(directory, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(directory, "arrays.npz"), **arrays)


def restore(directory: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with open(os.path.join(directory, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(directory, "arrays.npz"))
    flat_like, treedef = _paths(like)
    if manifest["num_leaves"] != len(flat_like):
        raise ValueError("checkpoint structure mismatch: "
                         f"{manifest['num_leaves']} leaves vs {len(flat_like)}")
    flat = []
    for i, ref in enumerate(flat_like):
        arr = data[f"a{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        flat.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, flat), manifest["step"]


def exists(directory: str) -> bool:
    return os.path.exists(os.path.join(directory, "manifest.msgpack"))
