"""CIFAR-10 stand-in: synthetic class-conditional 32x32x3 images (paper §4–5).

CIFAR-10 is not available offline, so the generator is engineered to mirror
the paper's *S/L accuracy structure* rather than its pixels:

* a WEAK GLOBAL cue — a class tint on a colour circle with angular jitter —
  whose Bayes accuracy is ~62% (tunable via ``tint_sigma``).  A tinyML-sized
  CNN learns this quickly, landing near the paper's S-ML (62.58%).
* a STRONG LOCAL cue — a class-specific texture-patch pair at mildly
  jittered positions — that needs more depth/capacity to exploit; the deeper
  L-CNN combines both cues and lands near the paper's L-ML (95%).

Crucially, S-ML *confidence* correlates with correctness (samples whose tint
lands near a class boundary are genuinely ambiguous to the S-tier), which is
the property HI's threshold rule exploits (paper Fig. 6).

Class 5 ("dog") doubles as the class-of-interest for the §5 binary filter.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

NUM_CLASSES = 10
DOG_CLASS = 5
_PATCH = 12
IMG = 32

# the strong cue is COMPOSITIONAL: 5 shared blocky base patterns; class c is
# the unordered pair PAIRS[c] of two of them, each placed at a fully random
# position.  Classification requires a conjunction of two translation-
# invariant detections — easy for the global-pooled L-CNN, out of reach for
# the flatten-head tinyML S-ML (calibrated: S~80%, L~94%).
from itertools import combinations
PAIRS = tuple(combinations(range(5), 2))          # exactly 10 classes


def _patterns(seed: int = 1234) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = rng.normal(0, 1, size=(5, 3, 3, 3))    # low-frequency (blocky)
    p = np.repeat(np.repeat(base, 4, axis=1), 4, axis=2)   # (5, 12, 12, 3)
    return (p / np.abs(p).max(axis=(1, 2, 3), keepdims=True)).astype(np.float32)


def make_dataset(n: int, seed: int = 0, noise: float = 0.40,
                 tint_sigma: float = 0.357, tint_amp: float = 0.5,
                 patch_amp: float = 0.5) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (n,32,32,3) float32, labels (n,) int32).

    ``tint_sigma`` = 0.357 puts the tint-only Bayes accuracy at ~62%
    (P(|N(0,s)| < pi/10)).  With ``patch_amp=0.5`` the measured tiers land at
    S ~ 80%, L ~ 94% (vs the paper's 62.58%/95% — the *structure* matches:
    a large S/L gap with confidence-correlated S errors; the paper's exact
    counts are replayed separately by core/replay).
    """
    rng = np.random.default_rng(seed)
    prims = _patterns()
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    imgs = rng.normal(0, noise, size=(n, IMG, IMG, 3)).astype(np.float32)

    # weak global cue: tint direction on a colour circle + angular jitter
    angles = 2 * np.pi * labels / NUM_CLASSES \
        + rng.normal(0, tint_sigma, size=n)
    tint = np.stack([np.cos(angles), np.sin(angles),
                     np.zeros_like(angles)], axis=-1).astype(np.float32)
    imgs += tint_amp * tint[:, None, None, :]

    # strong local cue: the class's pattern PAIR at fully random positions
    for i in range(n):
        for p in PAIRS[labels[i]]:
            y, x = rng.integers(0, IMG - _PATCH, 2)
            imgs[i, y:y + _PATCH, x:x + _PATCH] += patch_amp * prims[p]
    return imgs, labels


def binary_labels(labels: np.ndarray, cls: int = DOG_CLASS) -> np.ndarray:
    """Dog / not-dog labels for the §5 relevance filter."""
    return (labels == cls).astype(np.int32)
