"""CWRU-like synthetic vibration data (paper §3).

The real CWRU bearing dataset is not available offline; this generator
reproduces its *structure*: a rotating-machine vibration series sampled at
48 kHz, in one of 10 states — normal + {inner lace, outer lace, ball} x
{0.18, 0.36, 0.54 mm} fault widths.  Amplitude statistics mirror the paper's
Figures 4–5: the windowed mean |x| of the normal state sits below 0.07 while
every fault state sits above it, and (as in Fig. 5) some fault states overlap
each other so only the CNN can separate *which* fault it is.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

WINDOW = 4096               # samples per inference window (paper: 4096)
SAMPLE_RATE = 48_000        # Hz
BYTES_PER_SAMPLE = 2        # paper: 2-byte registers

STATES = ("normal",
          "inner_018", "inner_036", "inner_054",
          "outer_018", "outer_036", "outer_054",
          "ball_018", "ball_036", "ball_054")

# target windowed mean |x| per state (normal < 0.07 threshold, faults above).
# inner/outer pairs overlap at the larger widths — mirroring Fig. 5 where
# thresholds alone cannot separate them.
_STATE_MEAN = {
    "normal": 0.045,
    "inner_018": 0.110, "inner_036": 0.160, "inner_054": 0.230,
    "outer_018": 0.125, "outer_036": 0.165, "outer_054": 0.235,
    "ball_018": 0.095, "ball_036": 0.140, "ball_054": 0.200,
}
# distinct impulse periodicities let a CNN separate what thresholds cannot
_STATE_FREQ = {s: 40 + 17 * i for i, s in enumerate(STATES)}


def gen_series(state: str, num_windows: int, rng: np.random.Generator,
               motor_load: int = 0) -> np.ndarray:
    """Vibration series of ``num_windows * WINDOW`` samples for one state."""
    n = num_windows * WINDOW
    base = _STATE_MEAN[state] * (1.0 + 0.05 * motor_load)
    noise = rng.normal(0.0, base * 1.2533, size=n)   # E|x| = sigma*sqrt(2/pi)
    if state != "normal":
        # periodic fault impulses (characteristic frequency per fault type)
        t = np.arange(n)
        period = SAMPLE_RATE // _STATE_FREQ[state]
        impulses = ((t % period) < 8).astype(np.float64)
        ring = np.sin(2 * np.pi * t / 23.0) * np.exp(-(t % period) / 40.0)
        noise = noise + 0.8 * base * impulses * ring
    return noise.astype(np.float32)


def windowed_means(series: np.ndarray) -> np.ndarray:
    """Mean |x| per 4096-sample window (the sensor's moving-average S-ML)."""
    w = series[: len(series) // WINDOW * WINDOW].reshape(-1, WINDOW)
    return np.abs(w).mean(axis=1)


def windows_to_images(series: np.ndarray, scale: float = 1.0) -> np.ndarray:
    """(n*4096,) -> (n, 64, 64, 1) grey images, the CNN input of [38].

    FIXED scaling (not per-window min-max): the fault classes differ in
    absolute vibration amplitude as well as impulse periodicity, and
    per-window normalisation would erase the amplitude cue."""
    w = series[: len(series) // WINDOW * WINDOW].reshape(-1, WINDOW)
    img = np.clip(np.abs(w) / scale, 0.0, 1.0)
    return img.reshape(-1, 64, 64, 1).astype(np.float32)


def make_dataset(windows_per_state: int, seed: int = 0,
                 normal_fraction: float = None
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (images (N,64,64,1), labels (N,), window_means (N,)).

    ``normal_fraction`` optionally over-samples the normal state (machines are
    normal for hundreds of hours — the premise of the bandwidth saving)."""
    rng = np.random.default_rng(seed)
    imgs, labels, means = [], [], []
    for i, s in enumerate(STATES):
        k = windows_per_state
        if normal_fraction is not None:
            if s == "normal":
                k = int(windows_per_state * normal_fraction * len(STATES))
            else:
                k = max(1, int(windows_per_state * (1 - normal_fraction) *
                               len(STATES) / (len(STATES) - 1)))
        series = gen_series(s, k, rng)
        imgs.append(windows_to_images(series))
        means.append(windowed_means(series))
        labels.append(np.full(k, i, np.int32))
    perm = np.random.default_rng(seed + 1).permutation(
        sum(len(x) for x in labels))
    return (np.concatenate(imgs)[perm], np.concatenate(labels)[perm],
            np.concatenate(means)[perm])


def threshold_sml(window_means: np.ndarray, theta: float = 0.07) -> np.ndarray:
    """The paper's S-ML: normal iff windowed mean < theta.  Returns bool
    'is_fault' (= complex sample = offload)."""
    return window_means >= theta


def bandwidth_required(num_machines: int, rebs_per_machine: int = 2) -> float:
    """Mbps to stream everything to the ES (paper: >= 76.8 Mbps for 100
    machines x 2 REBs at 48 kHz x 2 bytes)."""
    return num_machines * rebs_per_machine * SAMPLE_RATE * BYTES_PER_SAMPLE \
        * 8 / 1e6
