"""Synthetic LM token streams (zipf-distributed with short-range structure)
for training-loop smoke tests and the end-to-end driver."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def token_stream(vocab: int, seed: int = 0) -> np.random.Generator:
    return np.random.default_rng(seed)


def sample_tokens(rng: np.random.Generator, batch: int, seq: int,
                  vocab: int) -> np.ndarray:
    """Zipf marginal + local repetition structure (so loss can fall)."""
    z = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    toks = (z - 1) % vocab
    # inject learnable bigram structure: even positions predict the next
    n_pairs = seq // 2
    toks[:, 1:2 * n_pairs:2] = (toks[:, 0:2 * n_pairs:2] * 7 + 13) % vocab
    return toks.astype(np.int32)


def lm_batches(vocab: int, batch: int, seq: int, steps: int,
               seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    rng = token_stream(vocab, seed)
    for _ in range(steps):
        toks = sample_tokens(rng, batch, seq + 1, vocab)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
