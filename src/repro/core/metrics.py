"""Accounting: turn cascade outputs + ground truth into the paper's tables."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.baselines import BaselineResult, TimingModel
from repro.core.cost import CostReport


def hi_report(pred: np.ndarray, s_pred: np.ndarray, served_remote: np.ndarray,
              offload_mask: np.ndarray, labels: np.ndarray, l_pred: Optional[np.ndarray],
              beta: float, name: str = "hierarchical-inference") -> CostReport:
    """Build a Table-1-style row from cascade outputs.

    wrong_local  = accepted-local (not offloaded) and wrong
    wrong_remote = served remotely and wrong
    """
    pred = np.asarray(pred)
    labels = np.asarray(labels)
    served = np.asarray(served_remote, bool)
    offl = np.asarray(offload_mask, bool)
    wrong = pred != labels
    return CostReport(
        approach=name,
        n=len(labels),
        offloaded=int(offl.sum()),
        wrong_local=int((wrong & ~served).sum()),
        wrong_remote=int((wrong & served).sum()),
        beta=beta,
    )


def baseline_report(r: BaselineResult, beta: float) -> CostReport:
    return CostReport(
        approach=r.name, n=r.n, offloaded=r.n_offloaded,
        wrong_local=int(r.n - r.n_correct), wrong_remote=0, beta=beta)


def hi_baseline_result(report: CostReport, tm: TimingModel) -> BaselineResult:
    """Timing view of an HI run (for the Fig. 8 comparison)."""
    return BaselineResult(
        name=report.approach, n=report.n, n_offloaded=report.offloaded,
        n_correct=report.n - report.misclassified,
        makespan_ms=tm.hi_makespan_ms(report.n, report.offloaded))


def format_table(rows) -> str:
    rows = [r.row() if hasattr(r, "row") else r for r in rows]
    keys = list(rows[0].keys())
    widths = {k: max(len(str(k)), *(len(f"{r[k]:.2f}" if isinstance(r[k], float)
                                        else str(r[k])) for r in rows))
              for k in keys}
    def fmt(v):
        return f"{v:.2f}" if isinstance(v, float) else str(v)
    lines = [" | ".join(k.ljust(widths[k]) for k in keys)]
    lines.append("-+-".join("-" * widths[k] for k in keys))
    for r in rows:
        lines.append(" | ".join(fmt(r[k]).ljust(widths[k]) for k in keys))
    return "\n".join(lines)
