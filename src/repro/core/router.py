"""Static-capacity sample router — HI's offload on a TPU fabric.

XLA needs static shapes, so "offload the complex samples" becomes: pick the
``capacity`` highest-priority samples (priority = wants-offload first, then
lowest confidence), gather them into a fixed (capacity, ...) batch for the
L-tier, and scatter-merge L-tier outputs back.  Samples that want offload but
exceed capacity are *dropped escalations* (served with the S-tier result) and
counted — the same accounting MoE frameworks report for token dropping.

This mirrors the MoE dispatch in models/moe.py one level up: the paper's ED→ES
link is the gather collective across the mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RouteDecision(NamedTuple):
    indices: jnp.ndarray      # (C,) int32 — positions gathered for the L-tier
    valid: jnp.ndarray        # (C,) bool  — gathered slot actually wants offload
    offload_mask: jnp.ndarray  # (N,) bool — the policy's raw decision
    served_remote: jnp.ndarray  # (N,) bool — offloaded AND within capacity
    dropped: jnp.ndarray      # ()   int32 — wanted offload, no capacity


def route(offload_mask: jnp.ndarray, conf: jnp.ndarray,
          capacity: int) -> RouteDecision:
    """offload_mask, conf: (N,).  capacity: static int <= N."""
    n = offload_mask.shape[0]
    if not 0 < capacity <= n:
        raise ValueError(f"capacity {capacity} must be in (0, {n}]")
    # priority: offloads first (by ascending confidence), non-offloads last
    prio = jnp.where(offload_mask, 2.0 - conf, -conf)
    _, idx = jax.lax.top_k(prio, capacity)
    valid = offload_mask[idx]
    served = jnp.zeros((n,), bool).at[idx].set(valid)
    dropped = jnp.sum(offload_mask) - jnp.sum(valid)
    return RouteDecision(idx.astype(jnp.int32), valid, offload_mask,
                         served, dropped.astype(jnp.int32))


def gather(x: jnp.ndarray, decision: RouteDecision) -> jnp.ndarray:
    """(N, ...) -> (C, ...) complex-sample batch for the L-tier."""
    return x[decision.indices]


def scatter_merge(s_out: jnp.ndarray, l_out: jnp.ndarray,
                  decision: RouteDecision) -> jnp.ndarray:
    """Merge L-tier outputs over S-tier outputs at the served positions.

    s_out: (N, ...); l_out: (C, ...) aligned with decision.indices.
    """
    upd = jnp.where(
        decision.valid.reshape((-1,) + (1,) * (l_out.ndim - 1)),
        l_out, s_out[decision.indices])
    return s_out.at[decision.indices].set(upd)


def agreement(s_out: jnp.ndarray, l_out: jnp.ndarray,
              decision: RouteDecision) -> jnp.ndarray:
    """Per-slot S/L agreement over the gathered batch -> (C,) bool.

    The online-policy correctness proxy (paper ref [27]): the ED never sees
    ground truth, so S-tier/L-tier agreement on the escalated samples stands
    in for it.  Computed on device so the serving engine's single post-cascade
    host fetch covers it.
    """
    s_sub = s_out[decision.indices]
    axes = tuple(range(1, l_out.ndim))
    return (s_sub == l_out).all(axis=axes) if axes else s_sub == l_out


def capacity_for(batch: int, capacity_factor: float) -> int:
    return max(1, min(batch, int(round(batch * capacity_factor))))
