"""Offload decision policies (the HI decision module of Fig. 1).

* :class:`ThresholdPolicy` — the paper's rule: offload iff conf < theta.
* :class:`BinaryRelevancePolicy` — §5 dog-filter rule: offload iff p >= theta
  (the *positive* class is the complex one).
* :class:`OnlineThresholdPolicy` — no-regret online tuning of theta via an
  EXP3-style bandit over a discretised threshold grid, following the paper's
  companion work [27] (Moothedath et al., Online Algorithms for HI): after
  each sample we observe the *full-information* cost of every candidate
  threshold (the cost is computable counterfactually from (conf, s_correct)),
  so this is exponentially-weighted-average forecasting (Hedge) over experts.
* :class:`AlwaysOffload` / :class:`NeverOffload` — the full-offload and
  tinyML endpoints.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


class Policy:
    def offload(self, conf: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    name: str = "policy"


@dataclass
class ThresholdPolicy(Policy):
    theta: float = 0.607
    name: str = "hi-threshold"

    def offload(self, conf):
        return conf < self.theta


@dataclass
class BinaryRelevancePolicy(Policy):
    """Offload the samples of interest (conf = P(positive class))."""
    theta: float = 0.5
    name: str = "hi-binary"

    def offload(self, conf):
        return conf >= self.theta


@dataclass
class AlwaysOffload(Policy):
    name: str = "full-offload"

    def offload(self, conf):
        return jnp.ones_like(conf, dtype=bool)


@dataclass
class NeverOffload(Policy):
    name: str = "tinyml"

    def offload(self, conf):
        return jnp.zeros_like(conf, dtype=bool)


class OnlineThresholdPolicy(Policy):
    """Hedge over a grid of thresholds; full-information counterfactual cost.

    After serving sample i we can evaluate, for every candidate theta, the
    cost that theta *would* have incurred: offloading costs ~(beta + E[eta]),
    accepting costs gamma_i.  Weights update multiplicatively; the acting
    threshold is the weighted median, so the policy converges to theta*
    (paper [27], Thm. 1-style guarantee).
    """

    name = "hi-online"

    def __init__(self, beta: float, grid: int = 64, eta_lr: float = 0.15,
                 l_ml_err: float = 0.0):
        self.grid = np.linspace(0.0, 1.0, grid, endpoint=False)
        self.w = np.ones(grid, dtype=np.float64)
        self.beta = beta
        self.eta_lr = eta_lr
        self.l_ml_err = l_ml_err     # expected remote error E[eta]
        self.history: list[float] = []

    @property
    def theta(self) -> float:
        p = self.w / self.w.sum()
        cdf = np.cumsum(p)
        return float(self.grid[int(np.searchsorted(cdf, 0.5))])

    def offload(self, conf):
        return conf < self.theta

    def update(self, conf: np.ndarray, s_correct: np.ndarray) -> None:
        """Batched counterfactual update."""
        conf = np.asarray(conf, np.float64)
        ok = np.asarray(s_correct, np.float64)
        for c, k in zip(conf, ok):
            # cost of each candidate theta on this sample
            offload = c < self.grid
            cost = np.where(offload, self.beta + self.l_ml_err, 1.0 - k)
            self.w *= np.exp(-self.eta_lr * cost)
            s = self.w.sum()
            if s < 1e-290:           # renormalise to dodge underflow
                self.w /= s
            self.history.append(self.theta)
