"""Analytical replay of the paper's *published* numbers.

The paper's datasets (CIFAR-10 + their trained TFLite S-ML) are not available
offline, so alongside the synthetic-data reproduction we replay the exact
counts the paper reports and verify every derived quantity (cost formulas,
accuracy, cost-reduction ranges).  This pins our cost/metric implementations
to the paper's ground truth.

Paper §4 / Table 1 (CIFAR-10, N=10000, theta*=0.607):
  full offload : 500 wrong on ES                  -> cost 10000*beta + 500
  no offload   : 3742 wrong on ED (62.58% acc)    -> cost 3742
  HI           : 3550 offloaded, 71 wrong on ES,
                 1577 wrong accepted locally      -> cost 3550*beta + 1648
                 accuracy 83.52%

Paper §5 / Table 3 (dog filter, N=10000, 1000 dogs):
  full offload : offload all;  cost 1000*beta + 9000   (9000 irrelevant)
  HI           : 4433 offloaded = 912 dogs + 3521 false positives;
                 88 dogs missed -> 91.2% accuracy; cost 912*beta + 3521
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.cost import CostReport, relative_cost_reduction

N_CIFAR = 10_000


def table1(beta: float) -> Dict[str, CostReport]:
    no_offload = CostReport("no-offload", N_CIFAR, 0, 3742, 0, beta)
    full = CostReport("full-offload", N_CIFAR, N_CIFAR, 0, 500, beta)
    hi = CostReport("hierarchical-inference", N_CIFAR, 3550, 1577, 71, beta)
    return {"no_offload": no_offload, "full_offload": full, "hi": hi}


def table1_cost_reduction(beta: float) -> float:
    """Paper: HI vs full offload, range 14–49% over beta in (0, 1)."""
    t = table1(beta)
    return relative_cost_reduction(t["hi"].cost, t["full_offload"].cost)


@dataclass
class DogReplay:
    n: int = N_CIFAR
    dogs: int = 1000
    offloaded_dogs: int = 912           # true positives reaching the L-ML
    missed_dogs: int = 88               # false negatives
    false_positives: int = 3521         # irrelevant images offloaded

    @property
    def n_offloaded(self) -> int:
        return self.offloaded_dogs + self.false_positives   # 4433

    @property
    def accuracy(self) -> float:
        return self.offloaded_dogs / self.dogs              # 0.912

    def cost_hi(self, beta: float) -> float:
        # beta per offloaded dog + 1 per offloaded irrelevant image
        return self.offloaded_dogs * beta + self.false_positives

    def cost_full(self, beta: float) -> float:
        return self.dogs * beta + (self.n - self.dogs)

    def cost_reduction(self, beta: float) -> float:
        """Paper: ((88 beta + 5479) / (1000 beta + 9000)) x 100%."""
        return (self.cost_full(beta) - self.cost_hi(beta)) \
            / self.cost_full(beta) * 100.0


def fig8_hi_vs_full_offload(beta: float = 0.5) -> Dict[str, float]:
    """§6: HI reduces latency / offloads by ~63.15% / ~64.45% at beta=0.5."""
    from repro.core.baselines import TimingModel
    tm = TimingModel()
    t = table1(beta)
    hi = t["hi"]
    latency_hi = tm.hi_makespan_ms(hi.n, hi.offloaded)
    latency_full = hi.n * tm.t_offload_ms
    return {
        "latency_reduction_pct": (1 - latency_hi / latency_full) * 100.0,
        "offload_reduction_pct": (1 - hi.offloaded / hi.n) * 100.0,
        "hi_accuracy_pct": hi.accuracy * 100.0,
    }
