"""The paper's §6 comparison baselines, with its measured timing model.

The paper's testbed constants (Raspberry Pi 4B ED, Tesla-T4 ES, 802.11 5 GHz
WLAN at 10.45 MB/s) are kept as a calibrated timing model so Figure 8 can be
reproduced quantitatively on any host:

  t_local   = 0.99 ms     S-ML inference on the ED
  t_offload = 74.34 ms    image transfer + L-ML inference on the ES

DNN-partitioning constants come from Appendix Tables 4–6 (EfficientNet split
between the Pi and the ES).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

T_LOCAL_MS = 0.99
T_OFFLOAD_MS = 74.34
WLAN_MBPS = 10.45          # MB/s measured via iPerf (SD 0.6)

# Appendix Table 4: per-layer EfficientNet time (ms) on Pi / ES-GPU
PI_LAYER_MS = (328.9, 1640.7, 1131.7, 970.0, 1561.0, 1981.0, 539.8)
ES_LAYER_MS = (1.01, 2.51, 1.50, 2.16, 2.31, 2.89, 0.91)
# Appendix Table 5: per-layer activation size (MB) and transfer time (ms)
LAYER_OUT_MB = (3.06, 1.64, 1.13, 0.97, 1.56, 1.98, 0.53)
IMAGE_MB = 0.003
LAYER_COMM_MS = tuple(1000.0 * mb / WLAN_MBPS for mb in LAYER_OUT_MB)
IMAGE_COMM_MS = 1000.0 * IMAGE_MB / WLAN_MBPS


@dataclass
class TimingModel:
    t_local_ms: float = T_LOCAL_MS
    t_offload_ms: float = T_OFFLOAD_MS

    def makespan_ms(self, n_local: int, n_offload: int) -> float:
        """ED and ES pipelines run concurrently; the ED also fronts every
        offloaded sample's S-ML pass under HI (handled by caller)."""
        return max(n_local * self.t_local_ms, n_offload * self.t_offload_ms)

    def hi_makespan_ms(self, n: int, n_offload: int) -> float:
        """HI: every sample runs S-ML on the ED, then offloads overlap."""
        return n * self.t_local_ms + n_offload * self.t_offload_ms

    def throughput(self, n: int, makespan_ms: float) -> float:
        return n / (makespan_ms / 1000.0)


@dataclass
class BaselineResult:
    name: str
    n: int
    n_offloaded: int
    n_correct: int
    makespan_ms: float

    @property
    def accuracy(self) -> float:
        return self.n_correct / self.n

    @property
    def throughput(self) -> float:
        return self.n / (self.makespan_ms / 1000.0)


def tinyml(s_correct: np.ndarray, tm: TimingModel) -> BaselineResult:
    """No offload: accept every S-ML inference."""
    n = len(s_correct)
    return BaselineResult("tinyml", n, 0, int(s_correct.sum()),
                          n * tm.t_local_ms)


def full_offload(l_correct: np.ndarray, tm: TimingModel) -> BaselineResult:
    n = len(l_correct)
    return BaselineResult("full-offload", n, n, int(l_correct.sum()),
                          n * tm.t_offload_ms)


def omd(s_correct: np.ndarray, l_correct: np.ndarray,
        tm: TimingModel, rng: Optional[np.random.Generator] = None
        ) -> BaselineResult:
    """Offloading for Minimizing Delay: split so both tiers finish together.

    k local and n-k offloaded with k*t_l = (n-k)*t_o  ->  k = n*t_o/(t_l+t_o).
    Samples are assigned randomly (the scheduler is accuracy-blind).
    """
    n = len(s_correct)
    k = int(round(n * tm.t_offload_ms / (tm.t_local_ms + tm.t_offload_ms)))
    rng = rng or np.random.default_rng(0)
    perm = rng.permutation(n)
    local, remote = perm[:k], perm[k:]
    correct = int(s_correct[local].sum() + l_correct[remote].sum())
    return BaselineResult("omd", n, n - k, correct,
                          tm.makespan_ms(k, n - k))


def oma(s_correct: np.ndarray, l_correct: np.ndarray, time_budget_ms: float,
        tm: TimingModel, worst_case: bool = False,
        rng: Optional[np.random.Generator] = None) -> BaselineResult:
    """Offloading for Maximizing Accuracy under a makespan constraint.

    Offload as many samples as the budget allows (they gain L-ML accuracy);
    the rest run locally.  The scheduler only knows *average* accuracies, so
    which samples go where is random — or adversarial in the worst case
    (it offloads exactly the samples S-ML had right; paper §6 'OMA worst
    case').
    """
    n = len(s_correct)
    n_off = min(n, int(time_budget_ms / tm.t_offload_ms))
    n_loc = n - n_off
    # local work must also fit the budget
    if n_loc * tm.t_local_ms > time_budget_ms:
        n_loc = int(time_budget_ms / tm.t_local_ms)
        n_off = n - n_loc
        n_off = min(n_off, int(time_budget_ms / tm.t_offload_ms))
    if worst_case:
        order = np.argsort(~s_correct)       # correct-on-S first -> offloaded
    else:
        rng = rng or np.random.default_rng(1)
        order = rng.permutation(n)
    remote, local = order[:n_off], order[n_off:]
    correct = int(s_correct[local].sum() + l_correct[remote].sum())
    name = "oma-worst" if worst_case else "oma"
    return BaselineResult(name, n, n_off, correct,
                          tm.makespan_ms(len(local), n_off))


def dnn_partitioning(l_correct: np.ndarray, split_layer: int = 0
                     ) -> BaselineResult:
    """Neurosurgeon-style partitioning.  Appendix: for 32x32 inputs every
    split is dominated by full offload, so the optimal split IS full offload;
    other splits are provided for the Table-6 comparison."""
    n = len(l_correct)
    if split_layer == 0:
        per_sample = T_OFFLOAD_MS
    else:
        pi = sum(PI_LAYER_MS[:split_layer])
        comm = LAYER_COMM_MS[split_layer - 1]
        es = sum(ES_LAYER_MS[split_layer:])
        per_sample = pi + comm + es
    return BaselineResult(f"dnn-partition-L{split_layer}", n, n,
                          int(l_correct.sum()), n * per_sample)


def partition_per_sample_ms(split_layer: int) -> float:
    """Single-inference latency for a split at ``split_layer`` (Table 6)."""
    if split_layer == 0:
        return T_OFFLOAD_MS
    pi = sum(PI_LAYER_MS[:split_layer])
    comm = LAYER_COMM_MS[split_layer - 1]
    es = sum(ES_LAYER_MS[split_layer:])
    return pi + comm + es
