"""Confidence metrics over S-ML output logits (paper §4).

The paper uses the max softmax probability p; we additionally provide margin
and (negated, normalised) entropy so the decision module is pluggable.  All
metrics are oriented so HIGHER = more confident, and live in [0, 1], which
keeps the paper's threshold rule ``offload iff conf < theta`` uniform.

``kernels/hi_gate.py`` is the fused Pallas version of :func:`confidence` +
threshold; this module is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

METRICS = ("max_prob", "margin", "entropy")


def max_prob(logits: jnp.ndarray) -> jnp.ndarray:
    """(..., C) -> (...): max softmax probability (the paper's p)."""
    return jnp.max(jax.nn.softmax(logits.astype(jnp.float32), axis=-1), axis=-1)


def margin(logits: jnp.ndarray) -> jnp.ndarray:
    """Top1 - top2 softmax probability."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return top2[..., 0] - top2[..., 1]


def entropy_conf(logits: jnp.ndarray) -> jnp.ndarray:
    """1 - H(p)/log(C): 1 = deterministic pmf, 0 = uniform."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    h = -jnp.sum(p * logp, axis=-1)
    return 1.0 - h / jnp.log(logits.shape[-1])


def binary_prob(logits: jnp.ndarray) -> jnp.ndarray:
    """Sigmoid score for single-logit binary heads (§5 dog filter)."""
    return jax.nn.sigmoid(logits.astype(jnp.float32))[..., 0]


def confidence(logits: jnp.ndarray, metric: str = "max_prob") -> jnp.ndarray:
    if logits.shape[-1] == 1:
        return binary_prob(logits)
    if metric == "max_prob":
        return max_prob(logits)
    if metric == "margin":
        return margin(logits)
    if metric == "entropy":
        return entropy_conf(logits)
    raise ValueError(f"unknown confidence metric {metric!r}")


def temperature_scale(logits: jnp.ndarray, temp: float) -> jnp.ndarray:
    """Post-hoc calibration knob (higher temp -> softer pmf)."""
    return logits / jnp.maximum(temp, 1e-6)
