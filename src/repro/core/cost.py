"""The paper's abstract cost model (§4).

Per sample i:
    C_i = beta + eta_i   if offloaded   (eta_i = 1 iff L-ML wrong)
        = gamma_i        otherwise      (gamma_i = 1 iff accepted S-ML wrong)

All quantities vectorise over a batch; totals are sums, so batched serving
reproduces the paper's per-image accounting exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax.numpy as jnp


def per_sample_cost(offloaded: jnp.ndarray, s_correct: jnp.ndarray,
                    l_correct: jnp.ndarray, beta: float) -> jnp.ndarray:
    """All inputs (N,) bool; returns (N,) float32 costs."""
    off = offloaded.astype(jnp.float32)
    eta = 1.0 - l_correct.astype(jnp.float32)
    gamma = 1.0 - s_correct.astype(jnp.float32)
    return off * (beta + eta) + (1.0 - off) * gamma


def total_cost(offloaded, s_correct, l_correct, beta: float) -> jnp.ndarray:
    return jnp.sum(per_sample_cost(offloaded, s_correct, l_correct, beta))


def cost_closed_form(n_offloaded: int, n_wrong_local: int, n_wrong_remote: int,
                     beta: float) -> float:
    """The paper's tabulated form: N_off*beta + misclassified."""
    return n_offloaded * beta + n_wrong_local + n_wrong_remote


def relative_cost_reduction(cost_hi: float, cost_ref: float) -> float:
    """Paper's '(1 - HI/ref) x 100%' cost-reduction metric."""
    return (1.0 - cost_hi / cost_ref) * 100.0


@dataclass
class CostReport:
    """One row of the paper's Table 1 / Table 3."""
    approach: str
    n: int
    offloaded: int
    wrong_local: int
    wrong_remote: int
    beta: float

    @property
    def misclassified(self) -> int:
        return self.wrong_local + self.wrong_remote

    @property
    def accuracy(self) -> float:
        return 1.0 - self.misclassified / self.n

    @property
    def cost(self) -> float:
        return cost_closed_form(self.offloaded, self.wrong_local,
                                self.wrong_remote, self.beta)

    def cost_formula(self) -> str:
        return f"{self.offloaded}*beta + {self.misclassified}"

    def row(self) -> Dict[str, object]:
        return {
            "approach": self.approach,
            "offloaded": self.offloaded,
            "offloaded_pct": 100.0 * self.offloaded / self.n,
            "misclassified": self.misclassified,
            "accuracy_pct": 100.0 * self.accuracy,
            "cost": self.cost,
            "cost_formula": self.cost_formula(),
        }
