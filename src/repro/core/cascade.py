"""HICascade: the paper's Figure-1 pipeline as one composable JAX module.

    S-tier forward on every sample
      -> confidence (fused hi_gate kernel or jnp oracle)
      -> policy decision (offload iff conf < theta)
      -> static-capacity router gather
      -> L-tier forward on the complex batch
      -> scatter-merge

The whole thing is a single jit/pjit-able function; under a mesh the gather
IS the ED→ES offload link and its collective bytes are the paper's beta.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import HIConfig
from repro.core.confidence import confidence as _confidence
from repro.core import router as R

ApplyFn = Callable[[Any, jnp.ndarray], jnp.ndarray]   # (params, x) -> logits


@dataclass(frozen=True)
class HICascade:
    """S/L apply functions + the HI decision parameters."""

    s_apply: ApplyFn
    l_apply: ApplyFn
    hi: HIConfig
    use_kernel: bool = False

    def _confidence(self, s_logits: jnp.ndarray) -> jnp.ndarray:
        if self.use_kernel:
            from repro.kernels import ops as kops
            return kops.hi_gate(s_logits, self.hi.theta,
                                metric=self.hi.metric)[0]
        return _confidence(s_logits, self.hi.metric)

    def _decide(self, conf: jnp.ndarray) -> jnp.ndarray:
        if self.hi.binary_relevance:
            return conf >= self.hi.theta          # §5: positives are complex
        return conf < self.hi.theta               # §4: low confidence offloads

    def infer(self, s_params: Any, l_params: Any, x: jnp.ndarray
              ) -> Dict[str, jnp.ndarray]:
        """x: (N, ...) -> dict of predictions + offload accounting."""
        n = x.shape[0]
        cap = R.capacity_for(n, self.hi.capacity_factor)

        s_logits = self.s_apply(s_params, x)
        conf = self._confidence(s_logits)
        offload = self._decide(conf)
        decision = R.route(offload, conf, cap)

        x_complex = R.gather(x, decision)
        l_logits = self.l_apply(l_params, x_complex)

        s_pred = jnp.argmax(s_logits, axis=-1) if s_logits.shape[-1] > 1 \
            else (conf >= 0.5).astype(jnp.int32)
        l_pred = jnp.argmax(l_logits, axis=-1)
        pred = R.scatter_merge(s_pred, l_pred.astype(s_pred.dtype), decision)

        return {
            "pred": pred,
            "s_pred": s_pred,
            "conf": conf,
            "offload_mask": decision.offload_mask,
            "served_remote": decision.served_remote,
            "dropped": decision.dropped,
            "n_offloaded": jnp.sum(decision.offload_mask.astype(jnp.int32)),
        }

    def infer_jit(self) -> Callable:
        """Jitted :meth:`infer`, cached on the instance: repeated calls reuse
        one jit wrapper (and its executable cache) instead of rebuilding it —
        the same no-silent-retrace discipline as ``HIEngine._exec``."""
        fn = getattr(self, "_infer_jit", None)
        if fn is None:
            fn = jax.jit(self.infer)
            object.__setattr__(self, "_infer_jit", fn)   # frozen dataclass
        return fn


def classifier_cascade(s_apply: ApplyFn, l_apply: ApplyFn, hi: HIConfig,
                       use_kernel: bool = False) -> HICascade:
    return HICascade(s_apply=s_apply, l_apply=l_apply, hi=hi,
                     use_kernel=use_kernel)
