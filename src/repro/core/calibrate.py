"""Offline theta* calibration (paper §4: brute force over the validation set;
they find theta* = 0.607 for their CIFAR-10 S-ML)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def brute_force_theta(conf: np.ndarray, s_correct: np.ndarray,
                      beta: float, l_correct: Optional[np.ndarray] = None,
                      grid: Optional[np.ndarray] = None
                      ) -> Tuple[float, float]:
    """Minimise sum_i C_i(theta) over a grid.  Returns (theta*, min cost).

    conf (N,) in [0,1]; s_correct (N,) bool; l_correct (N,) bool or None
    (None = assume remote always right, eta=0).
    """
    conf = np.asarray(conf, np.float64)
    s_ok = np.asarray(s_correct, bool)
    eta = np.zeros_like(conf) if l_correct is None \
        else 1.0 - np.asarray(l_correct, np.float64)
    if grid is None:
        # candidate thresholds: every observed confidence (plus endpoints) —
        # the cost is piecewise-constant between observed values
        grid = np.unique(np.concatenate([[0.0], conf, [1.0 - 1e-9]]))
    # sort once, sweep cumulative sums
    order = np.argsort(conf)
    cs, es = conf[order], eta[order]
    gs = 1.0 - s_ok[order].astype(np.float64)
    # prefix sums of offloaded-part cost (beta + eta) and suffix of gamma
    pre_off = np.concatenate([[0.0], np.cumsum(beta + es)])
    suf_gam = np.concatenate([np.cumsum(gs[::-1])[::-1], [0.0]])
    idx = np.searchsorted(cs, grid, side="left")
    costs = pre_off[idx] + suf_gam[idx]
    j = int(np.argmin(costs))
    return float(grid[j]), float(costs[j])


def cost_curve(conf: np.ndarray, s_correct: np.ndarray, beta: float,
               l_correct: Optional[np.ndarray] = None,
               thetas: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
    """Total cost as a function of theta (Fig. 6-style analysis)."""
    if thetas is None:
        thetas = np.linspace(0, 1, 101)
    conf = np.asarray(conf)
    s_ok = np.asarray(s_correct, bool)
    eta = np.zeros(len(conf)) if l_correct is None \
        else 1.0 - np.asarray(l_correct, np.float64)
    costs = []
    for th in thetas:
        off = conf < th
        costs.append(np.sum(np.where(off, beta + eta, 1.0 - s_ok)))
    return {"theta": thetas, "cost": np.asarray(costs)}


def p_histogram(conf: np.ndarray, s_correct: np.ndarray, bins: int = 20
                ) -> Dict[str, np.ndarray]:
    """Correct/incorrect counts per confidence bin (paper Fig. 6)."""
    edges = np.linspace(0, 1, bins + 1)
    ok = np.asarray(s_correct, bool)
    h_ok, _ = np.histogram(conf[ok], bins=edges)
    h_bad, _ = np.histogram(conf[~ok], bins=edges)
    return {"edges": edges, "correct": h_ok, "incorrect": h_bad}
