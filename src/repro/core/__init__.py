"""The paper's primary contribution: Hierarchical Inference (HI).

confidence -> policy -> router -> cascade is the Figure-1 pipeline;
cost/calibrate/replay implement the paper's cost model and its published
tables; baselines implements the §6 comparison points.
"""
from repro.core.cascade import HICascade, classifier_cascade  # noqa: F401
from repro.core.confidence import confidence  # noqa: F401
from repro.core.cost import CostReport, cost_closed_form  # noqa: F401
from repro.core.policy import (AlwaysOffload, BinaryRelevancePolicy,  # noqa: F401
                               NeverOffload, OnlineThresholdPolicy,
                               ThresholdPolicy)
from repro.core.router import RouteDecision, route  # noqa: F401
