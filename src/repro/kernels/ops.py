"""Public jit'd wrappers around the Pallas kernels.

``interpret=True`` everywhere in this environment (CPU container; TPU is the
target).  On a real TPU deployment flip ``INTERPRET`` to False — kernels are
written against the TPU lowering (BlockSpec VMEM tiling, sequential last grid
dim, output revisiting).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import hi_gate as _hg
from repro.kernels import ssd_scan as _ssd

INTERPRET = True    # CPU container: validate kernel bodies via interpreter


@functools.partial(jax.jit, static_argnames=("metric",))
def hi_gate(logits: jnp.ndarray, theta, metric: str = "max_prob"
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused confidence + argmax + threshold.  logits: (N, C).

    ``theta`` is a TRACED operand (python float or fp32 scalar array): the
    serving engine's online policy moves it every batch, and a static theta
    would force a recompile per update.
    """
    return _hg.hi_gate_pallas(logits, theta, metric, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, valid: jnp.ndarray,
                     block_s: int = 512) -> jnp.ndarray:
    """Flash decode attention.  q: (B,1,H,D); cache: (B,S,K,D); valid: (S,)."""
    return _da.decode_attention_pallas(q, cache_k, cache_v, valid,
                                       block_s=block_s, interpret=INTERPRET)


@jax.jit
def decode_attention_paged(q: jnp.ndarray, pool_k: jnp.ndarray,
                           pool_v: jnp.ndarray, block: jnp.ndarray,
                           valid: jnp.ndarray,
                           scale_k: jnp.ndarray | None = None,
                           scale_v: jnp.ndarray | None = None) -> jnp.ndarray:
    """Flash decode attention over a PAGED KV pool.

    q: (B,1,H,D); pool_k/v: (P, page, K, D); block: (B, n_pages) int32 block
    table (scalar-prefetched — the kernel DMAs physical pages directly);
    valid: (B, n_pages * page) per-slot positional mask.  Passing
    ``scale_k/v`` (P, K) fp32 marks the pools int8-quantized: the page's
    per-head scale is DMA'd through the same block-table index_map and
    dequant happens in-register inside the kernel."""
    return _da.decode_attention_paged_pallas(q, pool_k, pool_v, block, valid,
                                             scale_k=scale_k, scale_v=scale_v,
                                             interpret=INTERPRET)


@jax.jit
def decode_attention_chunk_paged(q: jnp.ndarray, pool_k: jnp.ndarray,
                                 pool_v: jnp.ndarray, block: jnp.ndarray,
                                 valid: jnp.ndarray,
                                 scale_k: jnp.ndarray | None = None,
                                 scale_v: jnp.ndarray | None = None
                                 ) -> jnp.ndarray:
    """Flash CHUNK attention over a paged KV pool: C query tokens per slot at
    per-slot start positions in one streaming pass over the slot's pages.

    q: (B, C, H, D); pool_k/v: (P, page, K, D); block: (B, n_pages) int32
    (scalar-prefetched); valid: (B, C, n_pages * page) positional +
    intra-chunk causal mask.  ``scale_k/v`` (P, K) fp32 mark the pools
    int8-quantized with dequant fused into the page gather."""
    return _da.decode_attention_chunk_paged_pallas(q, pool_k, pool_v, block,
                                                   valid, scale_k=scale_k,
                                                   scale_v=scale_v,
                                                   interpret=INTERPRET)


@jax.jit
def copy_pages(pool: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray
               ) -> jnp.ndarray:
    """Copy-on-write page duplication: pool pages ``dst`` become copies of
    pages ``src`` (pairs padded with (0, 0) — null onto null).

    pool: (L, P, ...) of any dtype — the kernel's block shape and out_shape
    derive from the operand, so the same op moves (L, P, page, K, D) int8/bf16
    page pools AND their (L, P, K) fp32 per-page scale rows.  The (src, dst)
    pairs are expanded into a per-page source map so the kernel writes every
    output page exactly once (identity for non-COW pages) with the map
    scalar-prefetched — see ``decode_attention.copy_pages_pallas``."""
    p = pool.shape[1]
    src_of = jnp.arange(p, dtype=jnp.int32).at[dst].set(src)
    return _da.copy_pages_pallas(pool, src_of, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
        C: jnp.ndarray, chunk: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD: Pallas intra-chunk kernel + jnp inter-chunk recurrence.

    x: (b, l, h, p); dt: (b, l, h); A: (h,); B/C: (b, l, n).
    Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    orig_l = l
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        l += pad
    nc = l // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    y_diag, S, g, eacs = _ssd.ssd_chunk_pallas(xc, dtc, A, Bc, Cc,
                                               interpret=INTERPRET)

    # inter-chunk linear recurrence (tiny: nc steps over (b,h,p,n))
    def step(hprev, xs):
        g_c, S_c = xs
        return g_c[:, :, None, None] * hprev + S_c, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hT, h_prevs = jax.lax.scan(
        step, h0, (g.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (b, nc, h, p, n)

    y_off = jnp.einsum("bcih,bcin,bchpn->bcihp", eacs, Cc, h_prevs)
    y = (y_diag + y_off).reshape(b, l, h, p)[:, :orig_l]
    return y.astype(x.dtype), hT
