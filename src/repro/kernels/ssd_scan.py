"""ssd_scan — Mamba-2 SSD intra-chunk kernel (the quadratic hot spot).

The chunked SSD algorithm splits into:
  (a) intra-chunk: attention-like (Q x Q) compute per chunk — O(L*Q) FLOPs,
      the dominant term and the MXU-friendly part      -> THIS KERNEL
  (b) inter-chunk: linear recurrence over chunk states — O(L/Q) tiny scan
      -> stays in jnp (ops.py), it is bandwidth-trivial

Per grid step (batch b, chunk c, head-block hb) the kernel computes, entirely
in VMEM:
  y_diag  (Q, hb, P)  causal decay-masked intra-chunk output
  S       (hb, P, N)  end-of-chunk summary state (feeds the jnp scan)
  g       (hb,)       total chunk decay  exp(sum a)
  exp_acs (Q, hb)     exp(cumsum a) — reused for the inter-chunk y_off term

Tiling: Q (chunk) and the headblock are the VMEM tile knobs; Q=128 aligns the
(Q x Q) decay matmul with the 128x128 MXU.  The (Q, Q, hb) decay tensor this
kernel materialises per step is exactly the buffer the pure-jnp path would
materialise for the WHOLE sequence at once — the kernel bounds it to one tile.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            y_ref, s_ref, g_ref, eacs_ref):
    x = x_ref[0, 0].astype(jnp.float32)       # (Q, hb, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q, hb)
    A = a_ref[...].astype(jnp.float32)        # (hb,)
    B = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)       # (Q, N)
    q = x.shape[0]

    a = dt * A[None, :]                        # (Q, hb) log-decay
    a_cs = jnp.cumsum(a, axis=0)
    dx = x * dt[..., None]                     # (Q, hb, P)

    # causal decay mask  L[i, j] = exp(a_cs[i] - a_cs[j]), i >= j
    decay = jnp.exp(a_cs[:, None, :] - a_cs[None, :, :])      # (Q, Q, hb)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where((rows >= cols)[:, :, None], decay, 0.0)

    cb = C @ B.T                                               # (Q, Q)
    y_ref[0, 0] = jnp.einsum("ij,ijh,jhp->ihp", cb, decay, dx)

    decay_to_end = jnp.exp(a_cs[-1:, :] - a_cs)                # (Q, hb)
    s_ref[0, 0] = jnp.einsum("jh,jhp,jn->hpn", decay_to_end, dx, B)
    g_ref[0, 0] = jnp.exp(a_cs[-1])
    eacs_ref[0, 0] = jnp.exp(a_cs)


def ssd_chunk_pallas(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                     B: jnp.ndarray, C: jnp.ndarray, *, head_block: int = 0,
                     interpret: bool = True):
    """Intra-chunk SSD terms.

    x: (b, nc, Q, H, P); dt: (b, nc, Q, H); A: (H,); B/C: (b, nc, Q, N).
    Returns (y_diag (b,nc,Q,H,P), S (b,nc,H,P,N), g (b,nc,H),
             exp_acs (b,nc,Q,H))."""
    b, nc, q, h, p = x.shape
    n = B.shape[-1]
    hb = head_block or h
    while h % hb:
        hb -= 1
    nhb = h // hb

    grid = (b * nhb, nc)

    def im_x(i, c):
        return (i // nhb, c, 0, i % nhb, 0)

    def im_dt(i, c):
        return (i // nhb, c, 0, i % nhb)

    def im_a(i, c):
        return ((i % nhb),)

    def im_bc(i, c):
        return (i // nhb, c, 0, 0)

    def im_s(i, c):
        return (i // nhb, c, i % nhb, 0, 0)

    def im_g(i, c):
        return (i // nhb, c, i % nhb)

    y, S, g, eacs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, hb, p), im_x),
            pl.BlockSpec((1, 1, q, hb), im_dt),
            pl.BlockSpec((hb,), im_a),
            pl.BlockSpec((1, 1, q, n), im_bc),
            pl.BlockSpec((1, 1, q, n), im_bc),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, hb, p), im_x),
            pl.BlockSpec((1, 1, hb, p, n), im_s),
            pl.BlockSpec((1, 1, hb), im_g),
            pl.BlockSpec((1, 1, q, hb), im_dt),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, q, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, S, g, eacs
