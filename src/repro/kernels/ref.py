"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.confidence import confidence as _confidence
from repro.models import layers as L
from repro.models import mamba2


def hi_gate_ref(logits: jnp.ndarray, theta: float, metric: str = "max_prob"
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(N, C) -> (conf f32, pred i32, offload i32)."""
    conf = _confidence(logits, metric)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    offload = (conf < theta).astype(jnp.int32)
    return conf.astype(jnp.float32), pred, offload


def decode_attention_ref(q: jnp.ndarray, cache_k: jnp.ndarray,
                         cache_v: jnp.ndarray, valid: jnp.ndarray
                         ) -> jnp.ndarray:
    """q: (B,1,H,D); cache: (B,S,K,D); valid: (S,) -> (B,1,H,D)."""
    mask = valid[None, None, :]
    return L._sdpa(q, cache_k, cache_v, mask)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
            C: jnp.ndarray, chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Delegates to the model's chunked-jnp implementation."""
    return mamba2.ssd_chunked(x, dt, A, B, C, chunk)


def ssd_naive_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                  B: jnp.ndarray, C: jnp.ndarray) -> jnp.ndarray:
    """O(L^2)-free sequential recurrence — the ground-truth semantics:
        h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T;   y_t = C_t . h_t
    Used to validate ssd_chunked itself."""
    b, l, h, p = x.shape
    n = B.shape[-1]

    def step(hprev, inp):
        x_t, dt_t, B_t, C_t = inp
        y, hnew = mamba2.ssd_recurrent_step(hprev, x_t, dt_t, A, B_t, C_t)
        return hnew, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.transpose(1, 0, 2, 3),
                                    dt.transpose(1, 0, 2),
                                    B.transpose(1, 0, 2),
                                    C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3)
