"""decode_attention — flash-style single-token GQA attention over a long KV
cache (the decode_32k / long_500k hot spot).

One query token attends over S cached keys.  The kernel streams the cache
through VMEM in ``block_s`` tiles and keeps the online-softmax state
(running max m, normaliser l, accumulator acc) in revisited output blocks —
the grid's last dimension is sequential on TPU, which makes output revisiting
the canonical accumulation idiom (no scratch carry needed across grid steps).

Grid: (batch, kv_head, S // block_s).  Each step loads:
  q    (1, 1, G, D)   — the G query heads of this kv group   [VMEM]
  k/v  (1, block_s, 1, D)                                      [VMEM]
  mask (1, block_s)    — validity (pos, sliding window)        [VMEM]
so VMEM per step is ~2 * block_s * D * itemsize, independent of S — this is
what makes the 500k-token cache workable.

Numerical-safety choices: running max starts at -1e30 (finite, so the
`exp(m - m_new)` correction never sees inf-inf = NaN) and masked probability
mass is explicitly zeroed (a fully-masked tile keeps l = 0).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref, *,
            scale: float):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32)           # (bs, D)
    valid = mask_ref[0] > 0                          # (bs,)

    scores = (q @ k.T) * scale                        # (G, bs)
    scores = jnp.where(valid[None, :], scores, _NEG)

    m_prev = m_ref[0, 0]                              # (G,)
    l_prev = l_ref[0, 0]
    acc_prev = acc_ref[0, 0]                          # (G, D)

    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m_new[:, None]) * valid[None, :].astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)

    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[0, 0] = acc_prev * corr[:, None] + p @ v


def decode_attention_pallas(q: jnp.ndarray, cache_k: jnp.ndarray,
                            cache_v: jnp.ndarray, valid: jnp.ndarray,
                            *, block_s: int = 512, interpret: bool = True
                            ) -> jnp.ndarray:
    """q: (B, 1, H, D); cache_k/v: (B, S, K, D); valid: (S,) bool.

    Returns (B, 1, H, D) attention output (fp32 accumulation)."""
    b, _, h, d = q.shape
    s, kh = cache_k.shape[1], cache_k.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    # largest divisor of S not exceeding the requested tile; block_s == s
    # simply yields a single-step grid (nsb == 1)
    block_s = math.gcd(s, block_s)
    nsb = s // block_s
    mask = jnp.broadcast_to(valid.astype(jnp.int32)[None, :], (b, s))

    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(d))
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(b, kh, nsb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s), lambda bi, ki, si: (bi, si)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si: (bi, ki, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si: (bi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g), jnp.float32),
        ],
        interpret=interpret,
    )(qg, cache_k, cache_v, mask)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)
