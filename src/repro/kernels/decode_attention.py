"""decode_attention — flash-style single-token GQA attention over a long KV
cache (the decode_32k / long_500k hot spot).

One query token attends over S cached keys.  The kernel streams the cache
through VMEM in ``block_s`` tiles and keeps the online-softmax state
(running max m, normaliser l, accumulator acc) in revisited output blocks —
the grid's last dimension is sequential on TPU, which makes output revisiting
the canonical accumulation idiom (no scratch carry needed across grid steps).

Grid: (batch, kv_head, S // block_s).  Each step loads:
  q    (1, 1, G, D)   — the G query heads of this kv group   [VMEM]
  k/v  (1, block_s, 1, D)                                      [VMEM]
  mask (1, block_s)    — validity (pos, sliding window)        [VMEM]
so VMEM per step is ~2 * block_s * D * itemsize, independent of S — this is
what makes the 500k-token cache workable.

Numerical-safety choices: running max starts at -1e30 (finite, so the
`exp(m - m_new)` correction never sees inf-inf = NaN) and masked probability
mass is explicitly zeroed (a fully-masked tile keeps l = 0).

Quantized page pools (int8, per-page-per-head scales)
-----------------------------------------------------
The paged kernels optionally take the pool QUANTIZED: ``pool_k/v`` become
(P, page, K, Dh) int8 and a symmetric fp32 scale tensor ``scale_k/v`` of
shape (P, K) rides beside each pool (one scale per physical page per kv
head; dequantized value = ``int8 * scale[page, head]``).  The scale is a
fourth/fifth operand block-spec'd THROUGH THE SAME block-table index_map as
its pool — grid step (b, kv, j) DMAs the (1, 1) scale of physical page
``block[b, j]`` alongside the page itself — and dequantization happens
in-register inside ``_paged_kernel``/``_chunk_paged_kernel``, right before
the fp32 flash update.  HBM traffic per gathered page drops ~2x (int8 pages
+ 4 bytes/head of scale vs bf16 pages) with no extra pass and no
materialised dequantized copy.  ``scale_k=None`` (the default) traces
exactly the unquantized graph, so bf16 pools stay bitwise identical.
Write-side quantization (monotone per-page running-max scales) lives in
``models/layers.py``; the scale rows move with their pages under COW via
``copy_pages_pallas``, which is shape/dtype-generic over the pool operand.

Mesh-sharded serving (``scheduler.ContinuousScheduler(mesh=...)``)
------------------------------------------------------------------
Under ``shard_map`` over the ``data`` axis (the S tier's replica fan-out),
every kernel here sees PER-SHARD local shapes: B is the replica's slot
count, P the replica's own page pool, and the block table is replica-local
— nothing in the grid or the BlockSpecs changes, so the kernels compose
with the sharded tick for any ``data`` size.  The ``model`` axis is
different: GSPMD cannot partition a ``pallas_call`` body, so an L tier with
``model > 1`` must run the reference (non-kernel) gather — the scheduler
rejects ``use_kernel`` + ``model > 1`` up front, and ``_check_heads`` below
catches the symptom (a locally-narrower K pool meeting an unsharded q)
with a diagnosis instead of a silently wrong ``h // kh`` group size.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30

# Widest per-slot block-table row the paged kernels accept: the table is a
# SCALAR-PREFETCH operand (SMEM-resident on TPU), so a slot's row must fit
# the scalar-prefetch block.  KVPool refuses configurations past this at
# allocation time — a clear host-side error instead of a Pallas lowering
# failure deep inside the tick executable.
MAX_PREFETCH_PAGES = 2048


def _check_heads(h: int, kh: int) -> int:
    """GQA group size, with a mesh-aware diagnosis: a pool whose K dim was
    narrowed by a ``model``-axis partition while q kept all H heads shows up
    here as a non-dividing head count — fail loudly before the kernel
    computes with a wrong group size."""
    if h % kh:
        raise ValueError(
            f"query heads H={h} not divisible by pool kv heads K={kh}; if "
            "the page pool is model-axis sharded (mesh serving), the Pallas "
            "gather cannot be GSPMD-partitioned — run the L tier with "
            "use_kernel=False (the scheduler enforces this)")
    return h // kh


def _flash_update(q, k, v, valid, acc_ref, m_ref, l_ref, *, scale: float,
                  init: jnp.ndarray) -> None:
    """One online-softmax accumulation step shared by the contiguous and the
    paged kernel.  q: (G, D); k/v: (bs, D); valid: (bs,) bool."""

    @pl.when(init)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    scores = (q @ k.T) * scale                        # (G, bs)
    scores = jnp.where(valid[None, :], scores, _NEG)

    m_prev = m_ref[0, 0]                              # (G,)
    l_prev = l_ref[0, 0]
    acc_prev = acc_ref[0, 0]                          # (G, D)

    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m_new[:, None]) * valid[None, :].astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)

    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[0, 0] = acc_prev * corr[:, None] + p @ v


def _kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref, *,
            scale: float):
    _flash_update(q_ref[0, 0].astype(jnp.float32),
                  k_ref[0, :, 0].astype(jnp.float32),
                  v_ref[0, :, 0].astype(jnp.float32),
                  mask_ref[0] > 0,
                  acc_ref, m_ref, l_ref, scale=scale,
                  init=pl.program_id(2) == 0)


def decode_attention_pallas(q: jnp.ndarray, cache_k: jnp.ndarray,
                            cache_v: jnp.ndarray, valid: jnp.ndarray,
                            *, block_s: int = 512, interpret: bool = True
                            ) -> jnp.ndarray:
    """q: (B, 1, H, D); cache_k/v: (B, S, K, D); valid: (S,) bool.

    Returns (B, 1, H, D) attention output (fp32 accumulation)."""
    b, _, h, d = q.shape
    s, kh = cache_k.shape[1], cache_k.shape[2]
    g = _check_heads(h, kh)
    qg = q.reshape(b, kh, g, d)
    # largest divisor of S not exceeding the requested tile; block_s == s
    # simply yields a single-step grid (nsb == 1)
    block_s = math.gcd(s, block_s)
    nsb = s // block_s
    mask = jnp.broadcast_to(valid.astype(jnp.int32)[None, :], (b, s))

    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(d))
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(b, kh, nsb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s), lambda bi, ki, si: (bi, si)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si: (bi, ki, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si: (bi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g), jnp.float32),
        ],
        interpret=interpret,
    )(qg, cache_k, cache_v, mask)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged variant: gather K/V through an int32 block table
# ---------------------------------------------------------------------------
#
# Continuous batching stores the KV cache as ONE physical page pool shared by
# every slot; each slot's logical pages map to physical ones via a block
# table.  The kernel never materialises the gathered (B, S, K, D) cache: the
# block table is a SCALAR-PREFETCH operand (available before the body runs on
# TPU), so the K/V BlockSpec index_maps dereference it directly — grid step
# (b, kv, j) DMAs physical page ``block[b, j]`` into VMEM.  Everything else
# (online softmax, output revisiting over the sequential last grid dim) is the
# contiguous kernel's discipline, shared via ``_flash_update``.


def _paged_kernel(blk_ref, q_ref, k_ref, v_ref, mask_ref,
                  acc_ref, m_ref, l_ref, *, scale: float):
    del blk_ref      # consumed by the index_maps, not the body
    _flash_update(q_ref[0, 0].astype(jnp.float32),
                  k_ref[0, :, 0].astype(jnp.float32),
                  v_ref[0, :, 0].astype(jnp.float32),
                  mask_ref[0, 0] > 0,
                  acc_ref, m_ref, l_ref, scale=scale,
                  init=pl.program_id(2) == 0)


def _paged_kernel_quant(blk_ref, q_ref, k_ref, v_ref, sk_ref, sv_ref,
                        mask_ref, acc_ref, m_ref, l_ref, *, scale: float):
    """int8-pool variant: the page's (1, 1) per-head scale rides in via the
    same block-table index_map as the page; dequant is one in-register
    multiply before the shared fp32 flash update."""
    del blk_ref      # consumed by the index_maps, not the body
    _flash_update(q_ref[0, 0].astype(jnp.float32),
                  k_ref[0, :, 0].astype(jnp.float32) * sk_ref[0, 0],
                  v_ref[0, :, 0].astype(jnp.float32) * sv_ref[0, 0],
                  mask_ref[0, 0] > 0,
                  acc_ref, m_ref, l_ref, scale=scale,
                  init=pl.program_id(2) == 0)


def copy_pages_pallas(pool: jnp.ndarray, src_of: jnp.ndarray, *,
                      interpret: bool = True) -> jnp.ndarray:
    """Copy-on-write page duplication over a physical page pool.

    pool: (L, P, page, K, D); src_of: (P,) int32 per-page SOURCE map —
    identity everywhere except the COW destinations, which name the page
    they clone.  The map is a scalar-prefetch operand (the same idiom as the
    paged decode kernel's block table): grid step (l, p) DMAs physical page
    ``src_of[p]`` into VMEM and writes it back out as page ``p``, so the
    copy never round-trips through HBM-resident gather/scatter buffers and
    every page is written exactly once (identity pages stream through
    unchanged — no aliasing or output-revisiting hazards).

    Admission schedules at most one COW per admitted request, so on TPU the
    non-identity traffic is a handful of pages; the identity passthrough is
    the price of a single well-formed grid.  Returns the updated pool.
    """
    l, p = pool.shape[:2]
    blk = (1, 1) + pool.shape[2:]

    def kernel(src_ref, in_ref, out_ref):
        del src_ref          # consumed by the index_map, not the body
        out_ref[...] = in_ref[...]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(l, p),
        in_specs=[
            pl.BlockSpec(blk, lambda li, pi, src: (li, src[pi]) +
                         (0,) * (len(blk) - 2)),
        ],
        out_specs=pl.BlockSpec(blk, lambda li, pi, src: (li, pi) +
                               (0,) * (len(blk) - 2)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
    )(src_of, pool)


def _chunk_paged_kernel(blk_ref, q_ref, k_ref, v_ref, mask_ref,
                        acc_ref, m_ref, l_ref, *, scale: float):
    """Multi-token generalisation of ``_paged_kernel``: a query BLOCK of C
    tokens per slot (per-slot start positions are already folded into the
    validity mask, which carries the intra-chunk causal structure).  The
    online-softmax state gains a leading C axis; everything else — scalar-
    prefetched block table, page DMA via the index_map, output revisiting
    over the sequential last grid dim — is the single-token kernel's
    discipline."""
    del blk_ref      # consumed by the index_maps, not the body
    _chunk_flash_update(q_ref[0, 0].astype(jnp.float32),        # (C, G, D)
                        k_ref[0, :, 0].astype(jnp.float32),     # (page, D)
                        v_ref[0, :, 0].astype(jnp.float32),
                        mask_ref[0, :, 0] > 0,                  # (C, page)
                        acc_ref, m_ref, l_ref, scale=scale)


def _chunk_paged_kernel_quant(blk_ref, q_ref, k_ref, v_ref, sk_ref, sv_ref,
                              mask_ref, acc_ref, m_ref, l_ref, *,
                              scale: float):
    """int8-pool variant of ``_chunk_paged_kernel`` — same in-register
    per-page-per-head dequant as ``_paged_kernel_quant``."""
    del blk_ref      # consumed by the index_maps, not the body
    _chunk_flash_update(q_ref[0, 0].astype(jnp.float32),
                        k_ref[0, :, 0].astype(jnp.float32) * sk_ref[0, 0],
                        v_ref[0, :, 0].astype(jnp.float32) * sv_ref[0, 0],
                        mask_ref[0, :, 0] > 0,
                        acc_ref, m_ref, l_ref, scale=scale)


def _chunk_flash_update(q, k, v, valid, acc_ref, m_ref, l_ref, *,
                        scale: float) -> None:
    """Chunked online-softmax step: q (C, G, D); k/v (page, D); valid
    (C, page)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    scores = jnp.einsum("cgd,sd->cgs", q, k) * scale       # (C, G, page)
    scores = jnp.where(valid[:, None, :], scores, _NEG)

    m_prev = m_ref[0, 0]                          # (C, G)
    l_prev = l_ref[0, 0]
    acc_prev = acc_ref[0, 0]                      # (C, G, D)

    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m_new[..., None]) \
        * valid[:, None, :].astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)

    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[0, 0] = acc_prev * corr[..., None] + \
        jnp.einsum("cgs,sd->cgd", p, v)


def decode_attention_chunk_paged_pallas(q: jnp.ndarray, pool_k: jnp.ndarray,
                                        pool_v: jnp.ndarray,
                                        block: jnp.ndarray,
                                        valid: jnp.ndarray, *,
                                        scale_k: jnp.ndarray | None = None,
                                        scale_v: jnp.ndarray | None = None,
                                        interpret: bool = True) -> jnp.ndarray:
    """q: (B, C, H, D) — a chunk of C query tokens per slot; pool_k/v:
    (P, page, K, D); block: (B, n_pages) int32; valid: (B, C, n_pages * page)
    bool — per-slot positional AND intra-chunk causal mask (query i of slot b
    may attend key position s iff ``valid[b, i, s]``).

    One grid step DMAs physical page ``block[b, j]`` (scalar-prefetched) and
    accumulates it into all C queries' online-softmax states at once — the
    chunk costs ONE streaming pass over the slot's pages instead of C.
    ``scale_k/v`` (P, K) fp32 mark the pools int8-quantized (see module
    docstring); dequant fuses into the gather.  Returns (B, C, H, D)
    attention output (fp32 accumulation)."""
    b, c, h, d = q.shape
    page, kh = pool_k.shape[1], pool_k.shape[2]
    npg = block.shape[1]
    g = _check_heads(h, kh)
    qg = q.reshape(b, c, kh, g, d).transpose(0, 2, 1, 3, 4)  # (B, KH, C, G, D)
    mask = valid.astype(jnp.int32).reshape(b, c, npg, page)
    quant = scale_k is not None

    body = _chunk_paged_kernel_quant if quant else _chunk_paged_kernel
    kernel = functools.partial(body, scale=1.0 / math.sqrt(d))
    page_spec = pl.BlockSpec((1, page, 1, d),
                             lambda bi, ki, si, blk: (blk[bi, si], 0, ki, 0))
    scale_spec = pl.BlockSpec((1, 1),
                              lambda bi, ki, si, blk: (blk[bi, si], ki))
    in_specs = [
        pl.BlockSpec((1, 1, c, g, d),
                     lambda bi, ki, si, blk: (bi, ki, 0, 0, 0)),
        page_spec,
        page_spec,
        *([scale_spec, scale_spec] if quant else []),
        pl.BlockSpec((1, c, 1, page),
                     lambda bi, ki, si, blk: (bi, 0, si, 0)),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, npg),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, c, g, d),
                         lambda bi, ki, si, blk: (bi, ki, 0, 0, 0)),
            pl.BlockSpec((1, 1, c, g), lambda bi, ki, si, blk: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, c, g), lambda bi, ki, si, blk: (bi, ki, 0, 0)),
        ],
    )
    operands = (block, qg, pool_k, pool_v) + \
        ((scale_k, scale_v) if quant else ()) + (mask,)
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, c, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, c, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, c, g), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)

    out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B, KH, C, G, D)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, h, d).astype(q.dtype)


def decode_attention_paged_pallas(q: jnp.ndarray, pool_k: jnp.ndarray,
                                  pool_v: jnp.ndarray, block: jnp.ndarray,
                                  valid: jnp.ndarray, *,
                                  scale_k: jnp.ndarray | None = None,
                                  scale_v: jnp.ndarray | None = None,
                                  interpret: bool = True) -> jnp.ndarray:
    """q: (B, 1, H, D); pool_k/v: (P, page, K, D); block: (B, n_pages) int32;
    valid: (B, n_pages * page) bool (per-slot positional mask); scale_k/v
    (P, K) fp32 mark the pools int8-quantized (see module docstring) — the
    per-page-per-head scale rides the same block-table index_map and dequant
    fuses into the gather.

    Returns (B, 1, H, D) attention output (fp32 accumulation)."""
    b, _, h, d = q.shape
    page, kh = pool_k.shape[1], pool_k.shape[2]
    npg = block.shape[1]
    g = _check_heads(h, kh)
    qg = q.reshape(b, kh, g, d)
    mask = valid.astype(jnp.int32).reshape(b, npg, page)
    quant = scale_k is not None

    body = _paged_kernel_quant if quant else _paged_kernel
    kernel = functools.partial(body, scale=1.0 / math.sqrt(d))
    page_spec = pl.BlockSpec((1, page, 1, d),
                             lambda bi, ki, si, blk: (blk[bi, si], 0, ki, 0))
    scale_spec = pl.BlockSpec((1, 1),
                              lambda bi, ki, si, blk: (blk[bi, si], ki))
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda bi, ki, si, blk: (bi, ki, 0, 0)),
        page_spec,
        page_spec,
        *([scale_spec, scale_spec] if quant else []),
        pl.BlockSpec((1, 1, page), lambda bi, ki, si, blk: (bi, si, 0)),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, npg),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si, blk: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si, blk: (bi, ki, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si, blk: (bi, ki, 0)),
        ],
    )
    operands = (block, qg, pool_k, pool_v) + \
        ((scale_k, scale_v) if quant else ()) + (mask,)
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kh, g), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)
