"""hi_gate — fused HI decision module as a Pallas TPU kernel.

One VMEM pass over the S-tier logits computes softmax statistics, the
confidence metric (max-prob / margin / entropy), the argmax prediction and
the threshold decision.  On a TPU serving tier this fuses what would
otherwise be 4 HBM round-trips over the (batch, num_classes) logits into one.

Tiling: grid over row blocks; each block holds (block_n, C) logits in VMEM.
``block_n`` is chosen so the tile stays within the VMEM budget even for
262k-token vocabularies (gemma3).  C is never split: every confidence metric
is a full-row reduction, so splitting C would force cross-block softmax
renormalisation for no win — the row dimension provides all the parallelism
the VPU needs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM tile budget for the logits block (bytes); v5e VMEM is ~16 MiB, leave
# headroom for the fp32 softmax intermediates (~3x the tile).
_VMEM_TILE_BUDGET = 4 * 1024 * 1024


def _pick_block_n(n: int, c: int, itemsize: int) -> int:
    rows = max(1, _VMEM_TILE_BUDGET // max(1, c * itemsize))
    rows = min(rows, n, 1024)
    while n % rows:
        rows -= 1
    return max(rows, 1)


def _kernel(logits_ref, theta_ref, conf_ref, pred_ref, off_ref, *,
            metric: str):
    theta = theta_ref[0, 0]
    x = logits_ref[...].astype(jnp.float32)                    # (bn, C)
    c = x.shape[-1]
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    z = jnp.sum(ex, axis=-1, keepdims=True)
    pred = jnp.argmax(x, axis=-1).astype(jnp.int32)

    if metric == "max_prob":
        conf = (jnp.max(ex, axis=-1, keepdims=True) / z)[:, 0]
    elif metric == "margin":
        p = ex / z
        top1 = jnp.max(p, axis=-1)
        # second max: mask out the argmax column
        cols = jax.lax.broadcasted_iota(jnp.int32, p.shape, 1)
        p2 = jnp.where(cols == pred[:, None], -1.0, p)
        conf = top1 - jnp.max(p2, axis=-1)
    elif metric == "entropy":
        p = ex / z
        logp = (x - m) - jnp.log(z)
        h = -jnp.sum(p * logp, axis=-1)
        conf = 1.0 - h / jnp.log(float(c))
    else:
        raise ValueError(metric)

    conf_ref[...] = conf
    pred_ref[...] = pred
    off_ref[...] = (conf < theta).astype(jnp.int32)


def hi_gate_pallas(logits: jnp.ndarray, theta, metric: str = "max_prob",
                   interpret: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """logits: (N, C) -> (conf (N,) f32, pred (N,) i32, offload (N,) i32).

    ``theta`` may be a python float or a traced fp32 scalar — it enters the
    kernel as a (1, 1) operand (broadcast to every grid step), so online
    policies can move the threshold every batch without recompiling.
    """
    n, c = logits.shape
    bn = _pick_block_n(n, c, logits.dtype.itemsize)
    grid = (n // bn,)
    theta_arr = jnp.asarray(theta, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_kernel, metric=metric)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, c), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(logits, theta_arr)
