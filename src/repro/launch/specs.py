"""ShapeDtypeStruct input stand-ins + sharding assembly for every
(architecture x input shape x mesh) dry-run case.  Zero device allocation."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ENCDEC, VLM, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.models import model_zoo
from repro.optim import adamw
from repro.sharding import specs as sh

I32 = jnp.int32
BF16 = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """VLM: the sequence budget is patches + text."""
    if cfg.family == VLM and shape.mode != "decode":
        return shape.seq_len - cfg.num_patches
    return shape.seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs as ShapeDtypeStructs (same pattern for real batches)."""
    b = shape.global_batch
    tl = text_len(cfg, shape)
    if shape.mode == "decode":
        return {"token": sds((b, 1), I32)}
    batch: Dict[str, Any] = {"tokens": sds((b, tl), I32)}
    if shape.mode == "train":
        batch["labels"] = sds((b, tl), I32)
    if cfg.family == ENCDEC:
        batch["frames"] = sds((b, cfg.num_audio_frames, cfg.d_model), BF16)
    if cfg.family == VLM:
        batch["patches"] = sds((b, cfg.num_patches, cfg.d_model), BF16)
    return batch


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    bspecs = batch_specs(cfg, shape)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, sh.data_spec(mesh, s.shape[0],
                                                   len(s.shape))), bspecs)


def grad_accum_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Microbatch accumulation: keeps saved residuals bounded.  The
    microbatch size must stay divisible by the batch-sharding axes."""
    if shape.mode != "train":
        return 1
    batch_shards = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            batch_shards *= mesh.shape[a]
    # rough param count proxy: d_model^2 * layers (+ experts)
    big = cfg.d_model >= 7000
    accum = 16 if big else 8
    while shape.global_batch // accum < batch_shards and accum > 1:
        accum //= 2
    return accum


def _all_axes_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """ZeRO-DP: shard the batch over EVERY mesh axis when it divides."""
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if batch % total == 0:
        return P(axes, *(None,) * (ndim - 1))
    return sh.data_spec(mesh, batch, ndim)


def make_train_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    *, fsdp: bool = True, variant: str = "baseline"):
    """Returns (fn, arg_specs, in_shardings, out_shardings).

    Variants (the §Perf hillclimb knobs; see EXPERIMENTS.md):
      baseline  — 2D TP+FSDP layout, fp32 grad accumulation, full Adam.
      zero_dp   — ZeRO-style: batch shards over (data x model); weights stay
                  2D-sharded as storage and are gathered per layer.  Replaces
                  the per-layer Megatron activation all-reduces with weight
                  all-gathers: wins whenever params << activations x layers.
      ep_dp     — expert-parallel only: dense attention/MLP compute is data-
                  parallel (FSDP storage, no TP all-reduces); experts stay
                  `model`-sharded via the shard_map dispatch.  grad_accum=1.
      lean_opt  — Adafactor-style factored second moment + bf16 grad
                  accumulation (memory-bound configs, e.g. arctic-480b).
      zero_lean / ep_lean — combinations.
    """
    from repro.training import trainer

    zero = variant in ("zero_dp", "zero_lean")
    epdp = variant in ("ep_dp", "ep_lean")
    lean = variant in ("lean_opt", "zero_lean", "ep_lean")
    tcfg = TrainConfig(
        grad_accum=1 if (zero or epdp) else grad_accum_for(cfg, shape, mesh),
        bf16_state=True, remat=True,
        factored_v=lean, accum_dtype="bfloat16" if lean else "float32")
    params = model_zoo.init_params_spec(cfg, BF16)
    opt = jax.eval_shape(lambda p: adamw.init_state(p, tcfg), params)
    batch = batch_specs(cfg, shape)

    p_sh = sh.param_shardings(params, mesh, fsdp=fsdp, tp=not epdp)

    def v_sharding(vtree):
        is_vleaf = lambda x: isinstance(x, dict) and set(x) == {"vr", "vc"}
        return jax.tree.map(
            lambda v, psh: ({"vr": NamedSharding(mesh, P()),
                             "vc": NamedSharding(mesh, P())}
                            if isinstance(v, dict) else psh),
            vtree, p_sh, is_leaf=is_vleaf)

    o_sh = {
        "m": p_sh, "v": v_sharding(opt["v"]),
        "step": NamedSharding(mesh, P()),
    }
    if zero:
        b_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, _all_axes_spec(mesh, s.shape[0],
                                                         len(s.shape))),
            batch)
    else:
        b_sh = batch_shardings(cfg, shape, mesh)
    metric_sh = NamedSharding(mesh, P())

    fn = trainer.make_train_step(cfg, tcfg)
    in_shardings = (p_sh, o_sh, b_sh)
    out_shardings = (p_sh, o_sh,
                     {"loss": metric_sh, "nll": metric_sh, "aux": metric_sh,
                      "lr": metric_sh, "grad_norm": metric_sh})
    return fn, (params, opt, batch), in_shardings, out_shardings


def make_prefill_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, fsdp: Optional[bool] = None):
    """Prefill lowers the full forward (logit computation over the prompt)."""
    if fsdp is None:
        fsdp = serving_fsdp(cfg, mesh)
    params = model_zoo.init_params_spec(cfg, BF16)
    batch = batch_specs(cfg, shape)
    p_sh = sh.param_shardings(params, mesh, fsdp=fsdp)
    b_sh = batch_shardings(cfg, shape, mesh)
    batch_ok = shape.global_batch % _nbatch(mesh) == 0
    logits_sh = NamedSharding(
        mesh, P(sh.batch_axes(mesh) if batch_ok else None, None, None))

    def fn(params, batch):
        # production prefill: only the last position's logits are needed
        logits, _ = model_zoo.forward(params, cfg, batch, last_only=True)
        return logits

    return fn, (params, batch), (p_sh, b_sh), logits_sh


def serving_fsdp(cfg: ModelConfig, mesh: Mesh, threshold_gb: float = 8.0) -> bool:
    """Serving wants TP-only weights (no per-step FSDP all-gathers) unless the
    TP-sharded weights alone would blow the HBM budget (arctic-480b)."""
    import math
    params = model_zoo.init_params_spec(cfg, BF16)
    total_bytes = sum(2 * math.prod(p.shape)      # python ints: no overflow
                      for p in jax.tree.leaves(params))
    per_chip = total_bytes / mesh.shape["model"]
    return per_chip > threshold_gb * 1e9


def make_decode_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     *, fsdp: Optional[bool] = None):
    """serve_step: ONE new token against a seq_len KV cache."""
    if fsdp is None:
        fsdp = serving_fsdp(cfg, mesh)
    params = model_zoo.init_params_spec(cfg, BF16)
    cache = model_zoo.cache_spec(cfg, shape.global_batch, shape.seq_len)
    token = sds((shape.global_batch, 1), I32)

    p_sh = sh.param_shardings(params, mesh, fsdp=fsdp)
    c_specs = sh.cache_specs(cfg, mesh, shape)
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    batch_ok = shape.global_batch % _nbatch(mesh) == 0
    t_sh = NamedSharding(mesh, P(sh.batch_axes(mesh) if batch_ok else None,
                                 None))
    logits_sh = NamedSharding(mesh, P(sh.batch_axes(mesh) if batch_ok
                                      else None, None))

    def fn(params, token, cache):
        return model_zoo.decode_step(params, cfg, token, cache)

    return fn, (params, token, cache), (p_sh, t_sh, c_sh), (logits_sh, c_sh)


def make_split_decode_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """serve_step with ring-buffered local caches (sliding-window archs).

    The §Perf split-cache iteration: local layers keep only W positions, so
    both resident cache HBM and per-step cache reads drop by ~S/W on the
    local fraction of layers."""
    from repro.models import transformer

    if not cfg.sliding_window:
        raise ValueError("split cache needs a sliding-window arch")
    params = model_zoo.init_params_spec(cfg, BF16)
    cache = transformer.split_cache_spec(cfg, shape.global_batch,
                                         shape.seq_len)
    token = sds((shape.global_batch, 1), I32)

    fsdp = serving_fsdp(cfg, mesh)
    p_sh = sh.param_shardings(params, mesh, fsdp=fsdp)
    batch_ok = shape.global_batch % _nbatch(mesh) == 0
    baxes = sh.batch_axes(mesh) if batch_ok else None

    def cache_rule(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "pos":
            return P()
        if name.startswith("local"):        # (n_local, B, W, K, Dh)
            return P(None, baxes, None, None, None)
        # global stacks: sequence-parallel like the uniform cache
        s_dim = leaf.shape[2]
        if batch_ok:
            s_ax = "model" if s_dim % mesh.shape["model"] == 0 else None
            return P(None, baxes, s_ax, None, None)
        flat = tuple(a for a in ("data", "model") if a in mesh.shape)
        tot = 1
        for a in flat:
            tot *= mesh.shape[a]
        return P(None, None, flat if s_dim % tot == 0 else "data",
                 None, None)

    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        jax.tree_util.tree_map_with_path(cache_rule, cache))
    t_sh = NamedSharding(mesh, P(baxes, None))
    logits_sh = NamedSharding(mesh, P(baxes, None))

    def fn(params, token, cache):
        return transformer.decode_step_split(params, cfg, token, cache)

    return fn, (params, token, cache), (p_sh, t_sh, c_sh), (logits_sh, c_sh)


def make_hi_decode_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                        capacity_factor: float = 0.5, theta: float = 0.607,
                        s_scale: int = 4):
    """The paper's technique as ONE lowered program: HI cascade serve_step.

    S-tier (cfg.s_variant) decodes every request; the fused confidence gate +
    static-capacity router escalate the complex subset (capacity =
    capacity_factor x batch) to the L-tier (the full assigned config), whose
    KV cache covers exactly `capacity` concurrent complex streams.  The
    router gather IS the paper's ED->ES offload link — its collective bytes
    are the measured beta.
    """
    from repro.core import router as router_mod
    from repro.core.confidence import confidence as conf_fn

    s_cfg = cfg.s_variant(s_scale)
    b = shape.global_batch
    cap = router_mod.capacity_for(b, capacity_factor)
    # keep the complex sub-batch shardable over the batch axes
    nb = _nbatch(mesh)
    if b % nb == 0 and cap % nb:
        cap = max(nb, (cap // nb) * nb)

    s_params = model_zoo.init_params_spec(s_cfg, BF16)
    l_params = model_zoo.init_params_spec(cfg, BF16)
    s_cache = model_zoo.cache_spec(s_cfg, b, shape.seq_len)
    l_cache = model_zoo.cache_spec(cfg, cap, shape.seq_len)
    token = sds((b, 1), I32)

    fsdp_l = serving_fsdp(cfg, mesh)
    sp_sh = sh.param_shardings(s_params, mesh, fsdp=False)
    lp_sh = sh.param_shardings(l_params, mesh, fsdp=fsdp_l)
    sc_specs = sh.cache_specs(s_cfg, mesh, shape)
    import dataclasses as _dc
    cap_shape = _dc.replace(shape, global_batch=cap)
    lc_specs = sh.cache_specs(cfg, mesh, cap_shape)
    sc_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sc_specs)
    lc_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), lc_specs)
    batch_ok = b % nb == 0
    t_sh = NamedSharding(mesh, P(sh.batch_axes(mesh) if batch_ok else None,
                                 None))
    logits_sh = NamedSharding(mesh, P(sh.batch_axes(mesh) if batch_ok
                                      else None, None))

    def hi_serve_step(s_params, l_params, token, s_cache, l_cache):
        s_logits, s_cache = model_zoo.decode_step(s_params, s_cfg, token,
                                                  s_cache)
        conf = conf_fn(s_logits, "max_prob")
        offload = conf < theta
        decision = router_mod.route(offload, conf, cap)
        # the ED->ES link: gather the complex sub-batch
        l_token = token[decision.indices]
        l_logits, l_cache = model_zoo.decode_step(l_params, cfg, l_token,
                                                  l_cache)
        merged = router_mod.scatter_merge(s_logits, l_logits, decision)
        return merged, s_cache, l_cache, decision.served_remote

    args = (s_params, l_params, token, s_cache, l_cache)
    in_sh = (sp_sh, lp_sh, t_sh, sc_sh, lc_sh)
    out_sh = (logits_sh, sc_sh, lc_sh,
              NamedSharding(mesh, P(sh.batch_axes(mesh) if batch_ok
                                    else None)))
    return hi_serve_step, args, in_sh, out_sh


def _nbatch(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def make_case(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              variant: str = "baseline"):
    if shape.mode == "train":
        return make_train_case(cfg, shape, mesh, fsdp=True, variant=variant)
    if shape.mode == "prefill":
        return make_prefill_case(cfg, shape, mesh)
    if variant == "split_cache":
        return make_split_decode_case(cfg, shape, mesh)
    return make_decode_case(cfg, shape, mesh)


def donate_for(shape: ShapeConfig) -> tuple:
    """Donation: train aliases params+opt state; decode aliases the cache."""
    if shape.mode == "train":
        return (0, 1)
    if shape.mode == "decode":
        return (2,)
    return ()
