"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the ``pod``
axis doubles as the HI cascade's tier axis (DESIGN.md §2).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)}; "
            "run under launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (tests)."""
    return make_serving_mesh(data, model)


def make_serving_mesh(data: int = 1, model: int = 1) -> Mesh:
    """(data, model) mesh for the mesh-sharded serving scheduler.

    ``data`` is the S-tier replica count (each replica owns a disjoint slot
    slice + its own paged-pool shard); ``model`` is the L tier's tensor-
    parallel axis.  A (1, 1) mesh is the DEBUG configuration: the sharded
    tick runs on one device and must be token-identical to the unsharded
    path.  Validates the device count up front — ``jax.make_mesh`` with too
    few devices fails with an opaque reshape error.
    """
    need = data * model
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"serving mesh ({data}, {model}) needs {need} devices, have "
            f"{len(devices)}; on CPU force host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N before the "
            "first jax import (tests/conftest.py does this under "
            "REPRO_MULTI_DEVICE=1)")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:need])
