"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the ``pod``
axis doubles as the HI cascade's tier axis (DESIGN.md §2).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)}; "
            "run under launch/dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
