"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) case.

MUST run as its own process: the first two lines force 512 host platform
devices before jax initialises.  Never import this from tests/benches (they
need the real 1-device view).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""
import os

_FLAG = "--xla_force_host_platform_device_count=512"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " " + _FLAG

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import SHAPES                      # noqa: E402
from repro.configs.registry import ARCHS, shape_applicable  # noqa: E402
from repro.launch import hlo_analysis as ha                # noqa: E402
from repro.launch import specs as case_specs               # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402


def param_counts(cfg):
    """(total, active) parameter counts from the spec tree (no alloc)."""
    from repro.models import model_zoo
    tree = model_zoo.init_params_spec(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = expert = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if any(getattr(p, "key", None) == "experts" for p in path):
            expert += n
    active = total - expert
    if cfg.num_experts:
        active += expert * cfg.experts_per_token / cfg.num_experts
    return total, active


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, variant: str = "baseline",
             hi: bool = False, capacity_factor: float = 0.5) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.perf_counter()
    if hi:
        if not shape.is_decode:
            return {"arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi_pod else "single",
                    "status": "skipped",
                    "reason": "HI cascade case lowers serve_step only"}
        fn, args, in_sh, out_sh = case_specs.make_hi_decode_case(
            cfg, shape, mesh, capacity_factor=capacity_factor)
        donate = (3, 4)
    else:
        fn, args, in_sh, out_sh = case_specs.make_case(cfg, shape, mesh,
                                                       variant)
        donate = case_specs.donate_for(shape)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()

    # loop-aware accounting (cost_analysis counts while bodies ONCE — with
    # scan-over-layers that understates by ~num_layers x; see hlo_loop.py)
    from repro.launch import hlo_loop
    coll = {k: int(v) for k, v in
            hlo_loop.collective_bytes_loop_aware(hlo_text).items()}
    fc = hlo_loop.stablehlo_flops(lowered.as_text())

    total_p, active_p = param_counts(cfg)
    tokens = shape.global_batch * (1 if shape.is_decode else
                                   case_specs.text_len(cfg, shape))
    mf = ha.model_flops(active_p, tokens, shape.mode)

    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    roof = ha.Roofline(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        hlo_flops=fc.flops / chips,          # per-chip, loop-aware
        hlo_bytes=fc.dot_bytes / chips,      # per-chip dot-operand traffic
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=mf / chips, peak_memory_bytes=ha.parse_memory_analysis(mem))

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant, "hi": hi,
        "capacity_factor": capacity_factor if hi else None,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "total_params": total_p, "active_params": active_p,
        "memory": {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            "peak_gb_per_device": roof.peak_memory_bytes / 1e9,
        },
        "cost": {"loop_aware_flops_per_chip": roof.hlo_flops,
                 "loop_aware_dot_bytes_per_chip": roof.hlo_bytes,
                 "raw_cost_analysis_flops": raw_flops,
                 "raw_cost_analysis_bytes": raw_bytes},
        "collectives": coll,
        "roofline": roof.row(),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {result['mesh']}] OK "
              f"compile={t_compile:.0f}s "
              f"peak={result['memory']['peak_gb_per_device']:.2f}GB/dev "
              f"dominant={roof.dominant} "
              f"(c={roof.compute_s:.4f}s m={roof.memory_s:.4f}s "
              f"coll={roof.collective_s:.4f}s)")
        print("  memory_analysis:", {k: f"{v:.2f}GB"
                                     for k, v in result["memory"].items()})
        print("  per-chip loop-aware: flops=%.3e dot_bytes=%.3e "
              "(raw cost_analysis: %.3e / %.3e)"
              % (roof.hlo_flops, roof.hlo_bytes, raw_flops, raw_bytes))
        print("  collectives:", {k: f"{v/1e9:.2f}GB" for k, v in coll.items()
                                 if v})
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "zero_dp", "ep_dp", "lean_opt", "zero_lean", "ep_lean", "split_cache"])
    ap.add_argument("--hi", action="store_true",
                    help="lower the HI cascade serve_step (decode shapes)")
    ap.add_argument("--capacity-factor", type=float, default=0.5)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_case(
                        arch, shape, multi_pod=mp, variant=args.variant,
                        hi=args.hi, capacity_factor=args.capacity_factor))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": "error", "error": repr(e)})
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", args.out)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
