"""Tier-split deployment: S-tier and L-tier on SEPARATE pods.

The single-program HI case (`make_hi_decode_case`) runs both tiers over one
mesh — the right plan when ED and ES share a fabric.  The paper's actual
topology is two *systems* joined by a narrow link; on a 2-pod machine that
maps to: S-tier owns pod 0's 256 chips, L-tier owns pod 1's, and the only
inter-pod traffic is the escalated token batch + returned logits (the DCN
offload link, measured below as beta_bytes).

Implemented as two separately-lowered programs on disjoint sub-meshes (the
realistic serving architecture — the ES is its own binary); the host-side
router glues them, exactly like the HIEngine does on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import router as router_mod
from repro.launch import specs as case_specs
from repro.models import model_zoo
from repro.sharding import specs as sh


def make_tier_meshes(shape: Optional[Tuple[int, int]] = None
                     ) -> Tuple[Mesh, Mesh]:
    """Two disjoint (data, model) meshes of ``shape`` each, split from the
    front of ``jax.devices()`` — S tier first, L tier second.

    ``shape=None`` keeps the historical default: two 16x16 pods from the
    512-device dry-run env.  Any smaller shape (e.g. ``(2, 2)`` on an
    8-forced-device CPU host) splits whatever devices exist, so the split is
    exercisable in plain-CPU tests without the dry-run harness.
    """
    shape = (16, 16) if shape is None else tuple(shape)
    per = shape[0] * shape[1]
    devs = jax.devices()
    if len(devs) < 2 * per:
        raise RuntimeError(
            f"tier split needs {2 * per} devices for two {shape} meshes, "
            f"have {len(devs)} (the 512-device dry-run env provides the "
            f"default 2x(16,16))")
    s_devs = np.asarray(devs[:per]).reshape(shape)
    l_devs = np.asarray(devs[per:2 * per]).reshape(shape)
    return (Mesh(s_devs, ("data", "model")), Mesh(l_devs, ("data", "model")))


@dataclass
class TierSplitReport:
    s_compile: Dict[str, Any]
    l_compile: Dict[str, Any]
    beta_bytes_per_step: int          # escalation link traffic


def lower_tier_split(cfg: ModelConfig, shape: ShapeConfig, *,
                     capacity_factor: float = 0.5, s_scale: int = 4
                     ) -> TierSplitReport:
    """Lower + compile serve_step for each tier on its own pod; report the
    inter-pod escalation bytes (the paper's beta, now a DCN transfer)."""
    mesh_s, mesh_l = make_tier_meshes()
    s_cfg = cfg.s_variant(s_scale)
    b = shape.global_batch
    cap = router_mod.capacity_for(b, capacity_factor)
    cap = max(16, (cap // 16) * 16)      # keep shardable on the L mesh

    reports = {}
    for name, mesh, mcfg, batch in (("s", mesh_s, s_cfg, b),
                                    ("l", mesh_l, cfg, cap)):
        params = model_zoo.init_params_spec(mcfg)
        cache = model_zoo.cache_spec(mcfg, batch, shape.seq_len)
        token = jax.ShapeDtypeStruct((batch, 1), "int32")
        fsdp = case_specs.serving_fsdp(mcfg, mesh)
        p_sh = sh.param_shardings(params, mesh, fsdp=fsdp)
        c_specs = sh.cache_specs(mcfg, mesh,
                                 dataclasses.replace(shape,
                                                     global_batch=batch))
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
        t_sh = NamedSharding(mesh, P("data" if batch % 16 == 0 else None,
                                     None))

        def step(params, token, cache, _mcfg=mcfg):
            return model_zoo.decode_step(params, _mcfg, token, cache)

        with mesh:
            compiled = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh),
                               out_shardings=(t_sh, c_sh),
                               donate_argnums=(2,)).lower(
                params, token, cache).compile()
        mem = compiled.memory_analysis()
        reports[name] = {
            "chips": mesh.size,
            "peak_gb_per_device":
                (getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)) / 1e9,
        }

    # the DCN link: escalated tokens out (cap x 1 int32) + logits back
    # (cap x vocab fp32) per step
    beta = cap * 4 + cap * cfg.vocab_size * 4
    return TierSplitReport(reports["s"], reports["l"], beta)
