"""Training driver.

On real hardware this launches the pjit train step over the production mesh;
on this CPU container it runs reduced configs end-to-end (the same code path,
1-device mesh) — used by examples/quickstart.py and the integration tests.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS
from repro.data import tokens as token_data
from repro.models import model_zoo
from repro.optim import adamw
from repro.training import trainer
from repro.checkpoint import io as ckpt_io


def run(arch: str, *, reduced: bool = True, steps: int = 50, batch: int = 8,
        seq: int = 64, lr: float = 1e-3, grad_accum: int = 1,
        ckpt_dir: str = "", log_every: int = 10, seed: int = 0):
    cfg = ARCHS[arch]
    if reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(lr=lr, total_steps=steps, warmup_steps=max(1, steps // 10),
                       grad_accum=grad_accum, bf16_state=False, remat=False)
    rng = jax.random.PRNGKey(seed)
    params = model_zoo.init_params(rng, cfg)
    opt = adamw.init_state(params, tcfg)
    step_fn = jax.jit(trainer.make_train_step(cfg, tcfg))

    losses = []
    t0 = time.time()
    for i, batch_np in enumerate(token_data.lm_batches(cfg.vocab_size, batch,
                                                       seq, steps, seed)):
        b = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.family == "encdec":
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (batch, cfg.num_audio_frames,
                                        cfg.d_model))
        if cfg.family == "vlm":
            b["patches"] = jax.random.normal(
                jax.random.PRNGKey(i), (batch, cfg.num_patches, cfg.d_model))
        params, opt, metrics = step_fn(params, opt, b)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
    dt = time.time() - t0
    print(f"trained {steps} steps in {dt:.1f}s "
          f"({steps * batch * seq / dt:.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if ckpt_dir:
        ckpt_io.save(ckpt_dir, params, step=steps)
        print("saved checkpoint to", ckpt_dir)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    run(args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, grad_accum=args.grad_accum,
        ckpt_dir=args.ckpt)


if __name__ == "__main__":
    main()
