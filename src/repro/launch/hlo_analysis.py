"""Extract roofline terms from a lowered/compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * peak_FLOPs)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

cost_analysis() provides flops/bytes; collective bytes are parsed from the
HLO text by summing operand sizes of all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute ops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind.

    HLO line shape: ``%name = TYPE opcode(T1 %a, T2 %b), ...`` — we take the
    result-type sizes (for all-gather the gathered result, for all-reduce the
    reduced tensor), which upper-bounds the per-op wire traffic within 2x and
    is uniform across op kinds.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(%?[\w\.\-]+)\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(2)
        opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                        r"collective-permute)(-start|-done)?\(", rhs)
        if not opm:
            continue
        if opm.group(2) == "-done":   # avoid double counting start/done pairs
            continue
        kind = opm.group(1)
        # result types appear before the opcode
        head = rhs[: opm.start()]
        size = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        out[kind] += size
    return out


@dataclass
class Roofline:
    """All byte/FLOP quantities are PER-CHIP (the HLO after SPMD partitioning
    is the per-device program; global analytic counts get divided by chips
    before they land here)."""
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float = 0.0
    peak_memory_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flops_ratio,
            "peak_mem_gb": self.peak_memory_bytes / 1e9,
        }


def model_flops(n_params_active: float, tokens: float, mode: str) -> float:
    """6ND for training, 2ND for inference forward."""
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_params_active * tokens


def parse_memory_analysis(mem) -> float:
    """compiled.memory_analysis() -> peak bytes (best-effort across versions)."""
    for attr in ("temp_size_in_bytes",):
        if hasattr(mem, attr):
            temp = getattr(mem, attr)
            args = getattr(mem, "argument_size_in_bytes", 0)
            out = getattr(mem, "output_size_in_bytes", 0)
            alias = getattr(mem, "alias_size_in_bytes", 0)
            return float(temp + args + out - alias)
    return 0.0
