"""Loop-aware HLO/StableHLO analysis.

XLA's ``compiled.cost_analysis()`` visits every instruction ONCE — a
scan-over-layers while loop is counted as a single iteration, which would
understate this codebase's rooflines by ~num_layers x.  Two analyzers fix
this:

* :func:`stablehlo_flops` — parses ``lowered.as_text()`` (types are inline in
  MLIR), walks ``stablehlo.while`` regions by brace matching, extracts trip
  counts from the loop condition's compare-against-constant, and sums
  dot_general / convolution FLOPs x the product of enclosing trip counts.
  This is the *global* (unpartitioned) FLOP count: divide by chip count for
  per-chip work.  Also returns a bytes-touched estimate (dot operand/result
  sizes, an unfused upper bound on HBM traffic for matmul-dominated graphs).

* :func:`collective_bytes_loop_aware` — parses the *partitioned* optimized
  HLO (``compiled.as_text()``), builds the computation call graph of while
  bodies, extracts trip counts from condition computations, and sums
  collective result bytes x trip multiplier.  These shapes are per-device,
  i.e. exactly the wire bytes each chip moves.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "i1": 1, "s8": 1, "u8": 1, "i8": 1, "s16": 2, "u16": 2,
    "i16": 2, "s32": 4, "u32": 4, "i32": 4, "s64": 8, "u64": 8, "i64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
}


# ---------------------------------------------------------------------------
# StableHLO (lowered, unpartitioned): FLOPs + dot bytes, loop-aware
# ---------------------------------------------------------------------------

_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z0-9]+)>")


def _tensor_numel_bytes(txt: str) -> Tuple[int, int]:
    m = _TENSOR_RE.search(txt)
    if not m:
        return 0, 0
    dims, dt = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split("x"):
            if d:
                n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 4)


def _tensor_dims(txt: str) -> List[int]:
    m = _TENSOR_RE.search(txt)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split("x") if d]


@dataclass
class FlopCount:
    flops: float = 0.0
    dot_bytes: float = 0.0


def _dot_flops(line: str) -> Tuple[float, float]:
    """FLOPs + operand/result bytes of one stablehlo.dot_general line."""
    # type signature at the end: ... : (tensor<...>, tensor<...>) -> tensor<...>
    sig = re.search(r":\s*\(\s*(tensor<[^>]+>)\s*,\s*(tensor<[^>]+>)\s*\)\s*->\s*(tensor<[^>]+>)", line)
    if not sig:
        return 0.0, 0.0
    lhs_t, rhs_t, out_t = sig.group(1), sig.group(2), sig.group(3)
    lhs_dims = _tensor_dims(lhs_t)
    cd = re.search(r"contracting_dims\s*=\s*\[([0-9, ]*)\]", line)
    k = 1
    if cd and cd.group(1).strip():
        for d in cd.group(1).split(","):
            k *= lhs_dims[int(d)]
    out_n, out_b = _tensor_numel_bytes(out_t)
    _, lhs_b = _tensor_numel_bytes(lhs_t)
    _, rhs_b = _tensor_numel_bytes(rhs_t)
    return 2.0 * out_n * k, float(lhs_b + rhs_b + out_b)


def _conv_flops(line: str) -> Tuple[float, float]:
    sig = re.search(r":\s*\(\s*(tensor<[^>]+>)\s*,\s*(tensor<[^>]+>)\s*\)\s*->\s*(tensor<[^>]+>)", line)
    if not sig:
        return 0.0, 0.0
    w_dims = _tensor_dims(sig.group(2))
    out_n, out_b = _tensor_numel_bytes(sig.group(3))
    _, lhs_b = _tensor_numel_bytes(sig.group(1))
    _, rhs_b = _tensor_numel_bytes(sig.group(2))
    # HWIO filter: flops = 2 * out_numel * (H*W*I)
    k = 1
    for d in w_dims[:-1]:
        k *= d
    return 2.0 * out_n * k, float(lhs_b + rhs_b + out_b)


def _region_trip_count(cond_text: str) -> int:
    """Trip count of a stablehlo.while from its cond region: the largest
    integer constant compared against the induction variable."""
    consts = [int(x) for x in re.findall(r"dense<(\d+)>\s*:\s*tensor<i(?:32|64)>",
                                         cond_text)]
    return max(consts) if consts else 1


def _split_functions(text: str) -> Dict[str, List[str]]:
    """MLIR module -> {func_name: body lines} via brace counting."""
    funcs: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    depth = 0
    for line in text.splitlines():
        if cur is None:
            m = re.search(r"func\.func\s+(?:public\s+|private\s+)?@([\w\.\-]+)",
                          line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                funcs[cur] = []
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            cur = None
            continue
        funcs[cur].append(line)
    return funcs


def _analyze_function(lines: List[str]):
    """Walk one function body tracking while cond/do regions.

    Returns (flops, dot_bytes, call_edges {callee: total multiplier}) where
    multipliers are the product of enclosing while trip counts."""
    flops = 0.0
    dot_bytes = 0.0
    edges: Dict[str, float] = {}
    # region stack: dicts {kind: 'while-cond'|'while-do'|'other', trip, buf}
    stack: List[dict] = []

    def cur_mult() -> float:
        m = 1.0
        for f in stack:
            if f["kind"] == "while-do":
                m *= f["trip"]
        return m

    for line in lines:
        s = line.strip()
        # region transitions -------------------------------------------------
        if s == "cond {" or s.endswith(" cond {"):
            stack.append({"kind": "while-cond", "trip": 1, "buf": []})
            continue
        if stack and stack[-1]["kind"] == "while-cond":
            if re.match(r"^\}\s*do\s*\{", s):
                trip = _region_trip_count("\n".join(stack[-1]["buf"]))
                stack[-1] = {"kind": "while-do", "trip": trip, "buf": []}
                continue
            stack[-1]["buf"].append(line)
            continue
        opens = s.count("{")
        closes = s.count("}")
        if closes > opens and stack:
            for _ in range(closes - opens):
                if stack:
                    stack.pop()
            continue
        if opens > closes:
            for _ in range(opens - closes):
                stack.append({"kind": "other", "trip": 1, "buf": []})
            # fall through: the line may also contain an op

        # ops ------------------------------------------------------------------
        if "stablehlo.dot_general" in s:
            f, b = _dot_flops(s)
            flops += f * cur_mult()
            dot_bytes += b * cur_mult()
        elif "stablehlo.convolution" in s:
            f, b = _conv_flops(s)
            flops += f * cur_mult()
            dot_bytes += b * cur_mult()
        m = re.search(r"(?:func\.call|call)\s+@([\w\.\-]+)", s)
        if m:
            edges[m.group(1)] = edges.get(m.group(1), 0.0) + cur_mult()
    return flops, dot_bytes, edges


def stablehlo_flops(text: str) -> FlopCount:
    """Loop-aware FLOP/byte count over a StableHLO module text (global, i.e.
    pre-partitioning: divide by chips for per-device)."""
    funcs = _split_functions(text)
    local: Dict[str, Tuple[float, float, Dict[str, float]]] = {
        name: _analyze_function(lines) for name, lines in funcs.items()}

    mult: Dict[str, float] = {name: 0.0 for name in funcs}
    if "main" in mult:
        mult["main"] = 1.0
    else:   # fallback: any function never called
        called = {c for _, (_, _, e) in local.items() for c in e}
        for name in funcs:
            if name not in called:
                mult[name] = 1.0

    # propagate through the (acyclic) call graph to fixed point
    for _ in range(len(funcs) + 2):
        # accumulate across distinct callers: full recompute pass each round
        new_mult = {name: 0.0 for name in funcs}
        if "main" in new_mult:
            new_mult["main"] = 1.0
        else:
            called = {c for _, (_, _, e) in local.items() for c in e}
            for name in funcs:
                if name not in called:
                    new_mult[name] = 1.0
        for name, (_, _, edges) in local.items():
            for callee, w in edges.items():
                if callee in new_mult:
                    new_mult[callee] += mult[name] * w
        if new_mult == mult:
            break
        mult = new_mult

    total = FlopCount()
    for name, (f, b, _) in local.items():
        total.flops += f * mult.get(name, 0.0)
        total.dot_bytes += b * mult.get(name, 0.0)
    return total


# ---------------------------------------------------------------------------
# Optimized (partitioned) HLO: loop-aware collective bytes
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_HLO_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# header like `%name (params...) -> type {` — param tuple types nest parens,
# so only anchor on the name + opening paren and the trailing `{`.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")


def _hlo_shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{") and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _line_collective(line: str) -> Optional[Tuple[str, int]]:
    s = line.strip()
    m = re.match(r"^(%?[\w\.\-]+)\s*=\s*(.*)$", s)
    if not m:
        return None
    rhs = m.group(2)
    opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                    r"collective-permute)(-start|-done)?\(", rhs)
    if not opm or opm.group(2) == "-done":
        return None
    head = rhs[: opm.start()]
    size = sum(_hlo_shape_bytes(d, dd) for d, dd in _HLO_SHAPE_RE.findall(head))
    return opm.group(1), size


def _comp_trip_count(comp_lines: List[str]) -> int:
    consts = []
    for line in comp_lines:
        for m in re.finditer(r"\bconstant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def collective_bytes_loop_aware(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)

    # while ops: find (body, condition) computation names per computation
    calls: Dict[str, List[Tuple[str, str]]] = {c: [] for c in comps}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if bm and cm:
                    calls[cname].append((bm.group(1), cm.group(1)))
            # fusion/call computations execute inline with multiplier 1 —
            # their collectives are hoisted to the caller in optimized HLO,
            # so we don't recurse into calls here.

    # multipliers: start from entry (the computation named like 'main' or the
    # one not referenced as body/cond/fusion), propagate through while bodies
    referenced = set()
    for cname, lst in calls.items():
        for b, c in lst:
            referenced.add(b)
            referenced.add(c)
    entry_candidates = [c for c in comps
                        if c not in referenced and ("main" in c or "entry" in c
                                                    or c.endswith(".0"))]
    entries = entry_candidates or [c for c in comps if c not in referenced]

    mult: Dict[str, float] = {c: 0.0 for c in comps}
    for e in entries:
        mult[e] = max(mult.get(e, 0.0), 1.0)

    # BFS through while nesting
    frontier = list(entries)
    seen = set(frontier)
    while frontier:
        cname = frontier.pop()
        for body, cond in calls.get(cname, []):
            trip = _comp_trip_count(comps.get(cond, []))
            m = mult[cname] * trip
            if m > mult.get(body, 0.0):
                mult[body] = m
                if body in seen:
                    frontier.append(body)
            if body not in seen:
                seen.add(body)
                frontier.append(body)
            mult[cond] = max(mult.get(cond, 0.0), mult[cname] * trip)

    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0) or 1.0
        for line in lines:
            lc = _line_collective(line)
            if lc:
                out[lc[0]] += lc[1] * m
    return out
