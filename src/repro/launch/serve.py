"""Serving driver: batched requests through the HI cascade.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --requests 32 --batch 8 --theta 0.6

``--no-reduced`` runs the full assigned config (TPU-sized; not CPU-friendly).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.batcher import Batcher, Request
from repro.serving.engine import build_engine


def run(arch: str, *, reduced: bool = True, requests: int = 32, batch: int = 8,
        theta: float = 0.6, capacity_factor: float = 0.5, seed: int = 0,
        max_new_tokens: int = 8, metric: str = "max_prob",
        legacy: bool = False):
    cfg = ARCHS[arch]
    if reduced:
        cfg = cfg.reduced()
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit(f"serve driver covers decoder-only text families; "
                         f"{cfg.family} is exercised via dryrun + smoke tests")
    hi = HIConfig(theta=theta, capacity_factor=capacity_factor, metric=metric)
    engine = build_engine(cfg, hi, max_new_tokens=max_new_tokens, cache_len=64)
    serve = engine.serve_legacy if legacy else engine.serve

    rng = np.random.default_rng(seed)
    batcher = Batcher(batch_size=batch, buckets=(16, 32))
    for i in range(requests):
        plen = int(rng.integers(4, 16))
        batcher.submit(Request(i, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32)))

    t0 = time.time()
    served = 0
    while batcher.queue:
        b = batcher.next_batch()
        out = serve(b.tokens)
        served += int((b.request_ids >= 0).sum())
        print(f"batch: offloaded {int(out['offloaded'].sum())}/{len(b.tokens)} "
              f"mean_conf {out['confidence'].mean():.3f}")
    dt = time.time() - t0
    s = engine.summary()
    print(f"served {served} requests in {dt:.1f}s "
          f"({served / max(dt, 1e-9):.1f} req/s) | offload_frac "
          f"{s['offload_frac']:.2%} drop_frac {s['drop_frac']:.2%} | "
          f"cascade time {s['serve_time']:.2f}s, {int(s['compiles'])} "
          f"compiled shapes")
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-scale config (disable with --no-reduced)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.6)
    ap.add_argument("--capacity-factor", type=float, default=0.5)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--metric", default="max_prob",
                    choices=["max_prob", "margin", "entropy"])
    ap.add_argument("--legacy", action="store_true",
                    help="use the pre-batched-prefill reference path")
    args = ap.parse_args()
    run(args.arch, reduced=args.reduced, requests=args.requests,
        batch=args.batch, theta=args.theta,
        capacity_factor=args.capacity_factor,
        max_new_tokens=args.max_new_tokens, metric=args.metric,
        legacy=args.legacy)


if __name__ == "__main__":
    main()
