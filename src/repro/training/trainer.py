"""Training step: loss -> grads -> AdamW, with microbatch gradient
accumulation expressed as a ``lax.scan`` (keeps both HLO size and saved
activations bounded — see DESIGN.md §5)."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model_zoo
from repro.optim import adamw

Params = Any
Batch = Dict[str, jnp.ndarray]


def _split_microbatches(batch: Batch, n: int) -> Batch:
    """(B, ...) -> (n, B//n, ...)."""
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by grad_accum {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def grads_and_metrics(params: Params, mcfg: ModelConfig, tcfg: TrainConfig,
                      batch: Batch) -> Tuple[Params, Dict[str, jnp.ndarray]]:
    loss_fn = lambda p, b: model_zoo.loss(p, mcfg, b, remat=tcfg.remat)
    if tcfg.grad_accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return grads, dict(metrics, loss=loss)

    micro = _split_microbatches(batch, tcfg.grad_accum)

    acc_dt = jnp.dtype(tcfg.accum_dtype)

    def body(carry, mb):
        acc, _ = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(acc_dt), acc, grads)
        return (acc, loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (gsum, last_loss), metrics = lax.scan(body, (zeros, jnp.zeros(())), micro)
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / tcfg.grad_accum
                                    ).astype(acc_dt), gsum)
    metrics = jax.tree.map(jnp.mean, metrics)
    return grads, dict(metrics, loss=last_loss)


def train_step(params: Params, opt_state: Dict[str, Any], batch: Batch, *,
               mcfg: ModelConfig, tcfg: TrainConfig
               ) -> Tuple[Params, Dict[str, Any], Dict[str, jnp.ndarray]]:
    grads, metrics = grads_and_metrics(params, mcfg, tcfg, batch)
    params, opt_state, opt_metrics = adamw.apply_updates(params, grads,
                                                         opt_state, tcfg)
    return params, opt_state, {**metrics, **opt_metrics}


def make_train_step(mcfg: ModelConfig, tcfg: TrainConfig):
    return partial(train_step, mcfg=mcfg, tcfg=tcfg)


def eval_step(params: Params, batch: Batch, *, mcfg: ModelConfig
              ) -> Dict[str, jnp.ndarray]:
    logits, _ = model_zoo.forward(params, mcfg, batch)
    if mcfg.family == "vlm":
        logits = logits[:, -batch["labels"].shape[1]:, :]
    nll = model_zoo.cross_entropy(logits, batch["labels"])
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.mean((pred == batch["labels"]).astype(jnp.float32))
    return {"nll": nll, "acc": acc}
