"""Small supervised-training loop for the paper's CNN classifiers (§3–§5).

Used by the examples and benchmarks to train the S-ML / L-ML tiers on the
synthetic CWRU / CIFAR-10 stand-in datasets on CPU.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.models import cnn
from repro.optim import adamw


def _loss_fn(params, cfg: cnn.CNNConfig, x, y):
    logits = cnn.apply_cnn(params, cfg, x)
    if cfg.num_classes == 1:
        y = y.astype(jnp.float32)
        p = logits[:, 0]
        nll = jnp.mean(jnp.maximum(p, 0) - p * y + jnp.log1p(jnp.exp(-jnp.abs(p))))
    else:
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        nll = jnp.mean(logz - gold)
    return nll


def train_cnn(cfg: cnn.CNNConfig, x_train: np.ndarray, y_train: np.ndarray,
              *, epochs: int = 5, batch: int = 128, lr: float = 2e-3,
              seed: int = 0, verbose: bool = False) -> Dict:
    rng = jax.random.PRNGKey(seed)
    params = cnn.init_cnn(rng, cfg)
    total_steps = epochs * max(1, len(x_train) // batch)
    tcfg = TrainConfig(lr=lr, warmup_steps=max(1, min(20, total_steps // 10)),
                       total_steps=total_steps,
                       weight_decay=0.01, bf16_state=False)
    opt = adamw.init_state(params, tcfg)

    @jax.jit
    def step(params, opt, x, y):
        loss, grads = jax.value_and_grad(_loss_fn)(params, cfg, x, y)
        params, opt, _ = adamw.apply_updates(params, grads, opt, tcfg)
        return params, opt, loss

    n = len(x_train)
    order_rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for ep in range(epochs):
        perm = order_rng.permutation(n)
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            params, opt, loss = step(params, opt, jnp.asarray(x_train[idx]),
                                     jnp.asarray(y_train[idx]))
            losses.append(float(loss))
        if verbose:
            print(f"  epoch {ep}: loss {np.mean(losses):.4f} "
                  f"({time.perf_counter() - t0:.0f}s)")
    return params


def predict_logits(params, cfg: cnn.CNNConfig, x: np.ndarray,
                   batch: int = 512) -> np.ndarray:
    fn = jax.jit(partial(cnn.apply_cnn, cfg=cfg))
    outs = []
    for i in range(0, len(x), batch):
        outs.append(np.asarray(fn(params, x=jnp.asarray(x[i:i + batch]))))
    return np.concatenate(outs)


def accuracy(params, cfg: cnn.CNNConfig, x: np.ndarray, y: np.ndarray) -> float:
    logits = predict_logits(params, cfg, x)
    if cfg.num_classes == 1:
        pred = (logits[:, 0] > 0).astype(np.int32)
    else:
        pred = logits.argmax(-1)
    return float((pred == y).mean())
