"""Continuous-batching scheduler over the paged KV pool.

The drain-path ``HIEngine.serve`` admits a whole (B, bucket) batch, runs the
cascade, and only then admits the next batch: a finished sequence's slot idles
until the SLOWEST sequence in its batch finishes, and every bucket compiles
its own executable with its own donated cache.  This module replaces batch
draining with SLOT-level admission (Orca-style iteration scheduling; see the
online-HI line of work, arXiv:2304.00891, for the per-sample admission model):

* Each tier owns ``num_slots`` decode slots backed by ONE :class:`KVPool`.
* Every scheduler *tick* is ONE device dispatch of one AOT-compiled program —
  the SAME program regardless of prompt bucket — that, per tier, (a) admits
  up to ``admit_width`` queued requests in one batched (A, S_max) prefill
  into their pages (``lax.cond``: skipped at runtime when nothing is
  admitted), and (b) runs ``decode_block`` fused decode steps for ALL slots
  at per-slot positions (a ``lax.scan``, like the drain path's fused decode).
* Host sync happens exactly once per tick, post-cascade, through the
  engine's ``_host_fetch`` — the drain path's single-sync discipline at tick
  granularity.
* A sequence frees its slot the moment it finishes (EOS or its OWN
  max-new-tokens); if its mean confidence fell below theta it re-queues onto
  the L tier's admission queue (the S→L escalation), otherwise the S result
  is final.  Decode steps a released slot computed past its request's end
  are discarded on the host (bounded by ``decode_block - 1``).

Outputs are TOKEN-IDENTICAL to the drain path on the same bucketized
prompts, for ANY ``admit_width``/``decode_block``: admission prefill reads
each row's logits at ``length - 1`` of the same padded prompt, decode masks
by position, and sampling keys are per-request + per-token-index — none of
it depends on which slot, tick, or co-resident requests the sequence ran
with.  ``tests/test_scheduler.py`` asserts this end to end.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core.confidence import confidence as _confidence
from repro.models import model_zoo
from repro.serving import sampler
from repro.serving.batcher import AdmissionQueue, AdmittedRequest
from repro.serving.kv_pool import KVPool


def _tier_tick_fn(cfg: ModelConfig, metric: str, use_kernel: bool,
                  decode_block: int):
    """Device-side per-tier tick: batched cond-prefill + K fused decode
    steps for all slots."""

    def conf_of(logits, theta):
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.hi_gate(logits, theta, metric=metric)[0]
        return _confidence(logits, metric)

    def tick(params, theta, tin, pool):
        a = tin["admit_tokens"].shape[0]

        def admit(pool):
            return model_zoo.prefill_paged(
                params, cfg, tin["admit_tokens"], tin["admit_len"],
                tin["admit_slot"], tin["admit_blocks"], pool,
                use_kernel=use_kernel)

        def skip(pool):
            return jnp.zeros((a, cfg.vocab_size), jnp.float32), pool

        logits0, pool = jax.lax.cond(tin["any_admit"], admit, skip, pool)
        conf0 = conf_of(logits0, theta)                          # (A,)
        keys0 = sampler.request_keys(tin["admit_seed"], 0)
        tok0 = sampler.sample(keys0, logits0, tin["admit_temp"])  # (A,)

        # admitted slots decode their own first tokens in the same tick;
        # padded admission rows carry an out-of-range slot -> dropped
        last0 = tin["last_tok"].at[tin["admit_slot"]].set(tok0, mode="drop")
        block = tin["block"]
        b = block.shape[0]

        def body(carry, k):
            last, pool = carry
            logits, pool = model_zoo.decode_step_paged(
                params, cfg, last[:, None], tin["pos"] + k, block, pool,
                use_kernel=use_kernel)
            confs_k = conf_of(logits, theta)
            keys = sampler.request_keys(tin["seeds"], tin["tok_idx"] + k)
            toks_k = sampler.sample(keys, logits, tin["temps"])
            return (toks_k, pool), (toks_k, confs_k)

        def decode(pool):
            (_, pool), (toks, confs) = jax.lax.scan(body, (last0, pool),
                                                    jnp.arange(decode_block))
            return toks, confs, pool

        def idle(pool):
            # this tier has no live slots this tick (e.g. the L tier before
            # the first escalation arrives): skip the decode entirely
            return (jnp.zeros((decode_block, b), jnp.int32),
                    jnp.zeros((decode_block, b), jnp.float32), pool)

        toks, confs, pool = jax.lax.cond(tin["any_live"], decode, idle, pool)
        return {"admit_tok": tok0, "admit_conf": conf0,
                "toks": toks, "confs": confs}, pool          # toks (K, B)

    return tick


@dataclass
class _Active:
    """One request occupying a decode slot."""
    adm: AdmittedRequest
    steps: int
    tokens: List[int] = field(default_factory=list)
    confs: List[float] = field(default_factory=list)
    hit_eos: bool = False

    def emit(self, tok: int, conf: float) -> None:
        if self.done:
            return
        self.tokens.append(int(tok))
        self.confs.append(float(conf))
        eos = self.adm.request.eos_id
        if eos is not None and int(tok) == eos:
            self.hit_eos = True

    @property
    def done(self) -> bool:
        return self.hit_eos or len(self.tokens) >= self.steps


class _TierRuntime:
    """Host-side slot state for one tier (numpy mirrors of tick operands)."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_context: int,
                 page_size: int, admit_width: int, dtype):
        self.pool = KVPool(cfg, num_slots, max_context, page_size, dtype=dtype)
        self.num_slots = num_slots
        self.admit_width = admit_width
        self.default_temp = 0.0      # engine-level fallback (Request wins)
        self.slot_req: List[Optional[_Active]] = [None] * num_slots
        self.last_tok = np.zeros((num_slots,), np.int32)
        self.pos = np.zeros((num_slots,), np.int32)
        self.seeds = np.zeros((num_slots,), np.int32)
        self.tok_idx = np.zeros((num_slots,), np.int32)
        self.temps = np.zeros((num_slots,), np.float32)
        self.admitted: List[int] = []    # slots admitted THIS tick, row order

    @property
    def busy(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def admit(self, adm: AdmittedRequest, steps: int, decode_block: int
              ) -> bool:
        """Claim a slot + pages for ``adm``; False if no capacity this tick."""
        slot = self.free_slot()
        # decode writes reach bucket + steps - 2, plus <= K-1 overrun steps
        context = adm.bucket + max(steps - 1, 1) + (decode_block - 1)
        if slot is None or not self.pool.can_alloc(context):
            return False
        self.pool.alloc(slot, context)
        self.slot_req[slot] = _Active(adm, steps)
        self.pos[slot] = adm.bucket
        self.seeds[slot] = adm.request.request_id
        self.tok_idx[slot] = 1                 # token 0 comes from the prefill
        self.temps[slot] = (adm.request.temperature
                            if adm.request.temperature > 0
                            else self.default_temp)
        self.last_tok[slot] = 0                # replaced on-device by tok0
        self.admitted.append(slot)
        return True

    def release(self, slot: int) -> _Active:
        rec = self.slot_req[slot]
        self.slot_req[slot] = None
        self.pool.free(slot)
        self.pos[slot] = 0
        self.tok_idx[slot] = 0
        self.temps[slot] = 0.0
        self.last_tok[slot] = 0
        return rec

    def tick_inputs(self, s_max: int) -> Dict:
        a = self.admit_width
        tokens = np.zeros((a, s_max), np.int32)
        lens = np.ones((a,), np.int32)
        slots = np.full((a,), self.num_slots, np.int32)    # drop sentinel
        blocks = np.zeros((a, self.pool.n_pages_per_slot), np.int32)
        seeds = np.zeros((a,), np.int32)
        temps = np.zeros((a,), np.float32)
        for row, slot in enumerate(self.admitted):
            adm = self.slot_req[slot].adm
            tokens[row, : adm.bucket] = adm.tokens
            lens[row] = adm.bucket
            slots[row] = slot
            blocks[row] = self.pool.block[slot]
            seeds[row] = self.seeds[slot]
            temps[row] = self.temps[slot]
        return {
            "last_tok": jnp.asarray(self.last_tok),
            "pos": jnp.asarray(self.pos),
            "block": jnp.asarray(self.pool.block),
            "seeds": jnp.asarray(self.seeds),
            "tok_idx": jnp.asarray(self.tok_idx),
            "temps": jnp.asarray(self.temps),
            "any_admit": jnp.asarray(bool(self.admitted)),
            "any_live": jnp.asarray(self.busy > 0),
            "admit_tokens": jnp.asarray(tokens),
            "admit_len": jnp.asarray(lens),
            "admit_slot": jnp.asarray(slots),
            "admit_blocks": jnp.asarray(blocks),
            "admit_seed": jnp.asarray(seeds),
            "admit_temp": jnp.asarray(temps),
        }


class ContinuousScheduler:
    """Slot-level admission over paged pools for BOTH cascade tiers.

    One instance = one AOT-compiled tick executable (``stats['compiles']``
    stays at 1 no matter how many prompt buckets flow through — the paged
    pool removed the bucket from every device shape).  ``admit_width``
    batches admission prefills like the drain path batches prompts;
    ``decode_block`` fuses that many decode steps per tick like the drain
    path's decode scan (host-discarded overrun past a request's end is the
    latency/throughput knob).
    """

    def __init__(self, s_tier, l_tier, hi: HIConfig, *, max_prompt_len: int,
                 max_new_tokens: int, num_slots: int = 8,
                 l_slots: Optional[int] = None, page_size: int = 16,
                 admit_width: Optional[int] = None, decode_block: int = 4,
                 use_kernel: bool = False, temperature: float = 0.0,
                 cache_dtype=jnp.bfloat16):
        if max_prompt_len % page_size:
            raise ValueError(f"max_prompt_len {max_prompt_len} must be a "
                             f"multiple of page_size {page_size}")
        self.s = s_tier
        self.l = l_tier
        self.hi = hi
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.decode_block = max(1, decode_block)
        l_slots = l_slots if l_slots is not None else max(2, num_slots // 2)
        admit_width = admit_width if admit_width is not None else num_slots
        page = page_size
        raw_ctx = max_prompt_len + max_new_tokens + self.decode_block - 1
        max_context = -(-raw_ctx // page) * page
        self.srt = _TierRuntime(s_tier.cfg, num_slots, max_context, page,
                                admit_width, cache_dtype)
        self.lrt = _TierRuntime(l_tier.cfg, l_slots, max_context, page,
                                min(admit_width, l_slots), cache_dtype)
        self.set_default_temperature(temperature)
        self.stats: Dict[str, float] = {
            "requests": 0, "offloaded": 0, "ticks": 0, "compiles": 0,
            "serve_time": 0.0}

        s_tick = _tier_tick_fn(s_tier.cfg, hi.metric, use_kernel,
                               self.decode_block)
        l_tick = _tier_tick_fn(l_tier.cfg, hi.metric, use_kernel,
                               self.decode_block)

        def tick(s_params, l_params, theta, s_in, l_in, s_pool, l_pool):
            s_out, s_pool = s_tick(s_params, theta, s_in, s_pool)
            l_out, l_pool = l_tick(l_params, theta, l_in, l_pool)
            return {"s": s_out, "l": l_out}, s_pool, l_pool

        spec = partial(jax.tree.map,
                       lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype))
        s_in0 = self.srt.tick_inputs(max_prompt_len)
        l_in0 = self.lrt.tick_inputs(max_prompt_len)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            self._exec = jax.jit(tick, donate_argnums=(5, 6)).lower(
                spec(self.s.params), spec(self.l.params),
                jax.ShapeDtypeStruct((), jnp.float32),
                spec(s_in0), spec(l_in0),
                spec(self.srt.pool.buffers),
                spec(self.lrt.pool.buffers)).compile()
        self.stats["compiles"] += 1

    def set_default_temperature(self, temperature: float) -> None:
        """Engine-level sampling temperature used for requests that don't set
        their own (Request.temperature > 0 wins) — keeps ``serve_stream``
        consistent with ``serve``'s engine-wide temperature."""
        self.srt.default_temp = float(temperature)
        self.lrt.default_temp = float(temperature)

    # -- host loop ----------------------------------------------------------

    def run(self, queue: AdmissionQueue, *, theta: Optional[float] = None
            ) -> Dict[int, Dict[str, Any]]:
        """Drain ``queue`` through the slots; returns per-request records
        keyed by request_id: tokens / s_tokens / confidence / offloaded /
        served_remote (mirroring ``HIEngine.serve``'s fields)."""
        from repro.serving import engine as engine_mod   # _host_fetch hook

        theta = float(self.hi.theta if theta is None else theta)
        theta_j = jnp.asarray(theta, jnp.float32)
        results: Dict[int, Dict[str, Any]] = {}
        l_queue: deque = deque()
        t0 = time.perf_counter()

        while len(queue) or l_queue or self.srt.busy or self.lrt.busy:
            self._try_admit(self.srt, queue)
            self._try_admit(self.lrt, l_queue)
            if (not self.srt.admitted and not self.lrt.admitted
                    and not self.srt.busy and not self.lrt.busy):
                raise RuntimeError(
                    "scheduler stalled: pool too small to admit a single "
                    "request — raise num_pages / num_slots")
            s_in = self.srt.tick_inputs(self.max_prompt_len)
            l_in = self.lrt.tick_inputs(self.max_prompt_len)
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*[Dd]onat")
                out, self.srt.pool.buffers, self.lrt.pool.buffers = \
                    self._exec(self.s.params, self.l.params, theta_j,
                               s_in, l_in, self.srt.pool.buffers,
                               self.lrt.pool.buffers)
            host = engine_mod._host_fetch(out)   # the tick's single sync
            self.stats["ticks"] += 1
            self._absorb(self.srt, host["s"],
                         lambda rec: self._finish_s(rec, theta, l_queue,
                                                    results))
            self._absorb(self.lrt, host["l"],
                         lambda rec: self._finish_l(rec, results))

        self.stats["serve_time"] += time.perf_counter() - t0
        return results

    # -- admission / completion -------------------------------------------

    def _try_admit(self, rt: _TierRuntime, queue) -> None:
        """Admit up to ``admit_width`` queued requests into free slots.
        ``queue`` is the AdmissionQueue (S tier) or the escalation deque
        (L tier); both speak the same popleft/appendleft head interface."""
        rt.admitted = []
        while len(rt.admitted) < rt.admit_width and len(queue):
            if rt.free_slot() is None:
                break
            adm = queue.popleft()
            steps = min(adm.request.max_new_tokens, self.max_new_tokens)
            if not rt.admit(adm, steps, self.decode_block):
                queue.appendleft(adm)   # no pages this tick: retry next tick
                break

    def _absorb(self, rt: _TierRuntime, out: Dict[str, np.ndarray],
                finish) -> None:
        for row, slot in enumerate(rt.admitted):
            rt.slot_req[slot].emit(out["admit_tok"][row],
                                   out["admit_conf"][row])
        k_steps = out["toks"].shape[0]
        for slot in range(rt.num_slots):
            rec = rt.slot_req[slot]
            if rec is None:
                continue
            for k in range(k_steps):
                rec.emit(out["toks"][k][slot], out["confs"][k][slot])
            rt.last_tok[slot] = int(out["toks"][k_steps - 1][slot])
            rt.tok_idx[slot] += k_steps
            rt.pos[slot] += k_steps
            if rec.done:
                finish(rt.release(slot))

    def _finish_s(self, rec: _Active, theta: float, l_queue: deque,
                  results: Dict) -> None:
        conf = float(np.mean(np.asarray(rec.confs, np.float32)))
        rid = rec.adm.request.request_id
        self.stats["requests"] += 1
        results[rid] = {
            "tokens": np.asarray(rec.tokens, np.int32),
            "s_tokens": np.asarray(rec.tokens, np.int32),
            "confidence": conf,
            "offloaded": conf < theta,
            "served_remote": False,
        }
        if conf < theta:
            self.stats["offloaded"] += 1
            l_queue.append(rec.adm)

    def _finish_l(self, rec: _Active, results: Dict) -> None:
        rid = rec.adm.request.request_id
        results[rid]["tokens"] = np.asarray(rec.tokens, np.int32)
        results[rid]["served_remote"] = True
