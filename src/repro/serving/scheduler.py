"""Continuous-batching scheduler over the paged KV pool — ONE chunked token
lane per tick.

The drain-path ``HIEngine.serve`` admits a whole (B, bucket) batch, runs the
cascade, and only then admits the next batch: a finished sequence's slot idles
until the SLOWEST sequence in its batch finishes, and every bucket compiles
its own executable with its own donated cache.  This module replaces batch
draining with SLOT-level admission (Orca-style iteration scheduling; see the
online-HI line of work, arXiv:2304.00891, for the per-sample admission model):

* Each tier owns ``num_slots`` decode slots backed by ONE :class:`KVPool`.
* Every scheduler *tick* is ONE device dispatch of one AOT-compiled program —
  the SAME program regardless of prompt bucket — and host sync happens
  exactly once per tick, post-cascade, through the engine's ``_host_fetch``.

What one tick contains (the chunk-lane dispatch-count model)
------------------------------------------------------------
All lanes below live in the SAME compiled executable; build-time flags decide
which lanes are traced, runtime ``lax.cond`` operands skip idle ones.  Per
tier, in order:

1. **COW lane** (sharing): the admission plan's copy-on-write page
   duplications, so appends never touch a shared page.
2. **Admit lane**: batched (A, S_max) prefill of up to ``admit_width`` queued
   requests + prefix-cache save/restore (``lax.cond``-skipped when idle or
   when every admission is a full-prefix restore).
3. **Chunk-prefill lane** (``chunk_prefill``): ONE
   ``model_zoo.forward_chunk_paged`` pass over a dedicated
   (``chunk_width``, ``chunk_size``) lane — the host schedules up to W
   still-prefilling slots per tick, each fed its next C prompt tokens at its
   own position — so a long prompt is ingested C tokens per tick INTERLEAVED
   with decode instead of monopolizing the admit lane (whose compiled width
   shrinks to ~one chunk when chunking is on and sharing off, since no
   long prompt ever reaches it); the slot that consumes its last chunk
   samples token 0 from the chunk's final live logits and joins decode in
   the same tick.  Recurrent families commit their state to exactly the
   chunk's live token count via the lane's boundary snapshots
   (``select_stage`` / ``scatter_chunk_slots``).
4. **Draft/decode lane**: ``decode_block`` fused decode steps for every
   decoding slot at per-slot positions (a ``lax.scan``); in speculative mode
   this is the S tier's DRAFT, and each step also emits a chunk-boundary
   state snapshot so the rejected tail can be rolled back.
5. **Verify lane** (speculative, L tier only): ONE batched
   ``forward_chunk_paged`` over the S tier's freshly drafted block — the
   fused S→L token cascade.  Per slot: if the minimum per-token hi_gate
   confidence over the draft clears theta the whole block is ACCEPTED at
   S-tier cost; otherwise the L logits greedily re-derive each draft
   position, the longest matching prefix is kept, the first divergence takes
   the L token (the "bonus" correction), and the rejected tail is rolled
   back — recurrent state via ``select_stage`` over the draft/verify
   snapshots, attention state by rewinding the host position, with
   ``KVPool.truncate`` asserting the rewind can never reach a shared page.

The single host sync sits after ALL of the above: one ``_host_fetch`` of the
tick's token/confidence/acceptance outputs.  ``stats['compiles']`` stays at 1
no matter which lanes are enabled — chunking and speculation add operands and
build-time lanes, never a shape.

Prefix sharing (``prefix_entries > 0``) changes admission, not decode: see
PR 3's notes.  Chunk-prefilled admissions still READ cached prefixes (they
start at ``plan.start``) but register nothing — their pages fill over many
ticks, and a same-tick alias would read unwritten pages.

The L-tier admission queue additionally enforces the time-constrained
offloading drop policy (Fresa & Champati, arXiv:2112.11413): an escalation
whose request has outlived its ``latency_budget`` is dropped — the S-tier
answer stands, ``stats["dropped"]`` counts it, and the result record is
flagged.  (Speculative mode has no L queue: every request is admitted to
BOTH tiers at the same slot index, and escalation happens per token block
inside the tick.)

Failure semantics (``serving/faults.py``)
-----------------------------------------
The S→L escalation now crosses a simulated ED↔ES transport
(:class:`~repro.serving.faults.EscalationLink`, driven by a seeded
:class:`~repro.serving.faults.FaultSchedule` set via :meth:`set_faults`)
instead of being appended directly to the L queue.  All of it is HOST-side:
fault injection, retries, and degradation change per-tick operand VALUES
only, never a compiled shape — ``stats['compiles']`` stays at 1 with any
fault schedule (empty ticks that merely advance timers dispatch the same
executable with every lane cond-skipped).

* Every request terminates with exactly ONE result record carrying one of
  the :data:`~repro.serving.faults.STATUSES`: ``ok`` (served locally or
  remotely), ``degraded_local`` (the escalation failed — loss/timeout
  retries exhausted, budget expiry during retry, L outage, open breaker, or
  L admission starvation — and the S answer stands), ``dropped``
  (arXiv:2112.11413 budget expiry in the L queue), or ``rejected``
  (admission gave up: page demand unsatisfiable for
  ``RetryPolicy.admit_retry_limit`` fruitless ticks — the bounded
  replacement for the old "scheduler stalled" RuntimeError).
* Lost / timed-out escalations retry with capped exponential backoff
  (``RetryPolicy``); a retry whose ``latency_budget`` has already expired
  gives up as ``degraded_local``.
* A :class:`~repro.serving.faults.CircuitBreaker` watches consecutive L-path
  failures: closed → open (FAIL-LOCAL: L admission paused, resends held, the
  tick's theta OPERAND becomes ``FAIL_LOCAL_THETA`` so the gate stops
  offloading — no recompile) → half-open (one probe escalation re-admitted;
  success closes, failure re-opens).  ``stats['breaker_open_ticks']`` /
  ``stats['breaker_opens']`` count it.
* Leak-free cancellation: an escalation abandoned at ANY point (lost,
  expired, outage-aborted mid-decode, or pending at drain) releases its
  L-tier slot and KV pages through ``KVPool.free`` — ``check_invariants()``
  holds after every tick (``validate=True``) and the pools' ``held_slots``
  are empty after drain.

Mesh-sharded tier split (``mesh=``)
-----------------------------------
Passing a ``jax.sharding.Mesh`` with ``("data", "model")`` axes (see
``launch/mesh.py``) turns the tick into the paper's ED/ES split at
datacenter scale, still ONE compiled executable and ONE host fetch per
tick:

* **S tier — R data-parallel replicas.** ``shard_map`` over ``data`` runs
  the UNCHANGED per-tier tick body once per replica; each replica owns a
  disjoint ``num_slots`` slot slice, its own host allocator
  (:class:`_TierRuntime` ``S0..S{R-1}``), and its own shard of ONE stacked
  donated ``(R, ...)`` pool pytree (``P("data")``).  Host-side admission
  round-robins requests across replicas; per-replica operands are built as
  raw numpy (``tick_inputs(raw=True)``), stacked, and shipped with one
  sharded ``device_put`` per tree.
* **L tier — one GSPMD-sharded instance.** Params via
  ``sharding/specs.param_shardings`` and paged KV pools via
  ``paged_cache_shardings`` shard the K/V-head dimension over ``model``;
  the L tick body itself is untouched (XLA partitions it).
* **Overlapped escalation transfer.** Escalations cross the mesh through a
  donated double-buffered staging tensor ``(2, admit_width, S_max)``: the
  write half is filled by ``dynamic_update_slice`` at TICK TOP (no S-side
  consumer, so the copy overlaps the same tick's prefill/decode — the
  ``transfer_overlap`` telemetry phase), and the L admit lane reads last
  tick's half, gathering per-admission rows — the host's ``admit_tokens``
  copy is ZEROED on the mesh path, so the device transfer is load-bearing.
  The resulting +1-tick L admission latency is the modelled ED→ES DCN hop;
  a ``ready`` gate holds an escalation in the L queue until its staged row
  is readable.
* A ``(1, 1)`` debug mesh is semantics-free: greedy outputs are bitwise
  the single-device path's in both ``kv_dtype`` modes
  (tests/test_mesh_serving.py).  Faults/retry/breaker machinery is
  host-side and unchanged (fault TIMING shifts by the DCN hop, as a real
  hop would).  Speculative mode and ``use_kernel`` with ``model > 1`` are
  explicitly rejected.

Outputs are TOKEN-IDENTICAL to the drain path on the same bucketized
prompts, for ANY ``admit_width``/``decode_block``, with prefix sharing ON or
OFF and chunked prefill ON or OFF (the chunk lane's per-position math is the
decode step's — tests/test_chunk_lane.py asserts greedy-token identity per
family, bitwise logits for the recurrent families whose chunk IS a scan of
the per-token step).
Speculative mode is greedy-only and matches the host-driven
``token_cascade`` draft-verify oracle block for block
(tests/test_speculative.py).  One caveat: MoE routed dispatch is
batch-coupled (capacity drops depend on co-resident rows), so MoE equality
is exact only up to routing-drop determinism — with the generous decode-path
``capacity_factor`` drops are absent on this reference; see
``moe.prefill_paged``.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core.confidence import confidence as _confidence
from repro.models import model_zoo
from repro.serving import sampler
from repro.serving.batcher import AdmissionQueue, AdmittedRequest
from repro.serving.faults import (NO_FAULTS, CircuitBreaker, Escalation,
                                  EscalationLink, FaultSchedule,
                                  FAIL_LOCAL_THETA, RetryPolicy)
from repro.serving.kv_pool import AdmitPlan, KVPool
from repro.serving.telemetry import SchedCounters, StatsView, Telemetry


def _tier_tick_fn(cfg: ModelConfig, metric: str, use_kernel: bool,
                  decode_block: int, sharing: bool, chunk: int = 0,
                  role: str = "plain"):
    """Device-side per-tier tick: COW copies + batched cond-prefill +
    prefix-cache save/restore + chunk-prefill lane + K fused draft/decode
    steps (or, for ``role == "spec_l"``, the fused verify chunk).

    ``chunk``/``role`` are BUILD-time switches: with ``chunk == 0`` and
    ``role == "plain"`` the traced graph is exactly the PR-2/3 tick, which
    is what keeps greedy outputs bitwise stable with the new lanes off."""

    def conf_of(logits, theta):
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.hi_gate(logits, theta, metric=metric)[0]
        return _confidence(logits, metric)

    def admit_and_prefix(params, tin, pool):
        """COW + batched admission prefill + prefix-cache save/restore.
        Returns (admission logits0 (A, V), core, prefix-or-None)."""
        core = pool["core"]
        a = tin["admit_tokens"].shape[0]

        if sharing:
            # copy-on-write duplications first: prefill reads and decode
            # appends must see the private copies.  Skipped at runtime on the
            # (common) no-COW tick — the kernel path in particular streams
            # the whole page pool, which would tax every steady-state tick.
            core = jax.lax.cond(
                tin["any_cow"],
                lambda c: model_zoo.cow_pages(cfg, c, tin["cow_src"],
                                              tin["cow_dst"],
                                              use_kernel=use_kernel),
                lambda c: c, core)

        def admit(core):
            return model_zoo.prefill_paged(
                params, cfg, tin["admit_tokens"], tin["admit_len"],
                tin["admit_slot"], tin["admit_blocks"], core,
                use_kernel=use_kernel,
                start=tin["admit_start"] if sharing else None)

        def skip(core):
            return jnp.zeros((a, cfg.vocab_size), jnp.float32), core

        # skipped when nothing is admitted — or (sharing) when every
        # admission this tick is a full-prefix restore
        logits0, core = jax.lax.cond(tin["any_prefill"], admit, skip, core)
        prefix = None
        if sharing:
            prefix = pool["prefix"]
            # full restores read their admission logits + recurrent state
            # from the PRE-SAVE prefix cache: a restore's entry was filled in
            # an earlier tick, and reading before this tick's saves keeps a
            # same-tick eviction that recycles the restore's row (LRU under
            # row pressure) from corrupting the restored state
            logits0 = jnp.where(tin["restore_mask"][:, None],
                                prefix["logits"][tin["restore_row"]], logits0)
            core = model_zoo.snapshot_restore(cfg, core, prefix,
                                              tin["restore_row"],
                                              tin["restore_slot"])
            # computing admissions persist their logits + recurrent state
            # into their reserved rows (sentinel rows drop); computing slots
            # are disjoint from restored slots, so the gather below is
            # unaffected by the restore scatter above
            prefix = dict(prefix, logits=prefix["logits"].at[
                tin["save_row"]].set(logits0, mode="drop"))
            prefix = model_zoo.snapshot_save(cfg, core, prefix,
                                             tin["save_row"],
                                             tin["admit_slot"])
        return logits0, core, prefix

    def chunk_lane(params, theta, tin, core):
        """Chunked prefill: one multi-token pass over a DEDICATED W-row lane
        (W = chunk_width slots scheduled by the host this tick, W <<
        num_slots) feeding each its next C prompt tokens.  Reads route
        through the scheduled slots' full block rows, writes through their
        write-masked rows; recurrent state commits to exactly ``chunk_keep``
        inputs via the lane's boundary snapshots and scatters back at the
        scheduled slot ids (sentinel rows drop).  Returns the per-ROW last
        live position's sampled token + confidence (consumed where
        ``chunk_fin``)."""

        def go(core):
            mini = model_zoo.gather_chunk_slots(cfg, core, tin["chunk_slot"])
            logits_c, mini, staged = model_zoo.forward_chunk_paged(
                params, cfg, tin["chunk_tokens"], tin["chunk_pos"],
                tin["chunk_block"], mini, use_kernel=use_kernel,
                write_block=tin["chunk_wblock"])
            sel = model_zoo.select_stage(cfg, staged, tin["chunk_keep"])
            core = model_zoo.scatter_chunk_slots(cfg, core, mini, sel,
                                                 tin["chunk_slot"])
            idx = jnp.maximum(tin["chunk_keep"] - 1, 0)
            last = jnp.take_along_axis(logits_c, idx[:, None, None],
                                       axis=1)[:, 0]
            return last, core

        def skip(core):
            w = tin["chunk_slot"].shape[0]
            return jnp.zeros((w, cfg.vocab_size), jnp.float32), core

        logits_c, core = jax.lax.cond(tin["any_chunk"], go, skip, core)
        conf_c = conf_of(logits_c, theta)
        keys = sampler.request_keys(tin["chunk_seed"], 0)
        tok_c = sampler.sample(keys, logits_c, tin["chunk_temp"])
        return tok_c, conf_c, core

    def tick(params, theta, tin, pool, draft=None):
        logits0, core, prefix = admit_and_prefix(params, tin, pool)
        conf0 = conf_of(logits0, theta)
        keys0 = sampler.request_keys(tin["admit_seed"], 0)
        tok0 = sampler.sample(keys0, logits0, tin["admit_temp"])  # (A,)

        # admitted slots decode their own first tokens in the same tick;
        # padded admission rows carry an out-of-range slot -> dropped
        last0 = tin["last_tok"].at[tin["admit_slot"]].set(tok0, mode="drop")
        out = {"admit_tok": tok0, "admit_conf": conf0}
        if chunk:
            tok_c, conf_c, core = chunk_lane(params, theta, tin, core)
            n_slots = tin["last_tok"].shape[0]
            fin_slot = jnp.where(tin["chunk_fin"], tin["chunk_slot"],
                                 n_slots)
            last0 = last0.at[fin_slot].set(tok_c, mode="drop")
            out["chunk_tok"] = tok_c
            out["chunk_conf"] = conf_c
        block = tin["block"]
        b = block.shape[0]
        wb = tin["draft_wblock"] if chunk else \
            (tin["wblock"] if sharing else None)

        if role == "spec_l":
            # ---- fused verify chunk over the S tier's drafts -------------
            k = decode_block
            toks = draft["toks"]                      # (K, B) S drafts
            confs = draft["confs"]                    # (K, B) hi_gate confs
            vin = jnp.concatenate([draft["last0"][None], toks[:-1]], 0).T

            def verify(core):
                pre = model_zoo.chunk_stage(cfg, core)
                vlog, core, staged = model_zoo.forward_chunk_paged(
                    params, cfg, vin, tin["pos"], block, core,
                    use_kernel=use_kernel, write_block=wb)
                # greedy-only acceptance (serve_stream raises on temp > 0)
                lv = jnp.argmax(vlog, -1).astype(jnp.int32)      # (B, K)
                live = tin["draft_live"]
                esc = (confs.min(axis=0) < theta) & live
                match = lv == toks.T
                m = jnp.where(match.all(axis=1), k,
                              jnp.argmax(~match, axis=1)).astype(jnp.int32)
                accept = jnp.where(esc, m, k)        # drafts kept
                keep = jnp.where(esc, jnp.minimum(m + 1, k), k)  # inputs kept
                core = model_zoo.restore_stage(cfg, core, pre, ~live)
                sel = model_zoo.select_stage(cfg, staged, keep)
                core = model_zoo.restore_stage(cfg, core, sel, live)
                cols = jnp.arange(k)[None, :]
                bonus = esc[:, None] & (cols == m[:, None])
                out_toks = jnp.where(bonus, lv, toks.T)
                out_confs = jnp.where(bonus, 1.0, confs.T)  # L-verified token
                n_emit = jnp.where(esc & (m < k), m + 1, k)
                return (out_toks, out_confs, keep, accept, esc, n_emit,
                        match, core)

            def v_idle(core):
                return (jnp.zeros((b, k), jnp.int32),
                        jnp.zeros((b, k), jnp.float32),
                        jnp.zeros((b,), jnp.int32),
                        jnp.zeros((b,), jnp.int32),
                        jnp.zeros((b,), bool),
                        jnp.zeros((b,), jnp.int32),
                        jnp.zeros((b, k), bool), core)

            (out_toks, out_confs, keep, accept, esc, n_emit, match,
             core) = jax.lax.cond(tin["any_live"], verify, v_idle, core)
            out.update({"toks": out_toks, "confs": out_confs, "keep": keep,
                        "accept": accept, "esc": esc, "n_emit": n_emit,
                        # verify-lane ground truth for the gate audit: raw
                        # per-position L accept/reject and the S draft confs
                        # (out_confs overwrites the bonus position to 1.0,
                        # which would poison calibration bins)
                        "match": match, "draft_confs": confs.T})
            out_pool = {"core": core, "prefix": prefix} if sharing \
                else {"core": core}
            return out, out_pool

        # ---- draft / decode scan (roles "plain" and "spec_s") ------------
        def body(carry, kk):
            last, core = carry
            logits, core = model_zoo.decode_step_paged(
                params, cfg, last[:, None], tin["pos"] + kk, block, core,
                use_kernel=use_kernel, write_block=wb)
            confs_k = conf_of(logits, theta)
            keys = sampler.request_keys(tin["seeds"], tin["tok_idx"] + kk)
            toks_k = sampler.sample(keys, logits, tin["temps"])
            ys = (toks_k, confs_k)
            if role == "spec_s":
                ys = ys + (model_zoo.chunk_stage(cfg, core),)
            return (toks_k, core), ys

        def decode(core):
            pre_d = model_zoo.chunk_stage(cfg, core) if chunk else None
            (_, core), ys = jax.lax.scan(body, (last0, core),
                                         jnp.arange(decode_block))
            if chunk:
                # slots still mid chunk-prefill took garbage draft steps:
                # their page writes were null-masked, restore their state
                core = model_zoo.restore_stage(cfg, core, pre_d,
                                               ~tin["draft_live"])
            if role == "spec_s":
                toks, confs, staged = ys
            else:
                (toks, confs), staged = ys, {}
            return toks, confs, staged, core

        def idle(core):
            # this tier has no decoding slots this tick (e.g. everything is
            # still chunk-prefilling): skip the scan entirely
            staged = jax.tree.map(
                lambda a: jnp.zeros((decode_block,) + a.shape, a.dtype),
                model_zoo.chunk_stage(cfg, core)) if role == "spec_s" else {}
            return (jnp.zeros((decode_block, b), jnp.int32),
                    jnp.zeros((decode_block, b), jnp.float32), staged, core)

        toks, confs, staged, core = jax.lax.cond(tin["any_live"], decode,
                                                 idle, core)
        out.update({"toks": toks, "confs": confs})       # toks (K, B)
        out_pool = {"core": core, "prefix": prefix} if sharing \
            else {"core": core}
        if role == "spec_s":
            return out, out_pool, {"staged": staged, "toks": toks,
                                   "confs": confs, "last0": last0}
        return out, out_pool

    return tick


@dataclass
class _Active:
    """One request occupying a decode slot."""
    adm: AdmittedRequest
    steps: int
    tokens: List[int] = field(default_factory=list)
    confs: List[float] = field(default_factory=list)
    rounds: List = field(default_factory=list)   # spec: (escalated, n_emit)
    first_tok: float = 0.0                       # monotonic first-emit time
    hit_eos: bool = False

    def emit(self, tok: int, conf: float) -> None:
        if self.done:
            return
        if not self.tokens:
            self.first_tok = time.monotonic()
        self.tokens.append(int(tok))
        self.confs.append(float(conf))
        eos = self.adm.request.eos_id
        if eos is not None and int(tok) == eos:
            self.hit_eos = True

    @property
    def done(self) -> bool:
        return self.hit_eos or len(self.tokens) >= self.steps

    @property
    def ttft(self) -> float:
        return self.first_tok - self.adm.submit_time


class _TierRuntime:
    """Host-side slot state for one tier (numpy mirrors of tick operands)."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_context: int,
                 page_size: int, admit_width: int, dtype,
                 prefix_entries: int = 0, max_prompt_len: int = 0,
                 num_pages: Optional[int] = None, chunk_size: int = 0,
                 chunk_width: int = 2, spec: bool = False,
                 name: str = "S", alloc: bool = True):
        if num_pages is None:
            # sharing headroom: beyond every slot's full context, enough
            # pages to RETAIN prefix_entries full prompts without evicting
            # under load
            num_pages = num_slots * (max_context // page_size) + 1
            num_pages += prefix_entries * (-(-max_prompt_len // page_size))
        # ``alloc=False``: this runtime is one DATA-axis replica of the
        # mesh-sharded scheduler — it keeps the full host-side allocator
        # (its own free list, block table, refcounts: the per-shard free
        # lists) but its device buffers are ShapeDtypeStructs; the real
        # allocation is one stacked (R, ...) donated tree the scheduler owns.
        self.pool = KVPool(cfg, num_slots, max_context, page_size,
                           num_pages=num_pages, dtype=dtype,
                           prefix_entries=prefix_entries, alloc=alloc)
        self.sharing = prefix_entries > 0
        self.name = name               # tier label for telemetry tracks
        self.num_slots = num_slots
        self.admit_width = admit_width
        self.chunk_size = chunk_size
        self.chunk_width = max(1, min(chunk_width, num_slots))
        self.chunk_sched: List = []    # (slot, keep, fin) rows THIS tick
        self.spec = spec
        self.default_temp = 0.0      # engine-level fallback (Request wins)
        self.slot_req: List[Optional[_Active]] = [None] * num_slots
        self.last_tok = np.zeros((num_slots,), np.int32)
        self.pos = np.zeros((num_slots,), np.int32)
        self.seeds = np.zeros((num_slots,), np.int32)
        self.tok_idx = np.zeros((num_slots,), np.int32)
        self.temps = np.zeros((num_slots,), np.float32)
        self.chunk_fed = np.zeros((num_slots,), np.int32)   # prompt tokens fed
        self.chunk_left = np.zeros((num_slots,), np.int32)  # 0 = decoding
        self.admitted: List[int] = []    # slots admitted THIS tick, row order
        self.plans: List[AdmitPlan] = []  # aligned admission plans

    @property
    def busy(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def admit(self, adm: AdmittedRequest, steps: int, decode_block: int,
              tick: int) -> Optional[int]:
        """Claim a slot + pages for ``adm``; returns the slot index, or
        ``None`` if no capacity this tick (callers MUST test ``is None`` —
        slot 0 is falsy).  With sharing, the pool aliases the longest cached
        prefix and the returned plan carries start / restore / save / COW
        decisions.  With ``chunk_size`` set, a prompt whose uncached
        remainder exceeds one chunk skips the admit lane: its pages are
        claimed now and its tokens flow through the chunk-prefill lane C per
        tick."""
        slot = self.free_slot()
        # decode writes reach bucket + steps - 2, plus <= K-1 overrun steps
        context = adm.bucket + max(steps - 1, 1) + (decode_block - 1)
        if slot is None:
            return None
        chunked = bool(self.chunk_size) and adm.bucket > self.chunk_size
        if self.sharing and not (chunked and self.spec):
            plan = self.pool.admit_prefix(slot, context, adm.bucket,
                                          adm.page_hashes, adm.full_hash,
                                          tick, register=not chunked)
            if plan is None:
                return None
            if plan.is_restore:
                chunked = False          # full hit: restoring beats chunking
        elif self.sharing:
            # speculative pairing: both tiers must chunk in LOCK-STEP (the
            # verify lane gates on a shared readiness), so chunk admissions
            # skip per-tier prefix hits — a hit in one tier's index but not
            # the other's would desynchronise the pair's prefill progress
            try:
                self.pool.alloc(slot, context, tick=tick)
            except ValueError:
                return None
            plan = AdmitPlan(slot=slot)
        else:
            if not self.pool.can_alloc(context):
                return None
            self.pool.alloc(slot, context)
            plan = AdmitPlan(slot=slot)
        self.slot_req[slot] = _Active(adm, steps)
        self.pos[slot] = adm.bucket
        self.seeds[slot] = adm.request.request_id
        self.tok_idx[slot] = 1                 # token 0 comes from the prefill
        self.temps[slot] = (adm.request.temperature
                            if adm.request.temperature > 0
                            else self.default_temp)
        self.last_tok[slot] = 0                # replaced on-device by tok0
        if chunked and adm.bucket - plan.start > self.chunk_size:
            self.chunk_fed[slot] = plan.start
            self.chunk_left[slot] = adm.bucket - plan.start
        else:
            self.admitted.append(slot)
            self.plans.append(plan)
        return slot

    def release(self, slot: int) -> _Active:
        rec = self.slot_req[slot]
        self.slot_req[slot] = None
        self.pool.free(slot)
        self.pos[slot] = 0
        self.tok_idx[slot] = 0
        self.temps[slot] = 0.0
        self.last_tok[slot] = 0
        self.chunk_fed[slot] = 0
        self.chunk_left[slot] = 0
        return rec

    def tick_inputs(self, s_max: int, raw: bool = False) -> Dict:
        """This tick's operand dict.  ``raw=True`` returns NUMPY leaves
        (copies where state arrays are exposed) instead of device arrays —
        the mesh dispatch path stacks R replicas' operands host-side and
        ships each leaf with ONE sharded ``device_put``, which beats R
        per-leaf ``jnp.stack`` + reshard by a wide margin per tick."""
        a = self.admit_width
        tokens = np.zeros((a, s_max), np.int32)
        lens = np.ones((a,), np.int32)
        slots = np.full((a,), self.num_slots, np.int32)    # drop sentinel
        blocks = np.zeros((a, self.pool.n_pages_per_slot), np.int32)
        seeds = np.zeros((a,), np.int32)
        temps = np.zeros((a,), np.float32)
        for row, slot in enumerate(self.admitted):
            adm = self.slot_req[slot].adm
            tokens[row, : adm.bucket] = adm.tokens
            lens[row] = adm.bucket
            slots[row] = slot
            blocks[row] = self.pool.block[slot]
            seeds[row] = self.seeds[slot]
            temps[row] = self.temps[slot]
        out = {
            "last_tok": self.last_tok,
            "pos": self.pos,
            "block": self.pool.block,
            "seeds": self.seeds,
            "tok_idx": self.tok_idx,
            "temps": self.temps,
            "admit_tokens": tokens,
            "admit_len": lens,
            "admit_slot": slots,
            "admit_blocks": blocks,
            "admit_seed": seeds,
            "admit_temp": temps,
        }
        occupied = np.asarray([r is not None for r in self.slot_req])
        if self.chunk_size:
            c, w = self.chunk_size, self.chunk_width
            npg = self.pool.n_pages_per_slot
            base = self.pool.write_block() if self.sharing else self.pool.block
            ctoks = np.zeros((w, c), np.int32)
            cslot = np.full((w,), self.num_slots, np.int32)  # drop sentinel
            cpos = np.zeros((w,), np.int32)
            ckeep = np.zeros((w,), np.int32)
            cfin = np.zeros((w,), bool)
            cblock = np.zeros((w, npg), np.int32)
            cwb = np.zeros((w, npg), np.int32)
            cseed = np.zeros((w,), np.int32)
            ctemp = np.zeros((w,), np.float32)
            dlive = np.zeros((self.num_slots,), bool)
            self.chunk_sched = []
            for slot in range(self.num_slots):
                rec = self.slot_req[slot]
                if rec is None:
                    continue
                left = int(self.chunk_left[slot])
                if left == 0:
                    dlive[slot] = True
                    continue
                row = len(self.chunk_sched)
                if row == w:
                    continue               # lane full: this slot waits a tick
                keep = min(c, left)
                fed = int(self.chunk_fed[slot])
                seg = rec.adm.tokens[fed:fed + c]
                ctoks[row, : len(seg)] = seg
                cslot[row] = slot
                cpos[row] = fed
                ckeep[row] = keep
                cfin[row] = keep == left
                cblock[row] = self.pool.block[slot]
                cwb[row] = base[slot]
                cseed[row] = self.seeds[slot]
                ctemp[row] = self.temps[slot]
                dlive[slot] = cfin[row]    # joins decode the same tick
                self.chunk_sched.append((slot, keep, bool(cfin[row])))
            out.update({
                "chunk_tokens": ctoks,
                "chunk_slot": cslot,
                "chunk_pos": cpos,
                "chunk_keep": ckeep,
                "chunk_fin": cfin,
                "any_chunk": np.asarray(bool(ckeep.any())),
                "chunk_block": cblock,
                "chunk_wblock": cwb,
                "chunk_seed": cseed,
                "chunk_temp": ctemp,
                "draft_live": dlive,
                "draft_wblock": np.where(dlive[:, None], base,
                                         0).astype(np.int32),
            })
            out["any_live"] = np.asarray(bool(dlive.any()))
        else:
            out["any_live"] = np.asarray(self.busy > 0)
            if self.spec:
                out["draft_live"] = occupied
        if not self.sharing:
            out["any_prefill"] = np.asarray(bool(self.admitted))
            return self._finish_inputs(out, raw)
        entries = self.pool.prefix_entries
        starts = np.zeros((a,), np.int32)
        restore_mask = np.zeros((a,), bool)
        restore_row = np.zeros((a,), np.int32)
        restore_slot = np.full((a,), self.num_slots, np.int32)
        save_row = np.full((a,), entries, np.int32)        # drop sentinel
        cow_src = np.zeros((a,), np.int32)
        cow_dst = np.zeros((a,), np.int32)
        any_prefill = False
        for row, (slot, plan) in enumerate(zip(self.admitted, self.plans)):
            starts[row] = plan.start
            if plan.is_restore:
                restore_mask[row] = True
                restore_row[row] = plan.restore_row
                restore_slot[row] = slot
            else:
                any_prefill = True
                if plan.save_row >= 0:
                    save_row[row] = plan.save_row
            if plan.cow is not None:
                cow_src[row], cow_dst[row] = plan.cow
        out.update({
            "any_prefill": np.asarray(any_prefill),
            "any_cow": np.asarray(bool(cow_dst.any())),
            "admit_start": starts,
            "restore_mask": restore_mask,
            "restore_row": restore_row,
            "restore_slot": restore_slot,
            "save_row": save_row,
            "cow_src": cow_src,
            "cow_dst": cow_dst,
            "wblock": self.pool.write_block(),
        })
        return self._finish_inputs(out, raw)

    @staticmethod
    def _finish_inputs(out: Dict, raw: bool) -> Dict:
        if raw:
            # numpy leaves; live state arrays (pos / seeds / block ...) are
            # copied so the caller's host-side stacking can never alias a
            # runtime that mutates between build and dispatch
            return {k: np.array(v) for k, v in out.items()}
        return {k: jnp.asarray(v) for k, v in out.items()}

    def pool_operand(self) -> Dict:
        if self.sharing:
            return {"core": self.pool.buffers,
                    "prefix": self.pool.prefix_buffers}
        return {"core": self.pool.buffers}

    def store_pool(self, pool: Dict) -> None:
        self.pool.buffers = pool["core"]
        if self.sharing:
            self.pool.prefix_buffers = pool["prefix"]


class ContinuousScheduler:
    """Slot-level admission over paged pools for BOTH cascade tiers.

    One instance = one AOT-compiled tick executable (``stats['compiles']``
    stays at 1 no matter how many prompt buckets flow through — the paged
    pool removed the bucket from every device shape, and prefix sharing,
    chunked prefill, and the speculative cascade add only runtime operands
    and build-time lanes).  ``admit_width`` batches admission prefills like
    the drain path batches prompts; ``decode_block`` fuses that many decode
    steps per tick (and is the speculative DRAFT length k).
    ``prefix_sharing`` turns on the pool's content-addressed prefix reuse.
    ``chunk_prefill`` routes prompts longer than ``chunk_size`` through the
    chunk lane (C tokens per tick, interleaved with decode).  ``speculative``
    fuses the S→L draft-verify token cascade into the tick (greedy-only;
    both tiers admit every request at the same slot index).
    ``kv_dtype="int8"`` stores both tiers' KV pages quantized (int8 with
    per-page-per-head scales, dequantization fused into the page-gather
    kernels) at roughly half the pool bytes; the default ``"bf16"`` keeps
    every bitwise invariant of the unquantized build.

    Telemetry (``serving/telemetry.py``)
    ------------------------------------
    :meth:`set_telemetry` installs a collector; ``None`` (the default)
    disables it — every hook site is a single ``is None`` branch, and NO
    telemetry work touches the device: ``stats['compiles']`` stays 1 and the
    tick keeps its single ``_host_fetch`` sync with telemetry on or off.
    Enabled, the collector records

    * a span tree per request: ``queued → admitted → prefill_chunk[i] →
      decode_block[j] → escalate_attempt[k] → l_verify → terminal`` with the
      terminal ``status``, TTFT, TPOT, queue-wait ticks, and retry counts
      (terminal hooks sit exactly where records reach their FINAL status:
      ``_finish_s`` un-escalated, ``_finish_l``, ``_finish_spec``,
      ``_degrade``, ``_drop_expired``, ``_reject``);
    * per-tick wall-time buckets (``fault_tick`` — fault machinery + slot
      admission, ``build_operands``, ``dispatch``, ``host_fetch``,
      ``postprocess``) plus pool / breaker / queue gauges sampled once per
      tick from host state the scheduler already holds (``KVPool.gauges``,
      breaker ``state_id``, queue depths) — no extra device traffic.

    ``serving/trace_export.py`` renders the collector as Perfetto-loadable
    Chrome ``trace_event`` JSON (slot tracks per tier, S→L flow arrows).
    """

    def __init__(self, s_tier, l_tier, hi: HIConfig, *, max_prompt_len: int,
                 max_new_tokens: int, num_slots: int = 8,
                 l_slots: Optional[int] = None, page_size: int = 16,
                 admit_width: Optional[int] = None, decode_block: int = 4,
                 use_kernel: bool = False, temperature: float = 0.0,
                 cache_dtype=jnp.bfloat16, prefix_sharing: bool = False,
                 prefix_entries: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 chunk_prefill: bool = False, chunk_size: int = 8,
                 chunk_width: int = 2, speculative: bool = False,
                 kv_dtype: str = "bf16", mesh=None):
        if max_prompt_len % page_size:
            raise ValueError(f"max_prompt_len {max_prompt_len} must be a "
                             f"multiple of page_size {page_size}")
        if kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {kv_dtype!r}")
        if kv_dtype == "int8":
            # quantized page pools: int8 pages + per-page-per-head fp32
            # scales, dequant fused into the page-gather kernels.  bf16 (the
            # default) keeps every bitwise invariant of the unquantized build.
            cache_dtype = jnp.int8
        self.kv_dtype = kv_dtype
        if chunk_prefill and chunk_size < 1:
            raise ValueError(f"chunk_size {chunk_size} must be >= 1")
        if mesh is not None:
            if speculative:
                raise NotImplementedError(
                    "speculative + mesh: the fused draft-verify cascade "
                    "pairs S and L slots 1:1, which a replicated S tier "
                    "breaks (same precedent as speculative + faults)")
            for ax in ("data", "model"):
                if ax not in mesh.shape:
                    raise ValueError(
                        f"serving mesh needs axes ('data', 'model'), got "
                        f"{tuple(mesh.shape)}")
            if use_kernel and mesh.shape["model"] > 1:
                raise NotImplementedError(
                    "use_kernel with model>1: the L tier's Pallas page-"
                    "gather cannot be GSPMD-partitioned over the model axis "
                    "(the S tier's kernels run per-shard under shard_map and "
                    "are fine at any data size)")
        self._mesh = mesh
        self.s = s_tier
        self.l = l_tier
        self.hi = hi
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.decode_block = max(1, decode_block)
        self.prefix_sharing = prefix_sharing
        self.speculative = speculative
        self.chunk = int(chunk_size) if chunk_prefill else 0
        if speculative:
            l_slots = num_slots          # strict 1:1 slot pairing
        else:
            l_slots = l_slots if l_slots is not None else max(2, num_slots // 2)
        admit_width = admit_width if admit_width is not None else num_slots
        page = page_size
        raw_ctx = max_prompt_len + max_new_tokens + self.decode_block - 1
        max_context = -(-raw_ctx // page) * page
        s_entries = (prefix_entries if prefix_entries is not None
                     else 2 * num_slots) if prefix_sharing else 0
        l_entries = (prefix_entries if prefix_entries is not None
                     else 2 * l_slots) if prefix_sharing else 0
        n_rep = 1 if mesh is None else int(mesh.shape["data"])
        self.srts: List[_TierRuntime] = [
            _TierRuntime(s_tier.cfg, num_slots, max_context, page,
                         admit_width, cache_dtype,
                         prefix_entries=s_entries,
                         max_prompt_len=max_prompt_len,
                         num_pages=num_pages, chunk_size=self.chunk,
                         chunk_width=chunk_width, spec=speculative,
                         name="S" if mesh is None else f"S{r}",
                         alloc=mesh is None)
            for r in range(n_rep)]
        self.lrt = _TierRuntime(l_tier.cfg, l_slots, max_context, page,
                                admit_width if speculative
                                else min(admit_width, l_slots), cache_dtype,
                                prefix_entries=l_entries,
                                max_prompt_len=max_prompt_len,
                                num_pages=num_pages, chunk_size=self.chunk,
                                chunk_width=chunk_width, spec=speculative,
                                name="L")
        self.set_default_temperature(temperature)
        # with chunking on (and no prefix hits routing long prompts back to
        # the admit lane), every admit-lane prompt is <= chunk_size: the
        # batched admission pass shrinks from (A, max_prompt_len) to
        # (A, ~chunk_size) — the long-prompt traffic stops taxing every
        # admission tick, which is the TTFT win bench_serving measures
        self._admit_s_max = max_prompt_len
        if self.chunk and not prefix_sharing:
            self._admit_s_max = min(max_prompt_len,
                                    -(-self.chunk // page) * page)
        # ONE authoritative counter store (typed); ``stats`` keeps the
        # historical dict API as a read/write view over it — HIEngine reads
        # the same fields live instead of copy-and-zeroing them
        self.counters = SchedCounters()
        self.stats: StatsView = StatsView(self.counters)
        # telemetry collector (None = disabled: every hook site is a single
        # ``is None`` branch — the zero-overhead default)
        self.tel: Optional[Telemetry] = None
        # decision-quality observability (serving/audit.py + flight_recorder
        # .py) — same contract as telemetry: host-side, None by default,
        # never part of the compile key
        self.aud = None                      # GateAudit
        self.wd = None                       # SLOWatchdog
        self.fr = None                       # FlightRecorder
        self._opens_seen = 0                 # breaker-open dump edge detect
        self._run_theta = float(hi.theta)    # the run's CALIBRATED theta
        self._eff_theta = float(hi.theta)    # theta IN EFFECT this tick
        # fault-injection state (host-side; set_faults replaces per run —
        # never part of the compile key, so changing it never recompiles)
        self.faults: FaultSchedule = NO_FAULTS
        self.policy: RetryPolicy = RetryPolicy()
        self.validate = False
        self._link: Optional[EscalationLink] = None
        self._breaker: Optional[CircuitBreaker] = None
        self._esc_meta: Dict[int, Escalation] = {}
        self._probe: Optional[int] = None
        self._tick0 = 0
        # escalation transfer staging (mesh mode): rows the L admit lane may
        # read THIS tick (written last tick) and rows being written this tick
        self._staged: Dict[int, int] = {}
        self._staged_next: Dict[int, int] = {}
        self._stage_tokens = np.zeros((0, 0), np.int32)
        self._stage_wix = 0

        s_role = "spec_s" if speculative else "plain"
        l_role = "spec_l" if speculative else "plain"
        s_tick = _tier_tick_fn(s_tier.cfg, hi.metric, use_kernel,
                               self.decode_block, self.srt.sharing,
                               chunk=self.chunk, role=s_role)
        l_tick = _tier_tick_fn(l_tier.cfg, hi.metric, use_kernel,
                               self.decode_block, self.lrt.sharing,
                               chunk=self.chunk, role=l_role)

        if speculative:
            s_cfg = s_tier.cfg

            def tick(s_params, l_params, theta, s_in, l_in, s_pool, l_pool):
                s_out, s_pool, s_ext = s_tick(s_params, theta, s_in, s_pool)
                l_out, l_pool = l_tick(l_params, theta, l_in, l_pool,
                                       draft=s_ext)
                # roll the S tier back to the accepted boundary: recurrent
                # state via the draft's per-step snapshots; attention state
                # is positional (the host rewinds pos)
                sel = model_zoo.select_stage(s_cfg, s_ext["staged"],
                                             l_out["keep"])
                core = model_zoo.restore_stage(s_cfg, s_pool["core"], sel,
                                               s_in["draft_live"])
                s_pool = dict(s_pool, core=core)
                return {"s": s_out, "l": l_out}, s_pool, l_pool
        else:
            def tick(s_params, l_params, theta, s_in, l_in, s_pool, l_pool):
                s_out, s_pool = s_tick(s_params, theta, s_in, s_pool)
                l_out, l_pool = l_tick(l_params, theta, l_in, l_pool)
                return {"s": s_out, "l": l_out}, s_pool, l_pool

        spec = partial(jax.tree.map,
                       lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype))
        s_in0 = self.srt.tick_inputs(self._admit_s_max)
        l_in0 = self.lrt.tick_inputs(self._admit_s_max)
        if mesh is None:
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*[Dd]onat")
                self._exec = jax.jit(tick, donate_argnums=(5, 6)).lower(
                    spec(self.s.params), spec(self.l.params),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    spec(s_in0), spec(l_in0),
                    spec(self.srt.pool_operand()),
                    spec(self.lrt.pool_operand())).compile()
        else:
            self._build_mesh_exec(mesh, s_tick, l_tick, spec, s_in0, l_in0)
        self.counters.compiles += 1

    @property
    def srt(self) -> _TierRuntime:
        """Replica 0's runtime — THE runtime on the single-device path (the
        historical attribute; mesh-unaware callers and tests keep working)."""
        return self.srts[0]

    def _build_mesh_exec(self, mesh, s_tick, l_tick, spec, s_in0, l_in0
                         ) -> None:
        """Compile the mesh-aware tick: ``shard_map`` the S tier over
        ``data`` (one replica per shard, running the UNMODIFIED per-tier
        tick on its own slot slice + pool shard), GSPMD-shard the L tier's
        params and KV pages over ``model``, and thread the donated
        double-buffered escalation staging buffer through the same single
        executable.  Still ONE compile, ONE host fetch per tick."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.sharding import specs as sh

        n_rep = len(self.srts)
        ns_rep = NamedSharding(mesh, P())
        ns_data = NamedSharding(mesh, P("data"))
        self._ns_rep, self._ns_data = ns_rep, ns_data

        # one-time placement: S params replicated, L params model-sharded by
        # the existing partition rules, L pool pages model-sharded on the
        # KV-head dim, S pools ONE stacked (R, ...) zero tree over ``data``
        self._s_params = jax.device_put(self.s.params, ns_rep)
        l_param_sh = sh.param_shardings(self.l.params, mesh, fsdp=False)
        self._l_params = jax.device_put(self.l.params, l_param_sh)
        l_core_sh = sh.paged_cache_shardings(self.l.cfg, mesh,
                                             self.lrt.pool.buffers)
        self.lrt.pool.buffers = jax.device_put(self.lrt.pool.buffers,
                                               l_core_sh)
        l_pool_sh = {"core": l_core_sh}
        if self.lrt.sharing:
            self.lrt.pool.prefix_buffers = jax.device_put(
                self.lrt.pool.prefix_buffers, ns_rep)
            l_pool_sh["prefix"] = jax.tree.map(
                lambda _: ns_rep, self.lrt.pool.prefix_buffers)
        self._s_pool = jax.tree.map(
            lambda s: jax.device_put(
                jnp.zeros((n_rep,) + s.shape, s.dtype), ns_data),
            self.srt.pool_operand())
        t_rows = self.lrt.admit_width
        self._stage = {"buf": jax.device_put(
            jnp.zeros((2, t_rows, self._admit_s_max), jnp.int32), ns_rep)}
        self._stage_tokens = np.zeros((t_rows, self._admit_s_max), np.int32)

        def s_body(s_params, theta, s_in, s_pool):
            tin = jax.tree.map(lambda a: a[0], s_in)
            pool = jax.tree.map(lambda a: a[0], s_pool)
            out, pool = s_tick(s_params, theta, tin, pool)
            return (jax.tree.map(lambda a: a[None], out),
                    jax.tree.map(lambda a: a[None], pool))

        # check_rep=False: the body is replicated over the (unused) model
        # axis; replication checking can't see that through the squeezes
        s_sharded = shard_map(s_body, mesh=mesh,
                              in_specs=(P(), P(), P("data"), P("data")),
                              out_specs=(P("data"), P("data")),
                              check_rep=False)

        def tick(s_params, l_params, theta, s_in, l_in, s_pool, l_pool,
                 stage):
            # tick top: copy this tick's escalation rows into the WRITE half
            # of the staging buffer.  Nothing on the S side depends on it,
            # so XLA schedules the transfer alongside the S lanes — the
            # S->L hop never sits on the critical path.  The admit lane
            # reads the OTHER half: rows staged LAST tick.
            wix = l_in["stage_wix"]
            buf = jax.lax.dynamic_update_slice(
                stage["buf"], l_in["stage_tokens"][None], (wix, 0, 0))
            read = jax.lax.dynamic_slice(
                buf, (1 - wix, 0, 0), (1,) + buf.shape[1:])[0]
            l_in = dict(l_in, admit_tokens=read[l_in["stage_row"]])
            s_out, s_pool = s_sharded(s_params, theta, s_in, s_pool)
            l_out, l_pool = l_tick(l_params, theta, l_in, l_pool)
            return ({"s": s_out, "l": l_out}, s_pool, l_pool, {"buf": buf})

        stack = partial(jax.tree.map, lambda a: jax.ShapeDtypeStruct(
            (n_rep,) + a.shape, a.dtype))
        l_in_spec = dict(
            spec(l_in0),
            stage_tokens=jax.ShapeDtypeStruct(
                (t_rows, self._admit_s_max), jnp.int32),
            stage_row=jax.ShapeDtypeStruct((t_rows,), jnp.int32),
            stage_wix=jax.ShapeDtypeStruct((), jnp.int32))
        stage_sh = {"buf": ns_rep}
        in_sh = (ns_rep, l_param_sh, ns_rep, ns_data, ns_rep,
                 ns_data, l_pool_sh, stage_sh)
        out_sh = ({"s": ns_data, "l": ns_rep}, ns_data, l_pool_sh, stage_sh)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            self._exec = jax.jit(
                tick, donate_argnums=(5, 6, 7), in_shardings=in_sh,
                out_shardings=out_sh).lower(
                    spec(self.s.params), spec(self.l.params),
                    jax.ShapeDtypeStruct((), jnp.float32),
                    stack(s_in0), l_in_spec,
                    stack(self.srt.pool_operand()),
                    spec(self.lrt.pool_operand()),
                    spec(self._stage)).compile()

    def set_faults(self, faults: Optional[FaultSchedule] = None,
                   policy: Optional[RetryPolicy] = None,
                   validate: Optional[bool] = None) -> None:
        """Install the fault schedule / retry policy / per-tick invariant
        checking for subsequent ``run`` calls.  Host-side only: the compiled
        tick executable is untouched (fault windows are RUN-relative ticks —
        each ``run`` re-anchors tick 0, so a schedule replays identically on
        a reused scheduler)."""
        if faults is not None:
            if self.speculative and faults.active:
                raise ValueError(
                    "fault injection models the S->L escalation QUEUE; "
                    "speculative mode has no L queue (escalation is fused "
                    "into the tick)")
            self.faults = faults
        if policy is not None:
            self.policy = policy
        if validate is not None:
            self.validate = bool(validate)

    def set_telemetry(self, tel: Optional[Telemetry]) -> None:
        """Install (``Telemetry``) or remove (``None``) the telemetry
        collector for subsequent ``run`` calls.  Host-side only — never part
        of the compile key, so toggling it never recompiles; disabled is the
        zero-overhead default (each hook is one ``is None`` branch)."""
        self.tel = tel
        if tel is not None:
            tel.counters = self.counters
            tel.audit = self.aud

    def set_audit(self, aud) -> None:
        """Install (``GateAudit``) or remove (``None``) the gate audit
        stream.  Host-side only — the confidences it consumes already come
        back in the tick's single ``_host_fetch``, so enabling it adds zero
        syncs and never recompiles (``stream_compiles == 1`` holds)."""
        self.aud = aud
        if self.tel is not None:
            self.tel.audit = aud

    def set_watchdog(self, wd) -> None:
        """Install (``SLOWatchdog``) or remove (``None``) the once-per-tick
        SLO evaluation.  Breaches emit telemetry instant events and trigger
        the flight recorder when those collectors are installed."""
        self.wd = wd

    def set_flight_recorder(self, fr) -> None:
        """Install (``FlightRecorder``) or remove (``None``) the bounded
        tick-snapshot ring.  Dumps fire on watchdog breach, breaker-open,
        ``check_invariants`` failure, and the idle-tick stall bound."""
        self.fr = fr

    def set_default_temperature(self, temperature: float) -> None:
        """Engine-level sampling temperature used for requests that don't set
        their own (Request.temperature > 0 wins) — keeps ``serve_stream``
        consistent with ``serve``'s engine-wide temperature."""
        for rt in self.srts:
            rt.default_temp = float(temperature)
        self.lrt.default_temp = float(temperature)

    @property
    def prefix_stats(self) -> Dict[str, int]:
        """Cumulative prefix-cache counters summed over both tiers: hits /
        full_hits / tokens_saved / cow_copies / evictions."""
        agg: Dict[str, int] = {}
        for rt in (*self.srts, self.lrt):
            for k, v in rt.pool.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    # -- host loop ----------------------------------------------------------

    def _dispatch(self, theta_j):
        """Build the tick operands, run the ONE compiled executable, store
        the donated pools back, and host-fetch the outputs (the tick's single
        sync)."""
        from repro.serving import engine as engine_mod   # _host_fetch hook

        if self._mesh is not None:
            return self._dispatch_mesh(theta_j)
        tel = self.tel
        s_in = self.srt.tick_inputs(self._admit_s_max)
        l_in = self.lrt.tick_inputs(self._admit_s_max)
        if tel is not None:
            tel.mark("build_operands")
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            out, s_pool, l_pool = \
                self._exec(self.s.params, self.l.params, theta_j,
                           s_in, l_in, self.srt.pool_operand(),
                           self.lrt.pool_operand())
        self.srt.store_pool(s_pool)
        self.lrt.store_pool(l_pool)
        if tel is not None:
            tel.mark("dispatch")
        host = engine_mod._host_fetch(out)   # the tick's single sync
        if tel is not None:
            tel.mark("host_fetch")
        self.counters.ticks += 1
        return host

    def _prepare_stage(self, l_queue, cur: int) -> None:
        """Stage up to ``lrt.admit_width`` head-of-queue escalations for the
        NEXT tick's L admit lane: their (padded) prompt tokens are copied
        into the staging buffer's write half inside THIS tick's dispatch, so
        the transfer overlaps this tick's S-side compute.  An escalation is
        re-staged every tick until admitted (its row may move); the host
        remembers rid -> row for the gate in ``_try_admit``."""
        t_rows, s_max = self._stage_tokens.shape
        tokens = np.zeros((t_rows, s_max), np.int32)
        nxt: Dict[int, int] = {}
        for i, adm in enumerate(l_queue):
            if i >= t_rows:
                break
            n = min(adm.bucket, s_max)
            tokens[i, :n] = adm.tokens[:n]
            nxt[adm.request.request_id] = i
        self._stage_tokens = tokens
        self._staged_next = nxt
        self._stage_wix = cur % 2

    def _dispatch_mesh(self, theta_j):
        """Mesh-mode tick dispatch: stage the escalation transfer operands
        FIRST (tick top — the device copy into the write half has no S-side
        consumers, so it overlaps the same tick's prefill/decode), then stack
        the per-replica S operands over ``data`` and run the one executable.
        Still exactly ONE compile and ONE host fetch per tick per host."""
        from repro.serving import engine as engine_mod   # _host_fetch hook

        tel = self.tel
        t_rows = self.lrt.admit_width
        rows = np.zeros((t_rows,), np.int32)
        for row, slot in enumerate(self.lrt.admitted):
            rid = self.lrt.slot_req[slot].adm.request.request_id
            rows[row] = self._staged[rid]   # gate guarantees membership
        stage_in = jax.device_put(
            {"stage_tokens": self._stage_tokens, "stage_row": rows,
             "stage_wix": np.asarray(self._stage_wix, np.int32)},
            self._ns_rep)
        if tel is not None:
            tel.mark("transfer_overlap")
        # raw numpy leaves stacked host-side, then ONE sharded transfer per
        # tree — per-leaf jnp.stack + reshard dominated the tick wall time
        s_raw = [rt.tick_inputs(self._admit_s_max, raw=True)
                 for rt in self.srts]
        s_in = jax.device_put(
            jax.tree.map(lambda *xs: np.stack(xs), *s_raw), self._ns_data)
        l_in = self.lrt.tick_inputs(self._admit_s_max, raw=True)
        # the device-side staging buffer is the authoritative token source
        # for the L admit lane — zero the host copy so the transfer path is
        # load-bearing, not decorative
        l_in["admit_tokens"] = np.zeros_like(l_in["admit_tokens"])
        l_in = jax.device_put(l_in, self._ns_rep)
        l_in.update(stage_in)
        theta_j = jax.device_put(np.asarray(theta_j), self._ns_rep)
        if tel is not None:
            tel.mark("build_operands")
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            out, s_pool, l_pool, stage = self._exec(
                self._s_params, self._l_params, theta_j, s_in, l_in,
                self._s_pool, self.lrt.pool_operand(), self._stage)
        self._s_pool = s_pool
        self.lrt.store_pool(l_pool)
        self._stage = stage
        self._staged = self._staged_next   # write half becomes readable
        if tel is not None:
            tel.mark("dispatch")
        host = engine_mod._host_fetch(out)   # the tick's single sync
        if tel is not None:
            tel.mark("host_fetch")
        self.counters.ticks += 1
        return host

    def _gauges(self, l_queue_len: int = 0) -> Dict[str, float]:
        """Per-tick telemetry gauges — all host state the scheduler already
        holds, so sampling costs no device traffic."""
        g: Dict[str, float] = {}
        for rt in (*self.srts, self.lrt):
            for k, v in rt.pool.gauges().items():
                g[f"{k}@{rt.name}"] = v
            g[f"busy_slots@{rt.name}"] = rt.busy
        g["l_queue_depth"] = l_queue_len
        if self._mesh is not None:
            # transfer staging buffer: rows readable this tick (occupancy of
            # the read half) + the ping-pong write index — flight-recorder
            # snapshots carry these alongside the per-replica @S{r} gauges
            g["stage_occupancy"] = len(self._staged)
            g["stage_wix"] = self._stage_wix
            g["replicas"] = len(self.srts)
        if self._link is not None:
            g["esc_in_flight"] = self._link.pending
        if self._breaker is not None:
            g["breaker_state"] = self._breaker.state_id
        return g

    def _observe_tick(self, l_queue_len: int = 0) -> None:
        """End-of-tick observability fan-out: telemetry gauges (audit
        aggregates merged in — they become Chrome counter tracks), SLO
        watchdog evaluation, flight-recorder snapshot + dump triggers.  All
        host-side over state the tick already produced; with every collector
        ``None`` this is one branch."""
        tel, aud, wd, fr = self.tel, self.aud, self.wd, self.fr
        if tel is None and wd is None and fr is None:
            return
        tick = self.counters.ticks - 1       # the tick just dispatched
        gauges = self._gauges(l_queue_len)
        if aud is not None:
            gauges.update(aud.gauge_values())
        if tel is not None:
            tel.end_tick(gauges)
        breaches = [] if wd is None else \
            wd.evaluate(tick, tel=tel, audit=aud, gauges=gauges)
        if tel is not None:
            for b in breaches:
                tel.instant(f"slo_breach:{b['kind']}", **b)
        if fr is None:
            return
        # snapshot fields are deterministic functions of the request trace +
        # fault schedule; serve_time is wall clock and would break the
        # byte-identical dump guarantee
        counters = {k: v for k, v in self.stats.items() if k != "serve_time"}
        snap: Dict[str, Any] = {"tick": tick, "gauges": gauges,
                                "counters": counters}
        if fr.include_timings and tel is not None and tel.ticks:
            snap["phase_seconds"] = {
                p: round(t1 - t0, 9)
                for p, t0, t1 in tel.ticks[-1].segments}
        fr.record(snap)
        for b in breaches:
            fr.trigger(f"slo_breach:{b['kind']}", tick, b)
        if self._breaker is not None \
                and self._breaker.opens > self._opens_seen:
            self._opens_seen = self._breaker.opens
            fr.trigger("breaker_open", tick,
                       {"opens": self._breaker.opens,
                        "opened_tick": self._breaker.opened_tick})

    def run(self, queue: AdmissionQueue, *, theta: Optional[float] = None
            ) -> Dict[int, Dict[str, Any]]:
        """Drain ``queue`` through the slots; returns per-request records
        keyed by request_id: tokens / s_tokens / confidence / offloaded /
        served_remote / dropped / status / escalation_retries /
        queue_wait_ticks / ttft (mirroring ``HIEngine.serve``'s fields, plus
        the speculative block accounting when enabled).  Every submitted
        request terminates with exactly one record whose ``status`` is one of
        ``faults.STATUSES`` — under ANY fault schedule installed via
        :meth:`set_faults`.  Ticks that only advance host-side timers
        (backoff, breaker cooldown, fault windows, admission retries)
        dispatch the same compiled executable with every lane skipped, so
        ``stats['compiles']`` stays at 1.

        ``stats['serve_time']`` accounting is SINGLE-ENTRY: one
        ``try/finally`` brackets the whole drain, so every exit path (normal
        completion, the speculative early return, the stall RuntimeError)
        adds the elapsed time exactly once — the old per-path additions
        could in principle double-book (tests/test_telemetry.py regresses
        this)."""
        t0 = time.perf_counter()
        try:
            return self._run(queue, theta)
        finally:
            self.counters.serve_time += time.perf_counter() - t0

    def _run(self, queue: AdmissionQueue, theta: Optional[float]
             ) -> Dict[int, Dict[str, Any]]:
        theta = float(self.hi.theta if theta is None else theta)
        theta_j = jnp.asarray(theta, jnp.float32)
        results: Dict[int, Dict[str, Any]] = {}
        tel = self.tel
        self._run_theta = theta
        self._eff_theta = theta

        if self.speculative:
            while len(queue) or self.srt.busy:
                if tel is not None:
                    tel.begin_tick(self.counters.ticks)
                self._try_admit_spec(queue, results)
                if tel is not None:
                    tel.mark("fault_tick")   # admission bookkeeping bucket
                host = self._dispatch(theta_j)
                self._absorb_spec(host, results)
                if tel is not None:
                    tel.mark("postprocess")
                self._observe_tick()
            return results

        # per-run fault state: run-relative tick 0 anchors here, so a seeded
        # FaultSchedule replays identically on a reused scheduler
        theta_fail_j = jnp.asarray(FAIL_LOCAL_THETA, jnp.float32)
        self._tick0 = self.counters.ticks
        self._link = EscalationLink(self.faults, self.policy)
        self._breaker = CircuitBreaker(self.policy)
        self._esc_meta = {}
        self._probe = None
        self._opens_seen = 0
        # mesh mode: the staging pipeline re-anchors per run — nothing from
        # an earlier run's buffer halves is readable
        self._staged = {}
        self._staged_next = {}
        stall, idle = self._stall_limit(), 0
        l_queue: deque = deque()
        while (len(queue) or l_queue or any(rt.busy for rt in self.srts)
               or self.lrt.busy or self._link.pending):
            if tel is not None:
                tel.begin_tick(self.counters.ticks)
            cur = self.counters.ticks - self._tick0
            state = self._breaker.state_at(cur)
            if state == CircuitBreaker.OPEN:
                self.counters.breaker_open_ticks += 1
            else:
                if state == CircuitBreaker.CLOSED:
                    self._probe = None
            self._fault_tick(cur, l_queue, results)
            for rt in self.srts:
                self._try_admit(rt, queue,
                                on_give_up=lambda adm: self._reject(adm,
                                                                    results))
            self._drop_expired(l_queue, results, cur)
            # mesh mode gates L admission on the staging pipeline: only
            # escalations whose tokens were staged LAST tick (readable from
            # the buffer's read half this tick) may admit — the +1 tick is
            # the modelled DCN hop, paid off the critical path
            ready = None if self._mesh is None else \
                (lambda adm: adm.request.request_id in self._staged)
            self._try_admit(self.lrt, l_queue, limit=self._l_admit_limit(cur),
                            on_give_up=lambda adm: self._l_give_up(adm, cur,
                                                                   results),
                            ready=ready)
            if self._mesh is not None:
                self._prepare_stage(l_queue, cur)
            for slot in range(self.lrt.num_slots):
                rec = self.lrt.slot_req[slot]
                if rec is None:
                    continue
                esc = self._esc_meta.get(rec.adm.request.request_id)
                if esc is not None and esc.l_admit_tick < 0:
                    esc.l_admit_tick = cur
                    if self._breaker.state == CircuitBreaker.HALF_OPEN \
                            and self._probe is None:
                        self._probe = esc.rid
            if tel is not None:
                tel.mark("fault_tick")   # fault machinery + admission
            s_busy = any(rt.busy for rt in self.srts)
            if not (len(queue) or l_queue or s_busy or self.lrt.busy
                    or self._link.pending):
                break                  # everything left resolved host-side
            if (s_busy or self.lrt.busy
                    or any(rt.admitted for rt in self.srts)
                    or self.lrt.admitted):
                idle = 0
            else:
                # a pure timer tick: backoff / cooldown / fault window /
                # admission retry.  Legitimate and bounded — the limit only
                # trips on a genuinely unbounded schedule or policy.
                idle += 1
                if idle > stall:
                    if self.fr is not None:
                        self.fr.trigger("stall", cur, {
                            "idle_ticks": idle, "queue": len(queue),
                            "l_queue": len(l_queue),
                            "in_flight": self._link.pending})
                    raise RuntimeError(
                        f"scheduler stalled: {idle} consecutive idle ticks "
                        f"with work pending (queue={len(queue)}, "
                        f"l_queue={len(l_queue)}, "
                        f"in_flight={self._link.pending})")
            open_now = self._breaker.state == CircuitBreaker.OPEN
            self._eff_theta = FAIL_LOCAL_THETA if open_now else theta
            host = self._dispatch(theta_fail_j if open_now else theta_j)
            if self._mesh is None:
                self._absorb(self.srt, host["s"],
                             lambda rec: self._finish_s(rec, theta, results))
            else:
                # host["s"] leaves carry the stacked replica axis: each
                # replica absorbs its own slice of the fetched outputs
                for r, rt in enumerate(self.srts):
                    self._absorb(rt,
                                 jax.tree.map(lambda a, _r=r: a[_r],
                                              host["s"]),
                                 lambda rec: self._finish_s(rec, theta,
                                                            results))
            self._absorb(self.lrt, host["l"],
                         lambda rec: self._finish_l(rec, results))
            if self.validate:
                try:
                    for rt in self.srts:
                        rt.pool.check_invariants()
                    self.lrt.pool.check_invariants()
                except AssertionError as e:
                    if self.fr is not None:
                        self.fr.trigger("invariant_failure", cur,
                                        {"error": str(e)})
                    raise
            if tel is not None:
                tel.mark("postprocess")
            self._observe_tick(len(l_queue))

        self.counters.esc_lost += self._link.lost
        self.counters.breaker_opens += self._breaker.opens
        return results

    # -- fault machinery (host-side; see serving/faults.py) -----------------

    def _stall_limit(self) -> int:
        """Upper bound on CONSECUTIVE idle (timer-only) ticks any bounded
        schedule + policy can produce; past it the run is genuinely stuck."""
        p = self.policy
        span = max([b for _, b in self.faults.outages + self.faults.spikes],
                   default=0)
        return (p.admit_retry_limit + p.breaker_cooldown_ticks
                + (p.max_retries + 2) * (p.ack_timeout_ticks
                                         + p.backoff_cap_ticks) + span + 64)

    def _l_admit_limit(self, cur: int) -> Optional[int]:
        """How many escalations L admission may take this tick: 0 while the
        L tier is paused (outage / spike / open breaker), 1 while half-open
        with no probe outstanding, unlimited when closed."""
        if self.faults.l_paused(cur):
            return 0
        state = self._breaker.state
        if state == CircuitBreaker.OPEN:
            return 0
        if state == CircuitBreaker.HALF_OPEN:
            return 0 if self._probe is not None else 1
        return None

    def _fault_tick(self, cur: int, l_queue: deque, results: Dict) -> None:
        """Advance the transport sim one tick: outage aborts first (busy L
        slots release their pages through ``KVPool.free`` — leak-free — and
        queued escalations fail), then arrivals / ack timeouts, then due
        resends (held while the breaker is open)."""
        link = self._link
        if self.faults.in_outage(cur):
            for slot in range(self.lrt.num_slots):
                if self.lrt.slot_req[slot] is not None:
                    rec = self.lrt.release(slot)
                    if self.tel is not None:
                        self.tel.req_l_release(rec.adm.request.request_id,
                                               "outage_abort")
                    self._esc_failed(
                        self._esc_meta[rec.adm.request.request_id], cur,
                        results)
            while l_queue:
                adm = l_queue.popleft()
                self._esc_failed(self._esc_meta[adm.request.request_id],
                                 cur, results)
        arrived, failed = link.step(cur)
        for esc in arrived:
            if self.tel is not None:
                self.tel.req_esc_end(esc.rid, "arrived")
            l_queue.append(esc.adm)
        for esc in failed:
            self._esc_failed(esc, cur, results)
        if self._breaker.state != CircuitBreaker.OPEN:
            for esc in link.due_resends(cur):
                link.take(esc)
                if self._budget_expired(esc.adm):
                    self._degrade(esc, cur, results)  # too late to retry
                else:
                    link.send(esc, cur)
                    if self.tel is not None:
                        self.tel.req_esc_send(esc.rid, -1, esc.attempt)

    @staticmethod
    def _budget_expired(adm: AdmittedRequest) -> bool:
        budget = adm.request.latency_budget
        return (budget is not None
                and time.monotonic() - adm.submit_time > budget)

    def _esc_failed(self, esc, cur: int, results: Dict) -> None:
        """One escalation attempt failed (lost, timed out, or outage-
        aborted): count it against the breaker, then retry with capped
        exponential backoff — or give up when retries are exhausted or the
        latency budget says the answer would arrive too late."""
        self._breaker.record_failure(cur)
        if self.tel is not None:
            self.tel.req_esc_end(esc.rid, "failed")
        if self._probe == esc.rid:
            self._probe = None
        if esc.attempt >= self.policy.max_retries \
                or self._budget_expired(esc.adm):
            self._degrade(esc, cur, results)
        else:
            self._link.schedule_retry(esc, cur)
            self.counters.esc_retries += 1

    def _degrade(self, esc, cur: int, results: Dict) -> None:
        """Give up on the escalation: the S-tier answer (already recorded)
        stands, flagged ``status='degraded_local'``."""
        self._esc_meta.pop(esc.rid, None)
        self.counters.degraded_local += 1
        rec = results[esc.rid]
        rec["status"] = "degraded_local"
        rec["escalation_retries"] = esc.attempt
        rec["queue_wait_ticks"] = max(cur - esc.created_tick, 0)
        if self.tel is not None:
            self.tel.req_terminal(esc.rid, rec)

    def _l_give_up(self, adm: AdmittedRequest, cur: int,
                   results: Dict) -> None:
        """L-tier admission starvation past the retry cap: the S answer
        exists, so degrade rather than reject."""
        esc = self._esc_meta.get(adm.request.request_id)
        if esc is not None:
            self._degrade(esc, cur, results)

    def _reject(self, adm: AdmittedRequest, results: Dict) -> None:
        """Bounded admission backpressure: after ``admit_retry_limit``
        fruitless ticks the request fails outright with
        ``status='rejected'`` — the bounded replacement for the old
        "scheduler stalled" RuntimeError, which an unsatisfiable page demand
        (prompt larger than the whole pool) used to hit."""
        self.counters.requests += 1
        self.counters.rejected += 1
        warnings.warn(
            f"request {adm.request.request_id} rejected: admission failed "
            f"{adm.admit_retries} ticks running (bucket {adm.bucket} needs "
            "more free pages than the pool can produce) — raise num_pages / "
            "num_slots or shrink the prompt", RuntimeWarning, stacklevel=3)
        results[adm.request.request_id] = {
            "tokens": np.zeros((0,), np.int32),
            "s_tokens": np.zeros((0,), np.int32),
            "confidence": 0.0,
            "offloaded": False,
            "served_remote": False,
            "dropped": False,
            "status": "rejected",
            "escalation_retries": 0,
            "queue_wait_ticks": 0,
            "esc_created_tick": -1,
            "ttft": float("nan"),
        }
        if self.tel is not None:
            self.tel.req_terminal(adm.request.request_id,
                                  results[adm.request.request_id])

    # -- admission / completion -------------------------------------------

    def _try_admit(self, rt: _TierRuntime, queue, limit: Optional[int] = None,
                   on_give_up=None, ready=None) -> None:
        """Admit up to ``admit_width`` queued requests into free slots.
        ``queue`` is the AdmissionQueue (S tier) or the escalation deque
        (L tier); both speak the same popleft/appendleft head interface.
        ``limit`` caps this tick's admissions (0 = the L tier is paused —
        outage / spike / open breaker; 1 = the half-open probe).  ``ready``
        (mesh mode) gates on the staging pipeline: admission stops at the
        first head entry whose tokens are not yet readable from the
        transfer buffer.  A request that keeps failing admission hands off
        to ``on_give_up`` after ``RetryPolicy.admit_retry_limit`` fruitless
        ticks instead of re-queueing forever (bounded backpressure)."""
        rt.admitted = []
        rt.plans = []
        if limit == 0:
            return
        tick = self.counters.ticks
        cap = rt.admit_width if limit is None else min(rt.admit_width, limit)
        admitted = 0
        while admitted < cap and len(queue):
            if rt.free_slot() is None:
                break
            if ready is not None and not ready(queue[0]):
                break
            adm = queue.popleft()
            steps = min(adm.request.max_new_tokens, self.max_new_tokens)
            slot = rt.admit(adm, steps, self.decode_block, tick)
            if slot is None:
                adm.admit_retries += 1
                if on_give_up is not None and \
                        adm.admit_retries > self.policy.admit_retry_limit:
                    on_give_up(adm)     # head cleared: try the next request
                    continue
                queue.appendleft(adm)   # no pages this tick: retry next tick
                break
            if self.tel is not None:
                self.tel.req_admitted(rt.name, slot, adm.request.request_id,
                                      adm.submit_time,
                                      chunked=bool(rt.chunk_left[slot]))
            admitted += 1

    def _try_admit_spec(self, queue, results: Dict) -> None:
        """Speculative admission: both tiers claim the SAME slot index for a
        request (strict pairing — the verify chunk addresses the L pool by
        the S slot's id), prefill both caches through their admit lanes."""
        srt, lrt = self.srt, self.lrt
        srt.admitted, srt.plans = [], []
        lrt.admitted, lrt.plans = [], []
        tick = self.counters.ticks
        admitted = 0
        while admitted < srt.admit_width and len(queue):
            slot = srt.free_slot()
            if slot is None:
                break
            assert lrt.slot_req[slot] is None, "spec slot pairing broken"
            adm = queue.popleft()
            steps = min(adm.request.max_new_tokens, self.max_new_tokens)
            if srt.admit(adm, steps, self.decode_block, tick) is None:
                adm.admit_retries += 1
                if adm.admit_retries > self.policy.admit_retry_limit:
                    self._reject(adm, results)
                    continue
                queue.appendleft(adm)
                break
            if lrt.admit(adm, steps, self.decode_block, tick) is None:
                # roll the S-side admission back and retry next tick: drop
                # any same-tick prefix-index registrations first (their pages
                # will never be prefilled now — a later lookup must not alias
                # them), then free the slot
                if srt.admitted and srt.admitted[-1] == slot:
                    srt.admitted.pop()
                    srt.plans.pop()
                if srt.sharing:
                    srt.pool.retract(slot, adm.page_hashes, adm.full_hash,
                                     tick)
                srt.release(slot)
                adm.admit_retries += 1
                if adm.admit_retries > self.policy.admit_retry_limit:
                    self._reject(adm, results)
                    continue
                queue.appendleft(adm)
                break
            if self.tel is not None:
                self.tel.req_admitted("S", slot, adm.request.request_id,
                                      adm.submit_time,
                                      chunked=bool(srt.chunk_left[slot]))
            admitted += 1

    def _drop_expired(self, l_queue: deque, results: Dict,
                      cur: int = 0) -> None:
        """arXiv:2112.11413 drop policy: an escalation whose request has
        outlived its latency budget is dropped from the L queue — the S-tier
        answer (already recorded) stands, flagged ``dropped``.  Nothing else
        to release: the L-tier prefix lookup and page claim both happen at
        ADMISSION (``rt.admit``), so a QUEUED escalation holds no L-side
        resources — the drop touches the record and counters only
        (tests/test_faults.py asserts pool invariants under repeated
        drops)."""
        if not l_queue:
            return
        now = time.monotonic()
        kept: List[AdmittedRequest] = []
        while l_queue:
            adm = l_queue.popleft()
            budget = adm.request.latency_budget
            if budget is not None and now - adm.submit_time > budget:
                self.counters.dropped += 1
                esc = self._esc_meta.pop(adm.request.request_id, None)
                rec = results.get(adm.request.request_id)
                if rec is not None:
                    rec["dropped"] = True
                    rec["status"] = "dropped"
                    if esc is not None:
                        rec["escalation_retries"] = esc.attempt
                        rec["queue_wait_ticks"] = max(
                            cur - esc.created_tick, 0)
                    if self.tel is not None:
                        self.tel.req_terminal(adm.request.request_id, rec)
            else:
                kept.append(adm)
        l_queue.extend(kept)

    def _absorb_chunk(self, rt: _TierRuntime, out, emit: bool) -> None:
        """Advance the tick's scheduled chunk-prefill rows; finishing rows
        optionally emit their chunk-sampled token 0 (the S tier emits, the
        paired L tier in speculative mode only advances bookkeeping)."""
        for row, (slot, keep, fin) in enumerate(rt.chunk_sched):
            rt.chunk_fed[slot] += keep
            rt.chunk_left[slot] -= keep
            if self.tel is not None:
                self.tel.req_chunk(rt.name, slot,
                                   rt.slot_req[slot].adm.request.request_id,
                                   fed=keep, keep=int(rt.chunk_left[slot]))
            if fin and emit:
                rec = rt.slot_req[slot]
                if self.aud is not None and not rec.done:
                    self.aud.decision(
                        rid=rec.adm.request.request_id, tier=rt.name,
                        tclass=rec.adm.request.tclass, kind="chunk",
                        conf=float(out["chunk_conf"][row]),
                        theta=self._eff_theta)
                rec.emit(out["chunk_tok"][row], out["chunk_conf"][row])

    def _absorb(self, rt: _TierRuntime, out: Dict[str, np.ndarray],
                finish) -> None:
        aud = self.aud
        for row, slot in enumerate(rt.admitted):
            rec = rt.slot_req[slot]
            if aud is not None and not rec.done:
                aud.decision(rid=rec.adm.request.request_id, tier=rt.name,
                             tclass=rec.adm.request.tclass, kind="admit",
                             conf=float(out["admit_conf"][row]),
                             theta=self._eff_theta)
            rec.emit(out["admit_tok"][row], out["admit_conf"][row])
        if self.chunk:
            self._absorb_chunk(rt, out, emit=True)
        k_steps = out["toks"].shape[0]
        for slot in range(rt.num_slots):
            rec = rt.slot_req[slot]
            if rec is None:
                continue
            if self.chunk and rt.chunk_left[slot] > 0:
                continue               # still chunk-prefilling: no decode
            for k in range(k_steps):
                if aud is not None and not rec.done:
                    aud.decision(rid=rec.adm.request.request_id,
                                 tier=rt.name,
                                 tclass=rec.adm.request.tclass,
                                 kind="decode",
                                 conf=float(out["confs"][k][slot]),
                                 theta=self._eff_theta)
                rec.emit(out["toks"][k][slot], out["confs"][k][slot])
            rt.last_tok[slot] = int(out["toks"][k_steps - 1][slot])
            rt.tok_idx[slot] += k_steps
            rt.pos[slot] += k_steps
            if self.tel is not None:
                self.tel.req_decode(rt.name, slot,
                                    rec.adm.request.request_id, k_steps)
            if rec.done:
                finish(rt.release(slot))

    def _absorb_spec(self, host: Dict, results: Dict) -> None:
        """Fused-cascade absorb: per decoding slot the L verify decided how
        many draft tokens stand (``accept``), which block input boundary both
        caches keep (``keep``) and what to emit (``n_emit`` of ``toks``).
        The host rewinds positions by the rejected tail and asserts the
        rewind is COW-safe (``KVPool.truncate``)."""
        s, l = host["s"], host["l"]
        srt, lrt = self.srt, self.lrt
        k = self.decode_block
        for row, slot in enumerate(srt.admitted):
            srt.slot_req[slot].emit(s["admit_tok"][row], s["admit_conf"][row])
        if self.chunk:
            self._absorb_chunk(srt, s, emit=True)
            self._absorb_chunk(lrt, s, emit=False)
        for slot in range(srt.num_slots):
            rec = srt.slot_req[slot]
            if rec is None:
                continue
            if self.chunk and srt.chunk_left[slot] > 0:
                continue               # still chunk-prefilling: no decode
            n = int(l["n_emit"][slot])
            keep = int(l["keep"][slot])
            esc = bool(l["esc"][slot])
            rec.rounds.append((esc, n))
            self.counters.blocks += 1
            self.counters.drafted += k
            self.counters.accepted += int(l["accept"][slot])
            if esc:
                self.counters.escalated_blocks += 1
            if self.tel is not None:
                rid = rec.adm.request.request_id
                self.tel.req_decode("S", slot, rid, n)
                if esc:
                    self.tel.req_l_verify(slot, rid,
                                          int(l["accept"][slot]), n)
            if self.aud is not None:
                # verify-lane feedback: the block-level gate decision plus
                # FREE per-position ground truth (L re-derived every drafted
                # position greedily, escalated or not)
                rid_a = rec.adm.request.request_id
                tclass = rec.adm.request.tclass
                dc = l["draft_confs"][slot]
                mt = l["match"][slot]
                self.aud.decision(rid=rid_a, tier="S", tclass=tclass,
                                  kind="block", conf=float(dc[:k].min()),
                                  theta=self._eff_theta, offload=esc)
                for j in range(n):     # emitted positions (n <= k; the
                    #                    rolled-back tail is re-drafted and
                    #                    would double-count its positions)
                    self.aud.outcome(rid=rid_a, tier="L", tclass=tclass,
                                     conf=float(dc[j]),
                                     theta=self._eff_theta,
                                     ok=bool(mt[j]), kind="draft")
            for j in range(n):
                rec.emit(l["toks"][slot][j], l["confs"][slot][j])
            last = int(l["toks"][slot][max(n - 1, 0)])
            for rt in (srt, lrt):
                rt.pos[slot] += keep
                rt.tok_idx[slot] += n
                rt.last_tok[slot] = last
                if keep < k:
                    # the rejected tail is rolled back: assert the rewound
                    # write position can never reach a shared page
                    rt.pool.truncate(slot, int(rt.pos[slot]))
            if rec.done:
                self._finish_spec(srt.release(slot), results)
                lrt.release(slot)

    def _finish_s(self, rec: _Active, theta: float, results: Dict) -> None:
        """S decode finished: record the local answer, and when the gate
        fires send the escalation across the (possibly faulty) ED↔ES link.
        ``offloaded`` records INTENT (``conf < theta`` with the REAL theta)
        even in fail-local mode — the degradation is visible in ``status``,
        not hidden by a rewritten gate decision."""
        conf = float(np.mean(np.asarray(rec.confs, np.float32)))
        rid = rec.adm.request.request_id
        self.counters.requests += 1
        if self.aud is not None:
            # the request-level escalation decision: REAL theta (intent
            # semantics, matching ``offloaded`` — fail-local degradation is
            # visible in ``status``, not a rewritten gate decision)
            self.aud.decision(rid=rid, tier="S",
                              tclass=rec.adm.request.tclass, kind="request",
                              conf=conf, theta=theta)
        results[rid] = {
            "tokens": np.asarray(rec.tokens, np.int32),
            "s_tokens": np.asarray(rec.tokens, np.int32),
            "confidence": conf,
            "offloaded": conf < theta,
            "served_remote": False,
            "dropped": False,
            "status": "ok",
            "escalation_retries": 0,
            "queue_wait_ticks": 0,
            "esc_created_tick": -1,      # -1 = never escalated
            "ttft": rec.ttft,
        }
        if conf >= theta:
            if self.tel is not None:      # never escalates: final status
                self.tel.req_terminal(rid, results[rid])
            return
        self.counters.offloaded += 1
        cur = self.counters.ticks - self._tick0
        results[rid]["esc_created_tick"] = cur
        esc = Escalation(rec.adm, rid, cur)
        if self._breaker.state == CircuitBreaker.OPEN:
            # fail-local: the breaker is open, nothing crosses the link —
            # the request degrades immediately (no retries to burn)
            self._degrade(esc, cur, results)
            return
        rec.adm.admit_retries = 0   # L admission gets a fresh retry budget
        self._esc_meta[rid] = esc
        self._link.send(esc, cur)
        if self.tel is not None:
            self.tel.req_esc_send(rid, -1, esc.attempt)

    def _finish_l(self, rec: _Active, results: Dict) -> None:
        rid = rec.adm.request.request_id
        out = results[rid]
        out["tokens"] = np.asarray(rec.tokens, np.int32)
        out["served_remote"] = True
        out["status"] = "ok"
        if self.aud is not None:
            # plain-mode ground truth: one agreement sample per completed
            # escalation — did the S answer match what L produced?
            st, lt = out["s_tokens"], out["tokens"]
            m = min(len(st), len(lt))
            ok = m > 0 and bool(np.array_equal(st[:m], lt[:m]))
            self.aud.outcome(rid=rid, tier="L",
                             tclass=rec.adm.request.tclass,
                             conf=out["confidence"], theta=self._run_theta,
                             ok=ok, kind="l_agree")
        esc = self._esc_meta.pop(rid, None)
        if esc is not None:
            cur = self.counters.ticks - self._tick0
            out["escalation_retries"] = esc.attempt
            out["queue_wait_ticks"] = max(
                (esc.l_admit_tick if esc.l_admit_tick >= 0 else cur)
                - esc.created_tick, 0)
            self._breaker.record_success()
            if self._probe == rid:
                self._probe = None
        if self.tel is not None:
            self.tel.req_terminal(rid, out)

    def _finish_spec(self, rec: _Active, results: Dict) -> None:
        rid = rec.adm.request.request_id
        self.counters.requests += 1
        escalated = sum(1 for esc, _ in rec.rounds if esc)
        if escalated:
            self.counters.offloaded += 1
        if self.aud is not None:
            self.aud.decision(
                rid=rid, tier="S", tclass=rec.adm.request.tclass,
                kind="request",
                conf=float(np.mean(np.asarray(rec.confs, np.float32)))
                if rec.confs else 1.0,
                theta=self._run_theta, offload=escalated > 0)
        results[rid] = {
            "tokens": np.asarray(rec.tokens, np.int32),
            "s_tokens": np.asarray(rec.tokens, np.int32),
            "confidence": float(np.mean(np.asarray(rec.confs, np.float32)))
            if rec.confs else 1.0,
            "offloaded": escalated > 0,
            "served_remote": False,
            "dropped": False,
            "status": "ok",
            "escalation_retries": 0,
            "queue_wait_ticks": 0,
            "esc_created_tick": -1,      # the fused cascade has no L queue
            "ttft": rec.ttft,
            "rounds": list(rec.rounds),
            "blocks": len(rec.rounds),
            "escalated_blocks": escalated,
        }
        if self.tel is not None:
            self.tel.req_terminal(rid, results[rid])
