"""Continuous-batching scheduler over the paged KV pool.

The drain-path ``HIEngine.serve`` admits a whole (B, bucket) batch, runs the
cascade, and only then admits the next batch: a finished sequence's slot idles
until the SLOWEST sequence in its batch finishes, and every bucket compiles
its own executable with its own donated cache.  This module replaces batch
draining with SLOT-level admission (Orca-style iteration scheduling; see the
online-HI line of work, arXiv:2304.00891, for the per-sample admission model):

* Each tier owns ``num_slots`` decode slots backed by ONE :class:`KVPool`.
* Every scheduler *tick* is ONE device dispatch of one AOT-compiled program —
  the SAME program regardless of prompt bucket — that, per tier, (a) executes
  the admission plan's copy-on-write page duplications, (b) admits up to
  ``admit_width`` queued requests in one batched (A, S_max) prefill into
  their pages (``lax.cond``: skipped at runtime when every admission is a
  full-prefix RESTORE — the prefix cache's throughput win), and (c) runs
  ``decode_block`` fused decode steps for ALL slots at per-slot positions
  (a ``lax.scan``, like the drain path's fused decode).
* Host sync happens exactly once per tick, post-cascade, through the
  engine's ``_host_fetch`` — the drain path's single-sync discipline at tick
  granularity.
* A sequence frees its slot the moment it finishes (EOS or its OWN
  max-new-tokens); if its mean confidence fell below theta it re-queues onto
  the L tier's admission queue (the S→L escalation), otherwise the S result
  is final.  Decode steps a released slot computed past its request's end
  are discarded on the host (bounded by ``decode_block - 1``).

Prefix sharing (``prefix_entries > 0``) changes admission, not decode: the
pool aliases the longest content-hash-matched prefix of each prompt into the
new slot's block row (refcount bump, read-only), the admit lane prefills
ONLY the uncached suffix (``prefill_paged(..., start)``), and a FULL-prompt
hit restores everything — pages, recurrent state, and last-position logits —
from the device-side prefix cache without touching the admit lane at all.
An admission that must append into a retained partial tail page gets a
copy-on-write duplicate (scheduled in the same tick's program), and the
decode write path takes a ``write_block`` table with shared pages masked to
the null page.  The L tier keeps its own pool and index, so repeated S→L
escalations of the same prompt skip the L prefill entirely.

The L-tier admission queue additionally enforces the time-constrained
offloading drop policy (Fresa & Champati, arXiv:2112.11413): an escalation
whose request has outlived its ``latency_budget`` is dropped — the S-tier
answer stands, ``stats["dropped"]`` counts it, and the result record is
flagged.

Outputs are TOKEN-IDENTICAL to the drain path on the same bucketized
prompts, for ANY ``admit_width``/``decode_block``, with prefix sharing ON or
OFF: admission prefill reads each row's logits at ``length - 1`` of the same
padded prompt (a suffix prefill splices the cached K/V — bitwise the values
its own full pass would compute — under the in-pass projections; a restore
replays logits the original admission computed), decode masks by position,
and sampling keys are per-request + per-token-index — none of it depends on
which slot, tick, or co-resident requests the sequence ran with.  One
caveat: MoE routed dispatch is batch-coupled (capacity drops depend on
co-admitted rows), so MoE prefix reuse is exact only up to routing-drop
determinism — with the generous decode-path ``capacity_factor`` drops are
absent on this reference and the equivalence tests hold; see
``moe.prefill_paged``.  ``tests/test_scheduler.py`` and
``tests/test_prefix_cache.py`` assert this end to end.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core.confidence import confidence as _confidence
from repro.models import model_zoo
from repro.serving import sampler
from repro.serving.batcher import AdmissionQueue, AdmittedRequest
from repro.serving.kv_pool import AdmitPlan, KVPool


def _tier_tick_fn(cfg: ModelConfig, metric: str, use_kernel: bool,
                  decode_block: int, sharing: bool):
    """Device-side per-tier tick: COW copies + batched cond-prefill +
    prefix-cache save/restore + K fused decode steps for all slots."""

    def conf_of(logits, theta):
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.hi_gate(logits, theta, metric=metric)[0]
        return _confidence(logits, metric)

    def tick(params, theta, tin, pool):
        core = pool["core"]
        a = tin["admit_tokens"].shape[0]

        if sharing:
            # copy-on-write duplications first: prefill reads and decode
            # appends must see the private copies.  Skipped at runtime on the
            # (common) no-COW tick — the kernel path in particular streams
            # the whole page pool, which would tax every steady-state tick.
            core = jax.lax.cond(
                tin["any_cow"],
                lambda c: model_zoo.cow_pages(cfg, c, tin["cow_src"],
                                              tin["cow_dst"],
                                              use_kernel=use_kernel),
                lambda c: c, core)

        def admit(core):
            return model_zoo.prefill_paged(
                params, cfg, tin["admit_tokens"], tin["admit_len"],
                tin["admit_slot"], tin["admit_blocks"], core,
                use_kernel=use_kernel,
                start=tin["admit_start"] if sharing else None)

        def skip(core):
            return jnp.zeros((a, cfg.vocab_size), jnp.float32), core

        # skipped when nothing is admitted — or (sharing) when every
        # admission this tick is a full-prefix restore
        logits0, core = jax.lax.cond(tin["any_prefill"], admit, skip, core)
        if sharing:
            prefix = pool["prefix"]
            # full restores read their admission logits + recurrent state
            # from the PRE-SAVE prefix cache: a restore's entry was filled in
            # an earlier tick, and reading before this tick's saves keeps a
            # same-tick eviction that recycles the restore's row (LRU under
            # row pressure) from corrupting the restored state
            logits0 = jnp.where(tin["restore_mask"][:, None],
                                prefix["logits"][tin["restore_row"]], logits0)
            core = model_zoo.snapshot_restore(cfg, core, prefix,
                                              tin["restore_row"],
                                              tin["restore_slot"])
            # computing admissions persist their logits + recurrent state
            # into their reserved rows (sentinel rows drop); computing slots
            # are disjoint from restored slots, so the gather below is
            # unaffected by the restore scatter above
            prefix = dict(prefix, logits=prefix["logits"].at[
                tin["save_row"]].set(logits0, mode="drop"))
            prefix = model_zoo.snapshot_save(cfg, core, prefix,
                                             tin["save_row"],
                                             tin["admit_slot"])
        conf0 = conf_of(logits0, theta)                          # (A,)
        keys0 = sampler.request_keys(tin["admit_seed"], 0)
        tok0 = sampler.sample(keys0, logits0, tin["admit_temp"])  # (A,)

        # admitted slots decode their own first tokens in the same tick;
        # padded admission rows carry an out-of-range slot -> dropped
        last0 = tin["last_tok"].at[tin["admit_slot"]].set(tok0, mode="drop")
        block = tin["block"]
        wblock = tin["wblock"] if sharing else None
        b = block.shape[0]

        def body(carry, k):
            last, core = carry
            logits, core = model_zoo.decode_step_paged(
                params, cfg, last[:, None], tin["pos"] + k, block, core,
                use_kernel=use_kernel, write_block=wblock)
            confs_k = conf_of(logits, theta)
            keys = sampler.request_keys(tin["seeds"], tin["tok_idx"] + k)
            toks_k = sampler.sample(keys, logits, tin["temps"])
            return (toks_k, core), (toks_k, confs_k)

        def decode(core):
            (_, core), (toks, confs) = jax.lax.scan(body, (last0, core),
                                                    jnp.arange(decode_block))
            return toks, confs, core

        def idle(core):
            # this tier has no live slots this tick (e.g. the L tier before
            # the first escalation arrives): skip the decode entirely
            return (jnp.zeros((decode_block, b), jnp.int32),
                    jnp.zeros((decode_block, b), jnp.float32), core)

        toks, confs, core = jax.lax.cond(tin["any_live"], decode, idle, core)
        out_pool = {"core": core, "prefix": prefix} if sharing \
            else {"core": core}
        return {"admit_tok": tok0, "admit_conf": conf0,
                "toks": toks, "confs": confs}, out_pool     # toks (K, B)

    return tick


@dataclass
class _Active:
    """One request occupying a decode slot."""
    adm: AdmittedRequest
    steps: int
    tokens: List[int] = field(default_factory=list)
    confs: List[float] = field(default_factory=list)
    hit_eos: bool = False

    def emit(self, tok: int, conf: float) -> None:
        if self.done:
            return
        self.tokens.append(int(tok))
        self.confs.append(float(conf))
        eos = self.adm.request.eos_id
        if eos is not None and int(tok) == eos:
            self.hit_eos = True

    @property
    def done(self) -> bool:
        return self.hit_eos or len(self.tokens) >= self.steps


class _TierRuntime:
    """Host-side slot state for one tier (numpy mirrors of tick operands)."""

    def __init__(self, cfg: ModelConfig, num_slots: int, max_context: int,
                 page_size: int, admit_width: int, dtype,
                 prefix_entries: int = 0, max_prompt_len: int = 0,
                 num_pages: Optional[int] = None):
        if num_pages is None:
            # sharing headroom: beyond every slot's full context, enough
            # pages to RETAIN prefix_entries full prompts without evicting
            # under load
            num_pages = num_slots * (max_context // page_size) + 1
            num_pages += prefix_entries * (-(-max_prompt_len // page_size))
        self.pool = KVPool(cfg, num_slots, max_context, page_size,
                           num_pages=num_pages, dtype=dtype,
                           prefix_entries=prefix_entries)
        self.sharing = prefix_entries > 0
        self.num_slots = num_slots
        self.admit_width = admit_width
        self.default_temp = 0.0      # engine-level fallback (Request wins)
        self.slot_req: List[Optional[_Active]] = [None] * num_slots
        self.last_tok = np.zeros((num_slots,), np.int32)
        self.pos = np.zeros((num_slots,), np.int32)
        self.seeds = np.zeros((num_slots,), np.int32)
        self.tok_idx = np.zeros((num_slots,), np.int32)
        self.temps = np.zeros((num_slots,), np.float32)
        self.admitted: List[int] = []    # slots admitted THIS tick, row order
        self.plans: List[AdmitPlan] = []  # aligned admission plans

    @property
    def busy(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def admit(self, adm: AdmittedRequest, steps: int, decode_block: int,
              tick: int) -> bool:
        """Claim a slot + pages for ``adm``; False if no capacity this tick.
        With sharing, the pool aliases the longest cached prefix and the
        returned plan carries start / restore / save / COW decisions."""
        slot = self.free_slot()
        # decode writes reach bucket + steps - 2, plus <= K-1 overrun steps
        context = adm.bucket + max(steps - 1, 1) + (decode_block - 1)
        if slot is None:
            return False
        if self.sharing:
            plan = self.pool.admit_prefix(slot, context, adm.bucket,
                                          adm.page_hashes, adm.full_hash,
                                          tick)
            if plan is None:
                return False
        else:
            if not self.pool.can_alloc(context):
                return False
            self.pool.alloc(slot, context)
            plan = AdmitPlan(slot=slot)
        self.slot_req[slot] = _Active(adm, steps)
        self.pos[slot] = adm.bucket
        self.seeds[slot] = adm.request.request_id
        self.tok_idx[slot] = 1                 # token 0 comes from the prefill
        self.temps[slot] = (adm.request.temperature
                            if adm.request.temperature > 0
                            else self.default_temp)
        self.last_tok[slot] = 0                # replaced on-device by tok0
        self.admitted.append(slot)
        self.plans.append(plan)
        return True

    def release(self, slot: int) -> _Active:
        rec = self.slot_req[slot]
        self.slot_req[slot] = None
        self.pool.free(slot)
        self.pos[slot] = 0
        self.tok_idx[slot] = 0
        self.temps[slot] = 0.0
        self.last_tok[slot] = 0
        return rec

    def tick_inputs(self, s_max: int) -> Dict:
        a = self.admit_width
        tokens = np.zeros((a, s_max), np.int32)
        lens = np.ones((a,), np.int32)
        slots = np.full((a,), self.num_slots, np.int32)    # drop sentinel
        blocks = np.zeros((a, self.pool.n_pages_per_slot), np.int32)
        seeds = np.zeros((a,), np.int32)
        temps = np.zeros((a,), np.float32)
        for row, slot in enumerate(self.admitted):
            adm = self.slot_req[slot].adm
            tokens[row, : adm.bucket] = adm.tokens
            lens[row] = adm.bucket
            slots[row] = slot
            blocks[row] = self.pool.block[slot]
            seeds[row] = self.seeds[slot]
            temps[row] = self.temps[slot]
        out = {
            "last_tok": jnp.asarray(self.last_tok),
            "pos": jnp.asarray(self.pos),
            "block": jnp.asarray(self.pool.block),
            "seeds": jnp.asarray(self.seeds),
            "tok_idx": jnp.asarray(self.tok_idx),
            "temps": jnp.asarray(self.temps),
            "any_live": jnp.asarray(self.busy > 0),
            "admit_tokens": jnp.asarray(tokens),
            "admit_len": jnp.asarray(lens),
            "admit_slot": jnp.asarray(slots),
            "admit_blocks": jnp.asarray(blocks),
            "admit_seed": jnp.asarray(seeds),
            "admit_temp": jnp.asarray(temps),
        }
        if not self.sharing:
            out["any_prefill"] = jnp.asarray(bool(self.admitted))
            return out
        entries = self.pool.prefix_entries
        starts = np.zeros((a,), np.int32)
        restore_mask = np.zeros((a,), bool)
        restore_row = np.zeros((a,), np.int32)
        restore_slot = np.full((a,), self.num_slots, np.int32)
        save_row = np.full((a,), entries, np.int32)        # drop sentinel
        cow_src = np.zeros((a,), np.int32)
        cow_dst = np.zeros((a,), np.int32)
        any_prefill = False
        for row, (slot, plan) in enumerate(zip(self.admitted, self.plans)):
            starts[row] = plan.start
            if plan.is_restore:
                restore_mask[row] = True
                restore_row[row] = plan.restore_row
                restore_slot[row] = slot
            else:
                any_prefill = True
                if plan.save_row >= 0:
                    save_row[row] = plan.save_row
            if plan.cow is not None:
                cow_src[row], cow_dst[row] = plan.cow
        out.update({
            "any_prefill": jnp.asarray(any_prefill),
            "any_cow": jnp.asarray(bool(cow_dst.any())),
            "admit_start": jnp.asarray(starts),
            "restore_mask": jnp.asarray(restore_mask),
            "restore_row": jnp.asarray(restore_row),
            "restore_slot": jnp.asarray(restore_slot),
            "save_row": jnp.asarray(save_row),
            "cow_src": jnp.asarray(cow_src),
            "cow_dst": jnp.asarray(cow_dst),
            "wblock": jnp.asarray(self.pool.write_block()),
        })
        return out

    def pool_operand(self) -> Dict:
        if self.sharing:
            return {"core": self.pool.buffers,
                    "prefix": self.pool.prefix_buffers}
        return {"core": self.pool.buffers}

    def store_pool(self, pool: Dict) -> None:
        self.pool.buffers = pool["core"]
        if self.sharing:
            self.pool.prefix_buffers = pool["prefix"]


class ContinuousScheduler:
    """Slot-level admission over paged pools for BOTH cascade tiers.

    One instance = one AOT-compiled tick executable (``stats['compiles']``
    stays at 1 no matter how many prompt buckets flow through — the paged
    pool removed the bucket from every device shape, and prefix sharing adds
    only runtime operands).  ``admit_width`` batches admission prefills like
    the drain path batches prompts; ``decode_block`` fuses that many decode
    steps per tick like the drain path's decode scan (host-discarded overrun
    past a request's end is the latency/throughput knob).
    ``prefix_sharing`` turns on the pool's content-addressed prefix reuse
    (``prefix_entries`` full-prompt rows per tier, default 2x the tier's
    slots).
    """

    def __init__(self, s_tier, l_tier, hi: HIConfig, *, max_prompt_len: int,
                 max_new_tokens: int, num_slots: int = 8,
                 l_slots: Optional[int] = None, page_size: int = 16,
                 admit_width: Optional[int] = None, decode_block: int = 4,
                 use_kernel: bool = False, temperature: float = 0.0,
                 cache_dtype=jnp.bfloat16, prefix_sharing: bool = False,
                 prefix_entries: Optional[int] = None,
                 num_pages: Optional[int] = None):
        if max_prompt_len % page_size:
            raise ValueError(f"max_prompt_len {max_prompt_len} must be a "
                             f"multiple of page_size {page_size}")
        self.s = s_tier
        self.l = l_tier
        self.hi = hi
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        self.decode_block = max(1, decode_block)
        self.prefix_sharing = prefix_sharing
        l_slots = l_slots if l_slots is not None else max(2, num_slots // 2)
        admit_width = admit_width if admit_width is not None else num_slots
        page = page_size
        raw_ctx = max_prompt_len + max_new_tokens + self.decode_block - 1
        max_context = -(-raw_ctx // page) * page
        s_entries = (prefix_entries if prefix_entries is not None
                     else 2 * num_slots) if prefix_sharing else 0
        l_entries = (prefix_entries if prefix_entries is not None
                     else 2 * l_slots) if prefix_sharing else 0
        self.srt = _TierRuntime(s_tier.cfg, num_slots, max_context, page,
                                admit_width, cache_dtype,
                                prefix_entries=s_entries,
                                max_prompt_len=max_prompt_len,
                                num_pages=num_pages)
        self.lrt = _TierRuntime(l_tier.cfg, l_slots, max_context, page,
                                min(admit_width, l_slots), cache_dtype,
                                prefix_entries=l_entries,
                                max_prompt_len=max_prompt_len,
                                num_pages=num_pages)
        self.set_default_temperature(temperature)
        self.stats: Dict[str, float] = {
            "requests": 0, "offloaded": 0, "dropped": 0, "ticks": 0,
            "compiles": 0, "serve_time": 0.0}

        s_tick = _tier_tick_fn(s_tier.cfg, hi.metric, use_kernel,
                               self.decode_block, self.srt.sharing)
        l_tick = _tier_tick_fn(l_tier.cfg, hi.metric, use_kernel,
                               self.decode_block, self.lrt.sharing)

        def tick(s_params, l_params, theta, s_in, l_in, s_pool, l_pool):
            s_out, s_pool = s_tick(s_params, theta, s_in, s_pool)
            l_out, l_pool = l_tick(l_params, theta, l_in, l_pool)
            return {"s": s_out, "l": l_out}, s_pool, l_pool

        spec = partial(jax.tree.map,
                       lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype))
        s_in0 = self.srt.tick_inputs(max_prompt_len)
        l_in0 = self.lrt.tick_inputs(max_prompt_len)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            self._exec = jax.jit(tick, donate_argnums=(5, 6)).lower(
                spec(self.s.params), spec(self.l.params),
                jax.ShapeDtypeStruct((), jnp.float32),
                spec(s_in0), spec(l_in0),
                spec(self.srt.pool_operand()),
                spec(self.lrt.pool_operand())).compile()
        self.stats["compiles"] += 1

    def set_default_temperature(self, temperature: float) -> None:
        """Engine-level sampling temperature used for requests that don't set
        their own (Request.temperature > 0 wins) — keeps ``serve_stream``
        consistent with ``serve``'s engine-wide temperature."""
        self.srt.default_temp = float(temperature)
        self.lrt.default_temp = float(temperature)

    @property
    def prefix_stats(self) -> Dict[str, int]:
        """Cumulative prefix-cache counters summed over both tiers: hits /
        full_hits / tokens_saved / cow_copies / evictions."""
        agg: Dict[str, int] = {}
        for rt in (self.srt, self.lrt):
            for k, v in rt.pool.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    # -- host loop ----------------------------------------------------------

    def run(self, queue: AdmissionQueue, *, theta: Optional[float] = None
            ) -> Dict[int, Dict[str, Any]]:
        """Drain ``queue`` through the slots; returns per-request records
        keyed by request_id: tokens / s_tokens / confidence / offloaded /
        served_remote / dropped (mirroring ``HIEngine.serve``'s fields)."""
        from repro.serving import engine as engine_mod   # _host_fetch hook

        theta = float(self.hi.theta if theta is None else theta)
        theta_j = jnp.asarray(theta, jnp.float32)
        results: Dict[int, Dict[str, Any]] = {}
        l_queue: deque = deque()
        t0 = time.perf_counter()

        while len(queue) or l_queue or self.srt.busy or self.lrt.busy:
            self._try_admit(self.srt, queue)
            self._drop_expired(l_queue, results)
            self._try_admit(self.lrt, l_queue)
            if (not self.srt.admitted and not self.lrt.admitted
                    and not self.srt.busy and not self.lrt.busy):
                if not len(queue) and not l_queue:
                    break               # everything left was dropped
                raise RuntimeError(
                    "scheduler stalled: pool too small to admit a single "
                    "request — raise num_pages / num_slots")
            s_in = self.srt.tick_inputs(self.max_prompt_len)
            l_in = self.lrt.tick_inputs(self.max_prompt_len)
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*[Dd]onat")
                out, s_pool, l_pool = \
                    self._exec(self.s.params, self.l.params, theta_j,
                               s_in, l_in, self.srt.pool_operand(),
                               self.lrt.pool_operand())
            self.srt.store_pool(s_pool)
            self.lrt.store_pool(l_pool)
            host = engine_mod._host_fetch(out)   # the tick's single sync
            self.stats["ticks"] += 1
            self._absorb(self.srt, host["s"],
                         lambda rec: self._finish_s(rec, theta, l_queue,
                                                    results))
            self._absorb(self.lrt, host["l"],
                         lambda rec: self._finish_l(rec, results))

        self.stats["serve_time"] += time.perf_counter() - t0
        return results

    # -- admission / completion -------------------------------------------

    def _try_admit(self, rt: _TierRuntime, queue) -> None:
        """Admit up to ``admit_width`` queued requests into free slots.
        ``queue`` is the AdmissionQueue (S tier) or the escalation deque
        (L tier); both speak the same popleft/appendleft head interface."""
        rt.admitted = []
        rt.plans = []
        tick = int(self.stats["ticks"])
        while len(rt.admitted) < rt.admit_width and len(queue):
            if rt.free_slot() is None:
                break
            adm = queue.popleft()
            steps = min(adm.request.max_new_tokens, self.max_new_tokens)
            if not rt.admit(adm, steps, self.decode_block, tick):
                queue.appendleft(adm)   # no pages this tick: retry next tick
                break

    def _drop_expired(self, l_queue: deque, results: Dict) -> None:
        """arXiv:2112.11413 drop policy: an escalation whose request has
        outlived its latency budget is dropped from the L queue — the S-tier
        answer (already recorded) stands, flagged ``dropped``."""
        if not l_queue:
            return
        now = time.monotonic()
        kept: List[AdmittedRequest] = []
        while l_queue:
            adm = l_queue.popleft()
            budget = adm.request.latency_budget
            if budget is not None and now - adm.submit_time > budget:
                self.stats["dropped"] += 1
                rec = results.get(adm.request.request_id)
                if rec is not None:
                    rec["dropped"] = True
            else:
                kept.append(adm)
        l_queue.extend(kept)

    def _absorb(self, rt: _TierRuntime, out: Dict[str, np.ndarray],
                finish) -> None:
        for row, slot in enumerate(rt.admitted):
            rt.slot_req[slot].emit(out["admit_tok"][row],
                                   out["admit_conf"][row])
        k_steps = out["toks"].shape[0]
        for slot in range(rt.num_slots):
            rec = rt.slot_req[slot]
            if rec is None:
                continue
            for k in range(k_steps):
                rec.emit(out["toks"][k][slot], out["confs"][k][slot])
            rt.last_tok[slot] = int(out["toks"][k_steps - 1][slot])
            rt.tok_idx[slot] += k_steps
            rt.pos[slot] += k_steps
            if rec.done:
                finish(rt.release(slot))

    def _finish_s(self, rec: _Active, theta: float, l_queue: deque,
                  results: Dict) -> None:
        conf = float(np.mean(np.asarray(rec.confs, np.float32)))
        rid = rec.adm.request.request_id
        self.stats["requests"] += 1
        results[rid] = {
            "tokens": np.asarray(rec.tokens, np.int32),
            "s_tokens": np.asarray(rec.tokens, np.int32),
            "confidence": conf,
            "offloaded": conf < theta,
            "served_remote": False,
            "dropped": False,
        }
        if conf < theta:
            self.stats["offloaded"] += 1
            l_queue.append(rec.adm)

    def _finish_l(self, rec: _Active, results: Dict) -> None:
        rid = rec.adm.request.request_id
        results[rid]["tokens"] = np.asarray(rec.tokens, np.int32)
        results[rid]["served_remote"] = True
