"""HI serving engine: the paper's ED/ES cascade over LM requests.

The S-tier (reduced variant of the same family) prefills + decodes every
request; per-request confidence (mean token confidence, fused hi_gate when
``use_kernel``) drives the paper's threshold rule; complex requests escalate
to the L-tier through the static-capacity router.  On a pod mesh the
escalation gather is the ED→ES offload link (DESIGN.md §2).

Dispatch-count model (the serving hot path is device-resident)
--------------------------------------------------------------
One ``serve()`` call is ONE compiled XLA program per (batch, bucket) shape:

* prefill      = 1 batched pass over the whole (B, S) prompt (not O(S)
  sequential ``decode_step`` dispatches),
* decode       = ``max_new_tokens`` steps inside a single ``lax.scan``,
* cascade      = 2 tiers: the S-tier generate, the on-device route/gather,
  and the L-tier generate all live in the SAME jitted function, so the S→L
  escalation never materialises NumPy arrays.

Host synchronisation happens exactly once per call, *after* the cascade, via
the module-level ``_host_fetch`` (tests monkeypatch it to assert the single
sync point).  Per-shape executables are AOT-compiled and cached in
``HIEngine._exec`` so bucket switching never silently retraces, and both
tiers' cache buffers are donated (``donate_argnums``) so XLA reuses the
allocations across requests.

Continuous batching (``serve_stream``)
--------------------------------------
``serve()`` drains whole batches: a finished sequence idles its row until the
slowest one in the batch completes, and every (batch, bucket) pair costs one
executable + one donated cache pair.  ``serve_stream()`` replaces both with
the ``serving/scheduler.py`` + ``serving/kv_pool.py`` subsystem:

* cache   = ONE donated page-pool allocation per tier
  (``model_zoo.init_paged_cache``), indexed by an int32 block table — the
  bucket disappears from every device shape.  Pages are REFCOUNTED and
  content-addressed: a rolling chain hash per page of prompt tokens indexes
  every previously-served prompt prefix, admission aliases the longest hit
  read-only into the new slot's block row, and a small donated prefix cache
  (``model_zoo.init_prefix_cache``) keeps last-position logits + recurrent
  state rows so a FULL-prompt repeat restores without any prefill compute;
* tick    = ONE dispatch of ONE AOT-compiled program for ALL buckets:
  copy-on-write page duplications (an appending slot never writes a shared
  page), batched admission prefill of ONLY the uncached suffixes for up to
  ``admit_width`` queued requests (``lax.cond``, skipped at runtime when
  nothing is admitted — or when every admission is a full-prefix restore) +
  ``decode_block`` fused decode steps for every slot of BOTH tiers at
  per-slot positions (idle tiers skip the decode the same way);
* sync    = exactly one ``_host_fetch`` per tick (the drain discipline at
  tick granularity);
* admission = ``batcher.AdmissionQueue`` feeds a slot the moment a sequence
  finishes (EOS / per-request max-new-tokens) or escalates S→L; the L queue
  drops escalations past their per-request ``latency_budget``
  (arXiv:2112.11413 — the S answer stands, counted in ``stats['dropped']``).

So the dispatch-count model becomes: ``serve()`` = 1 program per
(batch, bucket); ``serve_stream()`` = 1 program per TICK, 1 compiled shape
TOTAL (prefix sharing, chunked prefill, and the speculative cascade add
runtime operands and build-time lanes, never a shape), with greedy outputs
token-identical to ``serve()`` on the same bucketized traffic — sharing on
or off, chunking on or off (asserted by tests/test_scheduler.py,
tests/test_prefix_cache.py, tests/test_chunk_lane.py).  Because the L
tier's pool and index persist across escalations, a re-escalated prompt
skips the L prefill entirely — the HI analogue of not redoing work the S
tier already paid for.

Chunked token lane (``chunk_prefill`` / ``speculative``)
--------------------------------------------------------
Both PR-5 features ride ONE primitive, ``model_zoo.forward_chunk_paged`` —
a multi-token paged pass (C tokens per slot at per-slot positions, K/V
through the scalar-prefetched block table, intra-chunk causal masking) that
generalises ``prefill_paged`` / ``decode_step_paged``:

* ``chunk_prefill``: prompts longer than ``chunk_size`` skip the admit lane
  and stream through a (chunk_width, chunk_size) chunk lane, C tokens per
  tick, interleaved with every other slot's decode — the long-prompt TTFT
  win measured by ``bench_serving.py``'s ``long_prompt`` scenario;
* ``speculative``: the S→L token cascade fused into the tick (greedy-only;
  temperature raises NotImplementedError).  The S tier DRAFTS
  ``decode_block`` tokens per slot with per-token hi_gate confidences;
  blocks whose min confidence clears theta are accepted at S-tier cost;
  the rest get ONE batched L verify chunk with longest-prefix acceptance,
  and the rejected tail rolls back in-tick (recurrent boundary snapshots +
  positional rewind, ``KVPool.truncate`` guarding the rewind).  Decisions
  and tokens match the host-driven ``token_cascade`` oracle
  (tests/test_speculative.py); acceptance rate and req/s are measured by
  the ``speculative`` bench scenario.

Either way the tick stays ONE AOT-compiled executable with ONE host sync —
``stats['stream_compiles']`` == 1 with everything enabled.

Observability (``serving/telemetry.py``)
----------------------------------------
``serve_stream(..., telemetry=Telemetry())`` threads the serving telemetry
collector through the scheduler: per-request span trees (``queued →
admitted → prefill_chunk[i] → decode_block[j] → escalate_attempt[k] →
l_verify → terminal``), per-tick phase buckets (``fault_tick /
build_operands / dispatch / host_fetch / postprocess``) + pool gauges,
streaming latency histograms, a Prometheus text snapshot, and Perfetto-
loadable Chrome-trace export (``serving/trace_export.py``) — all host-side
bookkeeping, so the compile and sync invariants above are untouched;
disabled (the default) it costs one branch per hook.  ``HIEngine.stats``
and ``ContinuousScheduler.stats`` are now dict VIEWS over the same typed
counters (``telemetry.EngineStatsView`` reads the live scheduler's fields
instead of copy-and-zeroing them), so the mirrored fault counters can
never diverge.

``benchmarks/bench_serving.py`` measures this path against the legacy
token-by-token loop (kept below as :func:`_decode_loop` + ``serve_legacy``)
and the drained batch path under mixed-length Poisson traffic, and writes
requests/sec + the prefill/decode split to ``BENCH_serving.json``.

This module is deliberately generic over family — it only needs the
model_zoo API — and is exercised end-to-end on CPU with reduced configs by
``examples/serve_cascade.py`` and the integration tests.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core.confidence import confidence as _confidence
from repro.core import router as router_mod
from repro.models import model_zoo
from repro.serving import sampler
from repro.serving.telemetry import EngineCounters, EngineStatsView

# The engine's single device→host sync point.  Kept as a module-level
# indirection so tests can wrap it and count synchronisations per serve().
_host_fetch = jax.device_get


@dataclass
class TierModel:
    cfg: ModelConfig
    params: Any


def _decode_loop(params, cfg: ModelConfig, tokens: jnp.ndarray,
                 cache_len: int, steps: int, metric: str,
                 use_kernel: bool = False):
    """LEGACY path: token-by-token prefill + greedy decode.

    Kept as the reference for the prefill-equivalence tests and as the
    baseline ``benchmarks/bench_serving.py`` measures the batched path
    against.  Returns (generated (B, steps), mean confidence (B,)).
    """
    b, s = tokens.shape
    cache = model_zoo.init_cache(cfg, b, cache_len)

    def prefill_body(carry, t):
        cache, _ = carry
        logits, cache = model_zoo.decode_step(params, cfg, t[:, None], cache)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(prefill_body,
                                      (cache, jnp.zeros((b, cfg.vocab_size))),
                                      tokens.T)

    def gen_body(carry, _):
        cache, logits = carry
        conf = _confidence(logits, metric)
        tok = sampler.greedy(logits)
        logits, cache = model_zoo.decode_step(params, cfg, tok[:, None], cache)
        return (cache, logits), (tok, conf)

    (_, _), (toks, confs) = jax.lax.scan(gen_body, (cache, logits), None,
                                         length=steps)
    return toks.T, confs.mean(axis=0)


def _generate(params, cfg: ModelConfig, tokens: jnp.ndarray, cache, *,
              steps: int, metric: str, theta, use_kernel: bool = False,
              seeds=None, temperature=0.0):
    """Batched prefill + decode, fully on device.

    ``cache`` is overwritten by the prefill (callers donate it).  Sampling is
    greedy when ``temperature`` (a TRACED scalar — changing it never
    retraces) is <= 0; otherwise categorical with PER-REQUEST keys derived
    from ``seeds`` (B,) and the in-request token index, so a request's
    continuation is reproducible across batch compositions and matches the
    continuous scheduler token for token.  Returns (generated (B, steps),
    mean confidence (B,), cache).
    """
    logits, cache = model_zoo.prefill(params, cfg, tokens, cache,
                                      use_kernel=use_kernel)
    if seeds is None:
        seeds = jnp.zeros((tokens.shape[0],), jnp.int32)

    def gen_body(carry, i):
        cache, logits = carry
        if use_kernel:
            from repro.kernels import ops as kops
            conf = kops.hi_gate(logits, theta, metric=metric)[0]
        else:
            conf = _confidence(logits, metric)
        keys = sampler.request_keys(seeds, i)
        tok = sampler.sample(keys, logits, temperature)
        logits, cache = model_zoo.decode_step(params, cfg, tok[:, None], cache)
        return (cache, logits), (tok, conf)

    (cache, _), (toks, confs) = jax.lax.scan(gen_body, (cache, logits),
                                             jnp.arange(steps))
    return toks.T, confs.mean(axis=0), cache


def _make_cascade(s_cfg: ModelConfig, l_cfg: ModelConfig, hi: HIConfig,
                  steps: int, capacity: int, use_kernel: bool):
    """Build the single jitted S→L cascade for one (batch, bucket) shape.

    Everything between the two tier forwards — confidence, threshold,
    route/gather, scatter-merge, agreement stats — stays on device; the
    caller pulls the result dict once, asynchronously, at the end.
    """

    def cascade(s_params, l_params, tokens, theta, temperature, seeds,
                s_cache, l_cache):
        s_toks, s_conf, s_cache = _generate(
            s_params, s_cfg, tokens, s_cache, steps=steps, metric=hi.metric,
            theta=theta, use_kernel=use_kernel, seeds=seeds,
            temperature=temperature)
        offload = s_conf < theta
        decision = router_mod.route(offload, s_conf, capacity)
        complex_tokens = router_mod.gather(tokens, decision)
        l_toks, _, l_cache = _generate(
            l_params, l_cfg, complex_tokens, l_cache, steps=steps,
            metric=hi.metric, theta=theta, use_kernel=use_kernel,
            seeds=seeds[decision.indices], temperature=temperature)
        merged = router_mod.scatter_merge(s_toks, l_toks, decision)
        agree = router_mod.agreement(s_toks, l_toks, decision)
        out = {
            "tokens": merged,
            "s_tokens": s_toks,
            "confidence": s_conf,
            "offloaded": decision.offload_mask,
            "served_remote": decision.served_remote,
            "dropped": decision.dropped,
            "l_indices": decision.indices,
            "l_valid": decision.valid,
            "l_agree": agree,
        }
        return out, s_cache, l_cache

    return cascade


class HIEngine:
    """Two-tier cascade engine with a device-resident hot path.

    ``online_policy`` (paper ref [27], Moothedath et al.): when set, theta is
    tuned online from the L-tier's feedback on offloaded requests — S-tier
    agreement with the L-tier output is the correctness proxy (the ED never
    sees ground truth).  The engine then uses policy.theta instead of the
    static hi.theta; theta is a *traced* scalar so policy updates never force
    a recompile.
    """

    def __init__(self, s_tier: TierModel, l_tier: TierModel, hi: HIConfig,
                 cache_len: int = 128, max_new_tokens: int = 8,
                 online_policy=None, use_kernel: bool = False,
                 temperature: float = 0.0):
        self.s = s_tier
        self.l = l_tier
        self.hi = hi
        self.online_policy = online_policy
        self.cache_len = cache_len
        self.max_new_tokens = max_new_tokens
        self.use_kernel = use_kernel
        self.temperature = temperature
        # (batch, bucket) -> [compiled executable, s_cache, l_cache]
        self._exec: Dict[Tuple[int, int], list] = {}
        self._legacy = None
        self._stream = None          # (key, ContinuousScheduler) lazy cache
        # ONE authority per counter: keys the continuous scheduler also
        # counts are read LIVE through the view (engine total = retired base
        # + attached scheduler), instead of the old copy-and-zero mirroring
        # that kept two divergence-prone stores.  Dict API unchanged.
        self.counters = EngineCounters()
        self.stats: EngineStatsView = EngineStatsView(self.counters)

    # -- executable cache ---------------------------------------------------

    def _executable(self, b: int, s: int) -> list:
        """AOT-compile (once) the cascade for a (batch, bucket) shape and
        allocate the donated per-shape cache buffers."""
        key = (b, s)
        ent = self._exec.get(key)
        if ent is not None:
            return ent
        if s + self.max_new_tokens > self.cache_len:
            raise ValueError(
                f"bucket {s} + max_new_tokens {self.max_new_tokens} exceeds "
                f"cache_len {self.cache_len}")
        cap = router_mod.capacity_for(b, self.hi.capacity_factor)
        fn = jax.jit(_make_cascade(self.s.cfg, self.l.cfg, self.hi,
                                   self.max_new_tokens, cap, self.use_kernel),
                     donate_argnums=(6, 7))
        s_cache = model_zoo.init_cache(self.s.cfg, b, self.cache_len)
        l_cache = model_zoo.init_cache(self.l.cfg, cap, self.cache_len)
        spec = partial(jax.tree.map,
                       lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype))
        with warnings.catch_warnings():
            # buffer donation is a no-op on the CPU backend; stay quiet there
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            compiled = fn.lower(
                spec(self.s.params), spec(self.l.params),
                jax.ShapeDtypeStruct((b, s), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                spec(s_cache), spec(l_cache)).compile()
        self.stats["compiles"] += 1
        ent = [compiled, s_cache, l_cache]
        self._exec[key] = ent
        return ent

    # -- serving ------------------------------------------------------------

    def serve(self, tokens: np.ndarray,
              seeds: np.ndarray = None) -> Dict[str, np.ndarray]:
        """tokens: (B, S) prompt batch -> generations + offload accounting.

        One compiled-program dispatch; host sync happens exactly once, after
        the full cascade, via ``_host_fetch``.  ``seeds`` (B,) int32
        per-request sampling seeds (used when ``self.temperature > 0``;
        typically the request ids, so sampled continuations match the
        continuous path's).
        """
        b, s = tokens.shape
        ent = self._executable(b, s)
        theta = jnp.asarray(
            self.online_policy.theta if self.online_policy is not None
            else self.hi.theta, jnp.float32)
        seeds = (jnp.zeros((b,), jnp.int32) if seeds is None
                 else jnp.asarray(seeds, jnp.int32))
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat")
            out, ent[1], ent[2] = ent[0](
                self.s.params, self.l.params,
                jnp.asarray(tokens, jnp.int32), theta,
                jnp.asarray(self.temperature, jnp.float32), seeds,
                ent[1], ent[2])
        host = _host_fetch(out)       # the single device→host sync point
        t1 = time.perf_counter()

        if self.online_policy is not None:
            # L-tier agreement on served requests is the correctness proxy
            served = host["l_valid"]
            if served.any():
                self.online_policy.update(
                    host["confidence"][host["l_indices"][served]],
                    host["l_agree"][served])

        self.stats["requests"] += b
        self.stats["offloaded"] += int(host["offloaded"].sum())
        self.stats["dropped"] += int(host["dropped"])
        self.stats["serve_time"] += t1 - t0
        return {k: host[k] for k in ("tokens", "s_tokens", "confidence",
                                     "offloaded", "served_remote")}

    def serve_legacy(self, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        """Pre-batched-prefill reference path: per-token scan prefill, NumPy
        routing round-trip, and a host sync per tier.  Benchmarked against
        ``serve`` by ``benchmarks/bench_serving.py``; not used in production.
        """
        if self._legacy is None:
            self._legacy = (
                jax.jit(partial(_decode_loop, cfg=self.s.cfg,
                                cache_len=self.cache_len,
                                steps=self.max_new_tokens,
                                metric=self.hi.metric)),
                jax.jit(partial(_decode_loop, cfg=self.l.cfg,
                                cache_len=self.cache_len,
                                steps=self.max_new_tokens,
                                metric=self.hi.metric)))
        s_step, l_step = self._legacy
        b = tokens.shape[0]
        cap = router_mod.capacity_for(b, self.hi.capacity_factor)
        t0 = time.perf_counter()
        s_out, s_conf = s_step(self.s.params, tokens=jnp.asarray(tokens))
        s_out.block_until_ready()
        theta = (self.online_policy.theta if self.online_policy is not None
                 else self.hi.theta)
        offload = np.asarray(s_conf) < theta
        decision = router_mod.route(jnp.asarray(offload), jnp.asarray(s_conf),
                                    cap)
        complex_tokens = jnp.asarray(tokens)[decision.indices]
        l_out, _ = l_step(self.l.params, tokens=complex_tokens)
        l_out.block_until_ready()
        merged = router_mod.scatter_merge(s_out, l_out, decision)
        t1 = time.perf_counter()
        if self.online_policy is not None:
            served_idx = np.asarray(decision.indices)[np.asarray(decision.valid)]
            if len(served_idx):
                s_sub = np.asarray(s_out)[served_idx]
                l_sub = np.asarray(l_out)[np.asarray(decision.valid)]
                agree = (s_sub == l_sub).all(axis=-1)
                self.online_policy.update(np.asarray(s_conf)[served_idx],
                                          agree)
        self.stats["requests"] += b
        self.stats["offloaded"] += int(offload.sum())
        self.stats["dropped"] += int(decision.dropped)
        self.stats["serve_time"] += t1 - t0
        return {
            "tokens": np.asarray(merged),
            "s_tokens": np.asarray(s_out),
            "confidence": np.asarray(s_conf),
            "offloaded": np.asarray(decision.offload_mask),
            "served_remote": np.asarray(decision.served_remote),
        }

    def serve_stream(self, requests, *, buckets=(32, 64), num_slots: int = 8,
                     l_slots: int = None, page_size: int = 16,
                     admit_width: int = None, decode_block: int = 4,
                     prefix_sharing: bool = True, prefix_entries: int = None,
                     chunk_prefill: bool = False, chunk_size: int = 8,
                     chunk_width: int = 2, speculative: bool = False,
                     kv_dtype: str = "bf16", mesh=None, faults=None,
                     retry=None, validate: bool = False, telemetry=None,
                     audit=None, watchdog=None,
                     flight_recorder=None) -> Dict[int, Dict[str, np.ndarray]]:
        """Continuous-batching entry point: serve ``requests`` (an iterable of
        ``batcher.Request``) through slot-level admission over the paged KV
        pools instead of drained (B, bucket) batches.

        Requests are bucketized by the same ladder the drain path uses, so
        greedy outputs are token-identical to ``serve`` on the same traffic
        for ANY ``admit_width`` (batched admission prefill) / ``decode_block``
        (fused decode steps per tick); unlike the drain path, a finished or
        escalated sequence's slot is re-admitted IMMEDIATELY, per-request
        ``max_new_tokens`` / ``temperature`` / ``eos_id`` are honoured, and
        ONE executable serves every bucket (``stats['stream_compiles']``
        stays at 1).

        ``prefix_sharing`` (default on) enables the pools' content-addressed
        prefix reuse: prompts are chain-hashed at submit, admission aliases
        the longest cached prefix (refcounted, copy-on-write) and prefills
        only the uncached suffix; a repeated prompt — including an S→L
        escalation replay — restores pages + state + logits without running
        the admit lane.  The scheduler (and its pools and indexes) persists
        across ``serve_stream`` calls with the same configuration, so reuse
        is cross-call.  ``stats['prefill_tokens_saved']`` counts the skipped
        prompt positions; outputs stay token-identical to sharing-off.
        Requests carrying a ``latency_budget`` are dropped from the L queue
        once past their deadline (``stats['dropped']``, record flag
        ``dropped`` — the S answer stands).

        ``chunk_prefill`` routes prompts longer than ``chunk_size`` through
        the scheduler's chunked-prefill lane — ingested ``chunk_size`` tokens
        per tick, interleaved with every other slot's decode, instead of
        monopolizing the admit lane (the long-prompt TTFT win measured by
        ``bench_serving.py``); greedy outputs are token-identical with
        chunking on or off.  ``speculative`` fuses the S→L draft-verify token
        cascade into the tick (``serving/token_cascade.py`` semantics, one
        program): the S tier drafts ``decode_block`` tokens per slot, blocks
        whose minimum hi_gate confidence clears theta are accepted at S-tier
        cost, the rest get ONE batched L verify chunk with longest-prefix
        acceptance and an in-tick rollback of the rejected tail.
        Speculative acceptance is GREEDY-ONLY for now — any sampling
        temperature raises NotImplementedError (rejection sampling is future
        work).

        ``kv_dtype`` selects the page-pool storage format for both tiers:
        ``"bf16"`` (default, bitwise-identical to the unquantized build) or
        ``"int8"`` — quantized pages with per-page-per-head scales and
        dequantization fused into the page-gather kernels, roughly halving
        KV bytes per slot at a small greedy-fidelity cost (tolerance-based
        rather than bitwise equivalence).  Still one executable and one
        host sync per tick in either mode.

        ``mesh`` (a jax ``Mesh`` with axes ``("data", "model")``, e.g. from
        ``launch.mesh.make_serving_mesh``) turns on mesh-sharded tier-split
        serving: the S tier becomes ``data`` data-parallel replicas (each
        owning its own slot slice + paged-pool shard, run under
        ``shard_map``), the L tier's params and KV pages shard over
        ``model``, and S→L escalation tokens route through a donated
        double-buffered device staging buffer dispatched at tick top so the
        transfer overlaps the same tick's S-side compute (the modelled DCN
        hop costs one tick of L-admission latency, never critical-path
        time).  Still ONE compiled executable and ONE host fetch per tick
        per host; at a (1, 1) debug mesh greedy outputs are token-identical
        to ``mesh=None``.  The mesh participates in the scheduler cache key
        by identity.

        Failure semantics: ``faults`` (a ``serving.faults.FaultSchedule``)
        injects deterministic, seeded ED↔ES transport faults — escalation
        delivery delay, loss, L-tier outage windows, latency spikes — and
        ``retry`` (a ``serving.faults.RetryPolicy``) sets the resilience
        knobs: capped exponential backoff for lost/timed-out escalations, the
        consecutive-failure circuit breaker (closed → open → half-open; open
        = FAIL-LOCAL: the L queue pauses and the gate's traced theta operand
        drops to ``FAIL_LOCAL_THETA`` so nothing offloads — no recompile),
        and the admission retry cap.  Every record carries ``status`` ∈
        {``ok``, ``degraded_local``, ``dropped``, ``rejected``} plus
        ``escalation_retries`` / ``queue_wait_ticks`` / ``esc_created_tick``
        (-1 = never escalated; the outage bench slices the trace into
        during/after-window phases with it); degradation NEVER
        changes compiled shapes (``stats['stream_compiles']`` stays 1 under
        any schedule — fault state is per-run, not part of the scheduler
        cache key).  ``validate=True`` asserts ``KVPool.check_invariants``
        on both tiers after every tick (chaos tests).

        ``telemetry`` (a ``serving.telemetry.Telemetry``) installs the
        observability collector for this call: per-request span trees
        (``queued → admitted → prefill_chunk[i] → decode_block[j] →
        escalate_attempt[k] → l_verify → terminal``), per-tick phase timing
        (``fault_tick / build_operands / dispatch / host_fetch /
        postprocess``) and pool gauges — all host-side, so
        ``stats['stream_compiles']`` and the one-sync-per-tick discipline
        are unchanged; ``None`` (default) keeps the zero-overhead disabled
        path.  Export via ``telemetry.prometheus_text()`` /
        ``histogram_summary()`` or ``serving.trace_export.chrome_trace``.

        ``audit`` (a ``serving.audit.GateAudit``) installs the
        decision-quality layer with the same contract: every gate decision
        the scheduler absorbs (admit / chunk / decode / block / request)
        is recorded with its theta-IN-EFFECT, the speculative verify lane
        and completed escalations feed ground-truth outcomes, and the
        streaming aggregates (reliability bins, per-``tclass`` ECE +
        offload rate, theta margins, empirical regret) ride the existing
        single host fetch — zero extra syncs, token-identical outputs.
        ``watchdog`` (a ``serving.audit.SLOWatchdog``) evaluates SLO /
        drift thresholds once per tick; ``flight_recorder`` (a
        ``serving.flight_recorder.FlightRecorder``) keeps a bounded ring
        of tick snapshots and dumps a deterministic postmortem JSON on
        watchdog breach, breaker-open, invariant failure, or a stall.

        Returns per-request result records keyed by request_id.
        """
        from repro.serving.batcher import AdmissionQueue
        from repro.serving.scheduler import ContinuousScheduler

        requests = list(requests)
        if speculative:
            if self.temperature > 0:
                raise NotImplementedError(
                    "speculative serving is greedy-only: engine temperature "
                    f"{self.temperature} > 0 requires rejection sampling "
                    "(future work)")
            hot = [r.request_id for r in requests if r.temperature > 0]
            if hot:
                raise NotImplementedError(
                    "speculative serving is greedy-only: requests "
                    f"{hot} set temperature > 0, which requires rejection "
                    "sampling (future work)")
        mesh_key = None if mesh is None else (tuple(sorted(mesh.shape.items())),
                                              id(mesh))
        key = (tuple(sorted(buckets)), num_slots, l_slots, page_size,
               admit_width, decode_block, prefix_sharing, prefix_entries,
               chunk_prefill, chunk_size, chunk_width, speculative, kv_dtype,
               mesh_key)
        if self._stream is None or self._stream[0] != key:
            sched = ContinuousScheduler(
                self.s, self.l, self.hi, max_prompt_len=max(buckets),
                max_new_tokens=self.max_new_tokens, num_slots=num_slots,
                l_slots=l_slots, page_size=page_size,
                admit_width=admit_width, decode_block=decode_block,
                use_kernel=self.use_kernel, temperature=self.temperature,
                prefix_sharing=prefix_sharing,
                prefix_entries=prefix_entries,
                chunk_prefill=chunk_prefill, chunk_size=chunk_size,
                chunk_width=chunk_width, speculative=speculative,
                kv_dtype=kv_dtype, mesh=mesh)
            self._stream = (key, sched)
            self.stats["stream_compiles"] += sched.stats["compiles"]
        sched = self._stream[1]
        # engine totals read the scheduler's typed counters LIVE through the
        # view (attach folds a replaced scheduler's totals into the base
        # first) — no per-key copy-and-zero, so the two can never diverge
        self.stats.attach(sched)
        sched.set_default_temperature(self.temperature)
        sched.set_audit(audit)
        sched.set_telemetry(telemetry)
        sched.set_watchdog(watchdog)
        sched.set_flight_recorder(flight_recorder)
        from repro.serving.faults import NO_FAULTS, RetryPolicy
        sched.set_faults(faults if faults is not None else NO_FAULTS,
                         retry if retry is not None else RetryPolicy(),
                         validate)
        queue = AdmissionQueue(buckets=buckets,
                               page_size=page_size if prefix_sharing else None)
        for r in requests:
            queue.submit(r)
        theta = (self.online_policy.theta if self.online_policy is not None
                 else self.hi.theta)
        return sched.run(queue, theta=theta)

    def summary(self) -> Dict[str, float]:
        n = max(self.stats["requests"], 1)
        return {
            **self.stats,
            "offload_frac": self.stats["offloaded"] / n,
            "drop_frac": self.stats["dropped"] / n,
        }


def build_engine(cfg: ModelConfig, hi: HIConfig, rng=None, dtype=jnp.float32,
                 cache_len: int = 128, max_new_tokens: int = 8,
                 use_kernel: bool = False,
                 temperature: float = 0.0) -> HIEngine:
    """Construct an S/L cascade for one architecture family: L = reduced
    assigned config (CPU-runnable), S = its s_variant."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    l_cfg = cfg
    s_cfg = cfg.s_variant(hi.s_scale)
    l_params = model_zoo.init_params(k1, l_cfg, dtype)
    s_params = model_zoo.init_params(k2, s_cfg, dtype)
    return HIEngine(TierModel(s_cfg, s_params), TierModel(l_cfg, l_params),
                    hi, cache_len=cache_len, max_new_tokens=max_new_tokens,
                    use_kernel=use_kernel, temperature=temperature)
