"""HI serving engine: the paper's ED/ES cascade over LM requests.

The S-tier (reduced variant of the same family) prefills + decodes every
request; per-request confidence (mean token confidence from the fused
hi_gate) drives the paper's threshold rule; complex requests escalate to the
L-tier through the static-capacity router.  On a pod mesh the escalation
gather is the ED→ES offload link (DESIGN.md §2).

This module is deliberately generic over family — it only needs the
model_zoo API — and is exercised end-to-end on CPU with reduced configs by
``examples/serve_cascade.py`` and the integration tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core import confidence as _c_unused  # noqa: F401 (keep pkg init)
from repro.core.confidence import confidence as _confidence
from repro.core import router as router_mod
from repro.models import model_zoo
from repro.serving import sampler


@dataclass
class TierModel:
    cfg: ModelConfig
    params: Any


def _decode_loop(params, cfg: ModelConfig, tokens: jnp.ndarray,
                 cache_len: int, steps: int, metric: str,
                 use_kernel: bool = False):
    """Prefill (token-by-token for family-uniformity) + greedy decode.

    Returns (generated (B, steps), mean confidence (B,)).
    """
    b, s = tokens.shape
    cache = model_zoo.init_cache(cfg, b, cache_len)

    def prefill_body(carry, t):
        cache, _ = carry
        logits, cache = model_zoo.decode_step(params, cfg, t[:, None], cache)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(prefill_body,
                                      (cache, jnp.zeros((b, cfg.vocab_size))),
                                      tokens.T)

    def gen_body(carry, _):
        cache, logits = carry
        conf = _confidence(logits, metric)
        tok = sampler.greedy(logits)
        logits, cache = model_zoo.decode_step(params, cfg, tok[:, None], cache)
        return (cache, logits), (tok, conf)

    (_, _), (toks, confs) = jax.lax.scan(gen_body, (cache, logits), None,
                                         length=steps)
    return toks.T, confs.mean(axis=0)


class HIEngine:
    """Two-tier cascade engine.

    ``online_policy`` (paper ref [27], Moothedath et al.): when set, theta is
    tuned online from the L-tier's feedback on offloaded requests — S-tier
    agreement with the L-tier output is the correctness proxy (the ED never
    sees ground truth).  The engine then uses policy.theta instead of the
    static hi.theta.
    """

    def __init__(self, s_tier: TierModel, l_tier: TierModel, hi: HIConfig,
                 cache_len: int = 128, max_new_tokens: int = 8,
                 online_policy=None):
        self.s = s_tier
        self.l = l_tier
        self.hi = hi
        self.online_policy = online_policy
        self.cache_len = cache_len
        self.max_new_tokens = max_new_tokens
        self._s_step = jax.jit(partial(_decode_loop, cfg=self.s.cfg,
                                       cache_len=cache_len,
                                       steps=max_new_tokens, metric=hi.metric))
        self._l_step = jax.jit(partial(_decode_loop, cfg=self.l.cfg,
                                       cache_len=cache_len,
                                       steps=max_new_tokens, metric=hi.metric))
        self.stats: Dict[str, float] = {
            "requests": 0, "offloaded": 0, "dropped": 0,
            "s_time": 0.0, "l_time": 0.0}

    def serve(self, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        """tokens: (B, S) prompt batch -> generations + offload accounting."""
        b = tokens.shape[0]
        cap = router_mod.capacity_for(b, self.hi.capacity_factor)
        t0 = time.perf_counter()
        s_out, s_conf = self._s_step(self.s.params, tokens=jnp.asarray(tokens))
        s_out.block_until_ready()
        t1 = time.perf_counter()

        theta = (self.online_policy.theta if self.online_policy is not None
                 else self.hi.theta)
        offload = np.asarray(s_conf) < theta
        decision = router_mod.route(jnp.asarray(offload), jnp.asarray(s_conf),
                                    cap)
        complex_tokens = jnp.asarray(tokens)[decision.indices]
        l_out, _ = self._l_step(self.l.params, tokens=complex_tokens)
        l_out.block_until_ready()
        t2 = time.perf_counter()

        merged = router_mod.scatter_merge(s_out, l_out, decision)

        if self.online_policy is not None:
            # L-tier agreement on served requests is the correctness proxy
            served_idx = np.asarray(decision.indices)[np.asarray(decision.valid)]
            if len(served_idx):
                s_sub = np.asarray(s_out)[served_idx]
                l_sub = np.asarray(l_out)[np.asarray(decision.valid)]
                agree = (s_sub == l_sub).all(axis=-1)
                self.online_policy.update(np.asarray(s_conf)[served_idx],
                                          agree)

        self.stats["requests"] += b
        self.stats["offloaded"] += int(offload.sum())
        self.stats["dropped"] += int(decision.dropped)
        self.stats["s_time"] += t1 - t0
        self.stats["l_time"] += t2 - t1
        return {
            "tokens": np.asarray(merged),
            "s_tokens": np.asarray(s_out),
            "confidence": np.asarray(s_conf),
            "offloaded": np.asarray(decision.offload_mask),
            "served_remote": np.asarray(decision.served_remote),
        }

    def summary(self) -> Dict[str, float]:
        n = max(self.stats["requests"], 1)
        return {
            **self.stats,
            "offload_frac": self.stats["offloaded"] / n,
            "drop_frac": self.stats["dropped"] / n,
        }


def build_engine(cfg: ModelConfig, hi: HIConfig, rng=None, dtype=jnp.float32,
                 cache_len: int = 128, max_new_tokens: int = 8) -> HIEngine:
    """Construct an S/L cascade for one architecture family: L = reduced
    assigned config (CPU-runnable), S = its s_variant."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    l_cfg = cfg
    s_cfg = cfg.s_variant(hi.s_scale)
    l_params = model_zoo.init_params(k1, l_cfg, dtype)
    s_params = model_zoo.init_params(k2, s_cfg, dtype)
    return HIEngine(TierModel(s_cfg, s_params), TierModel(l_cfg, l_params),
                    hi, cache_len=cache_len, max_new_tokens=max_new_tokens)
