"""Decision-quality observability: the gate audit stream, online calibration
monitors, and the SLO watchdog.

The paper's central objection to HI is that "the ED, in general, cannot know
if the local inference is sufficient" — so the *health* of a deployed HI
system IS the health of the gate's calibration.  PR 7's telemetry measures
time (TTFT/TPOT, tick phases) but is blind to confidences, theta margins,
offload mix, and calibration drift.  This module observes exactly that, and
is the feedback plumbing for online threshold control (ROADMAP open item 2:
arXiv:2304.00891 online HI, arXiv:2508.08985 low-regret threshold learning —
both consume the per-decision (confidence, outcome) stream collected here).

Everything rides the scheduler's existing single ``_host_fetch`` per tick:
the confidences already come back to the host for escalation routing, so
enabling the audit adds ZERO device syncs and ZERO compiled shapes
(``stream_compiles == 1`` with the audit on, test-asserted in both
``kv_dtype`` modes).  Disabled (the default ``audit=None``) every scheduler
hook is a single ``is None`` branch — the same contract as
``telemetry=None``.

Three pieces:

**1. :class:`GateAudit`** — the per-decision audit stream.  Every gate
evaluation the scheduler absorbs becomes one :class:`AuditRecord`:

* ``decision()`` records (rid, tier, traffic class, kind, confidence,
  theta-IN-EFFECT — i.e. ``FAIL_LOCAL_THETA`` while the circuit breaker is
  open — and the offload decision).  Kinds: ``admit`` / ``chunk`` /
  ``decode`` (per-token gate evaluations), ``block`` (a speculative draft
  block's min-confidence escalation decision), ``request`` (the
  request-level escalation decision at S-finish, which drives the per-class
  offload rate).
* ``outcome()`` additionally carries ground truth ``ok``: in speculative
  mode the L-verify lane re-derives every drafted position greedily, so
  per-position accept/reject feedback is FREE every tick (kind ``draft``);
  in plain mode each escalation that completes remotely yields one
  agreement sample — did the S tokens match the L tokens? (kind
  ``l_agree``).

Streaming aggregates (constant memory, besides the bounded ``records``
ring):

* **Reliability bins** (:class:`ReliabilityBins`): correct/incorrect counts
  per confidence bin with bin semantics IDENTICAL to
  ``core/calibrate.p_histogram`` (``edges = linspace(0, 1, bins+1)``,
  half-open bins, last bin closed) — tests cross-check the streaming bins
  against the NumPy oracle on the same decision stream.  Running **ECE**
  (expected calibration error, confidence-weighted) per traffic class and
  overall.
* **Offload rate per traffic class** (``Request.tclass``, default ``""``).
* **Theta-margin histogram**: linear bins of ``conf - theta`` over [-1, 1]
  — how close the traffic runs to the gate.
* **Empirical regret vs the verify-lane oracle**: per ground-truthed
  decision, the gate pays ``beta`` for an offload and ``1 - ok`` for a
  local serve; the oracle (which sees ``ok``) pays ``min(beta, 1 - ok)``.
  ``regret_cost`` accumulates the difference; ``wasted_offload`` /
  ``missed_local`` count the two mistake kinds.

Exported through ``Telemetry.prometheus_text`` (``hi_audit_*`` families)
and as Chrome-trace counter tracks (``gauge_values()`` feeds the per-tick
gauges).

**2. :class:`SLOThresholds` / :class:`SLOWatchdog`** — configurable
TTFT-p95 / TPOT-p95 / L-queue-depth / calibration-drift (ECE, offload-rate)
thresholds evaluated ONCE per tick from state the scheduler already holds.
Breaches append to ``watchdog.breaches``, emit telemetry instant events
(Chrome ``i`` markers on the scheduler track), and trigger the flight
recorder (``serving/flight_recorder.py``).

**3.** The flight recorder itself lives in ``serving/flight_recorder.py``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional

import numpy as np

from repro.serving.telemetry import escape_label


class AuditRecord(NamedTuple):
    """One gate decision (the bounded raw-stream face of the audit)."""
    rid: int
    tier: str                 # "S" / "L"
    tclass: str               # Request.tclass traffic-class tag
    kind: str                 # admit / chunk / decode / block / request /
    #                           draft / l_agree
    conf: float
    theta: float              # theta IN EFFECT (FAIL_LOCAL_THETA when open)
    offload: bool             # the gate's decision at that theta
    ok: Optional[bool] = None  # ground truth when the verify lane ran


class ReliabilityBins:
    """Streaming correct/incorrect counts per confidence bin.

    Bin semantics are shared with ``core/calibrate.p_histogram``: edges are
    ``np.linspace(0, 1, bins + 1)``, every bin is half-open ``[lo, hi)``
    except the last (closed at 1.0) — ``np.histogram``'s rule, so the
    streaming counts match the NumPy oracle sample for sample
    (tests/test_audit.py cross-checks)."""

    def __init__(self, bins: int = 20):
        self.bins = int(bins)
        self.edges = np.linspace(0.0, 1.0, self.bins + 1)
        self.correct = np.zeros(self.bins, np.int64)
        self.incorrect = np.zeros(self.bins, np.int64)
        self.conf_sum = np.zeros(self.bins, np.float64)

    def _idx(self, conf: float) -> int:
        # searchsorted(side="right") - 1 == np.histogram's bin rule; the
        # clip folds conf == 1.0 into the (closed) last bin
        i = int(np.searchsorted(self.edges, conf, side="right")) - 1
        return min(max(i, 0), self.bins - 1)

    def record(self, conf: float, ok: bool) -> None:
        i = self._idx(conf)
        (self.correct if ok else self.incorrect)[i] += 1
        self.conf_sum[i] += conf

    @property
    def count(self) -> int:
        return int(self.correct.sum() + self.incorrect.sum())

    def ece(self) -> float:
        """Expected calibration error: sum_b (n_b/N) |acc_b - mean conf_b|."""
        n_b = self.correct + self.incorrect
        n = n_b.sum()
        if n == 0:
            return 0.0
        live = n_b > 0
        acc = self.correct[live] / n_b[live]
        mean_conf = self.conf_sum[live] / n_b[live]
        return float(np.sum(n_b[live] / n * np.abs(acc - mean_conf)))

    def as_dict(self) -> Dict[str, np.ndarray]:
        """`p_histogram`-shaped view: edges / correct / incorrect."""
        return {"edges": self.edges.copy(),
                "correct": self.correct.copy(),
                "incorrect": self.incorrect.copy()}


class _ClassStats:
    """Per-traffic-class aggregates."""

    def __init__(self, bins: int):
        self.evals = 0            # every gate evaluation (all kinds)
        self.requests = 0         # request-level decisions
        self.offloaded = 0        # ... that offloaded
        self.bins = ReliabilityBins(bins)

    @property
    def offload_rate(self) -> float:
        return self.offloaded / self.requests if self.requests else 0.0


class GateAudit:
    """Per-decision gate audit stream + streaming calibration monitors.

    Install via ``serve_stream(..., audit=GateAudit())`` (or
    ``ContinuousScheduler.set_audit``).  Host-side only: never part of the
    scheduler's compile key, zero device traffic, zero overhead when absent.

    ``bins`` sets the reliability-bin count (shared semantics with
    ``core/calibrate.p_histogram``); ``beta`` is the paper's offload cost in
    [0, 1) for the empirical-regret counter (default = ``HIConfig.beta``);
    ``max_records`` bounds the raw :class:`AuditRecord` ring (aggregates are
    exact regardless)."""

    def __init__(self, *, bins: int = 20, beta: float = 0.5,
                 margin_bins: int = 40, max_records: int = 65536):
        self.beta = float(beta)
        self.records: deque = deque(maxlen=int(max_records))
        self.overall = ReliabilityBins(bins)
        self.classes: Dict[str, _ClassStats] = {}
        self._bins = int(bins)
        # theta-margin histogram: linear bins over conf - theta in [-1, 1]
        self.margin_bins = int(margin_bins)
        self.margin_edges = np.linspace(-1.0, 1.0, self.margin_bins + 1)
        self.margin_counts = np.zeros(self.margin_bins, np.int64)
        self.decisions = 0
        self.outcomes = 0
        self.wasted_offload = 0     # offloaded though S was right
        self.missed_local = 0       # served local though S was wrong
        self.regret_cost = 0.0      # gate cost - oracle cost, paper units

    # -- scheduler hooks ----------------------------------------------------

    def _class(self, tclass: str) -> _ClassStats:
        cs = self.classes.get(tclass)
        if cs is None:
            cs = self.classes[tclass] = _ClassStats(self._bins)
        return cs

    def _margin(self, conf: float, theta: float) -> None:
        m = conf - theta
        i = int(np.searchsorted(self.margin_edges, m, side="right")) - 1
        self.margin_counts[min(max(i, 0), self.margin_bins - 1)] += 1

    def decision(self, *, rid: int, tier: str, tclass: str, kind: str,
                 conf: float, theta: float,
                 offload: Optional[bool] = None) -> None:
        """One gate evaluation.  ``theta`` is the threshold IN EFFECT for
        the decision (``FAIL_LOCAL_THETA`` while the breaker is open).
        ``offload`` defaults to ``conf < theta``; pass it explicitly where
        the decision is not a plain comparison (e.g. the speculative
        request-level roll-up)."""
        if offload is None:
            offload = conf < theta
        self.decisions += 1
        self._margin(conf, theta)
        cs = self._class(tclass)
        cs.evals += 1
        if kind == "request":
            cs.requests += 1
            cs.offloaded += bool(offload)
        self.records.append(AuditRecord(rid, tier, tclass, kind,
                                        float(conf), float(theta),
                                        bool(offload)))

    def outcome(self, *, rid: int, tier: str, tclass: str, conf: float,
                theta: float, ok: bool, kind: str = "draft") -> None:
        """One ground-truthed decision: the verify lane (kind ``draft``) or
        a completed escalation's S/L agreement (kind ``l_agree``).  Feeds
        the reliability bins, running ECE, and the empirical-regret
        counters."""
        ok = bool(ok)
        offload = conf < theta
        self.outcomes += 1
        self.overall.record(conf, ok)
        self._class(tclass).bins.record(conf, ok)
        # gate cost: beta per offload, 1 per wrong local answer; the oracle
        # (which sees ``ok``) pays min(beta, 1 - ok)
        if offload and ok:
            self.wasted_offload += 1
            self.regret_cost += self.beta
        elif not offload and not ok:
            self.missed_local += 1
            self.regret_cost += 1.0 - self.beta
        self.records.append(AuditRecord(rid, tier, tclass, kind,
                                        float(conf), float(theta),
                                        offload, ok))

    # -- exporters ----------------------------------------------------------

    def ece(self, tclass: Optional[str] = None) -> float:
        if tclass is None:
            return self.overall.ece()
        cs = self.classes.get(tclass)
        return cs.bins.ece() if cs is not None else 0.0

    def offload_rate(self, tclass: Optional[str] = None) -> float:
        if tclass is not None:
            cs = self.classes.get(tclass)
            return cs.offload_rate if cs is not None else 0.0
        req = sum(c.requests for c in self.classes.values())
        off = sum(c.offloaded for c in self.classes.values())
        return off / req if req else 0.0

    def reliability(self, tclass: Optional[str] = None
                    ) -> Dict[str, np.ndarray]:
        """``p_histogram``-shaped reliability bins (edges / correct /
        incorrect), overall or for one traffic class."""
        if tclass is None:
            return self.overall.as_dict()
        cs = self.classes.get(tclass)
        return cs.bins.as_dict() if cs is not None \
            else ReliabilityBins(self._bins).as_dict()

    def gauge_values(self) -> Dict[str, float]:
        """Compact per-tick aggregates — merged into the telemetry gauges,
        which makes them Chrome-trace counter tracks and flight-recorder
        snapshot fields for free.  All values are deterministic functions of
        the decision stream."""
        return {
            "audit_decisions": float(self.decisions),
            "audit_outcomes": float(self.outcomes),
            "audit_ece": round(self.overall.ece(), 9),
            "audit_offload_rate": round(self.offload_rate(), 9),
            "audit_regret_cost": round(self.regret_cost, 9),
            "audit_wasted_offload": float(self.wasted_offload),
            "audit_missed_local": float(self.missed_local),
        }

    def summary(self) -> Dict[str, Any]:
        return {
            "decisions": self.decisions,
            "outcomes": self.outcomes,
            "ece": self.overall.ece(),
            "offload_rate": self.offload_rate(),
            "regret": {"wasted_offload": self.wasted_offload,
                       "missed_local": self.missed_local,
                       "cost": self.regret_cost, "beta": self.beta},
            "classes": {
                t: {"evals": c.evals, "requests": c.requests,
                    "offloaded": c.offloaded,
                    "offload_rate": c.offload_rate,
                    "ece": c.bins.ece(), "outcomes": c.bins.count}
                for t, c in sorted(self.classes.items())},
        }

    def prometheus_lines(self) -> List[str]:
        """``hi_audit_*`` metric families, appended by
        ``Telemetry.prometheus_text`` when an audit is installed."""
        L: List[str] = []

        def fam(metric: str, mtype: str, help_: str) -> None:
            L.append(f"# HELP {metric} {help_}")
            L.append(f"# TYPE {metric} {mtype}")

        fam("hi_audit_decisions_total", "counter",
            "Gate decisions recorded by the audit stream.")
        L.append(f"hi_audit_decisions_total {self.decisions}")
        fam("hi_audit_outcomes_total", "counter",
            "Ground-truthed decisions (verify lane / L agreement).")
        L.append(f"hi_audit_outcomes_total {self.outcomes}")
        fam("hi_audit_regret_total", "counter",
            "Gate mistakes vs the verify-lane oracle, by kind.")
        L.append(f'hi_audit_regret_total{{kind="wasted_offload"}} '
                 f"{self.wasted_offload}")
        L.append(f'hi_audit_regret_total{{kind="missed_local"}} '
                 f"{self.missed_local}")
        fam("hi_audit_regret_cost", "counter",
            "Cumulative empirical regret vs the oracle (paper cost units).")
        L.append(f"hi_audit_regret_cost {self.regret_cost:.9f}")
        fam("hi_audit_ece", "gauge",
            "Running expected calibration error per traffic class "
            '(tclass="" = overall).')
        L.append(f'hi_audit_ece{{tclass=""}} {self.overall.ece():.9f}')
        for t, c in sorted(self.classes.items()):
            if t:
                L.append(f'hi_audit_ece{{tclass="{escape_label(t)}"}} '
                         f"{c.bins.ece():.9f}")
        fam("hi_audit_offload_rate", "gauge",
            "Offload rate over request-level gate decisions per traffic "
            'class (tclass="" = overall).')
        L.append(f'hi_audit_offload_rate{{tclass=""}} '
                 f"{self.offload_rate():.9f}")
        for t, c in sorted(self.classes.items()):
            if t:
                L.append(
                    f'hi_audit_offload_rate{{tclass="{escape_label(t)}"}} '
                    f"{c.offload_rate:.9f}")
        fam("hi_audit_reliability_total", "counter",
            "Correct/incorrect counts per confidence bin "
            "(p_histogram bin semantics).")
        for i in range(self._bins):
            lo, hi = self.overall.edges[i], self.overall.edges[i + 1]
            for outcome, arr in (("correct", self.overall.correct),
                                 ("incorrect", self.overall.incorrect)):
                if arr[i]:
                    L.append(
                        f'hi_audit_reliability_total{{bin="{lo:g}-{hi:g}",'
                        f'outcome="{outcome}"}} {int(arr[i])}')
        fam("hi_audit_theta_margin", "histogram",
            "Gate margin (conf - theta_in_effect) per decision.")
        cum = 0
        for i in range(self.margin_bins):
            cum += int(self.margin_counts[i])
            if self.margin_counts[i] and i < self.margin_bins - 1:
                L.append(f'hi_audit_theta_margin_bucket'
                         f'{{le="{self.margin_edges[i + 1]:g}"}} {cum}')
        L.append(f'hi_audit_theta_margin_bucket{{le="+Inf"}} '
                 f"{int(self.margin_counts.sum())}")
        L.append(f"hi_audit_theta_margin_count "
                 f"{int(self.margin_counts.sum())}")
        return L


# ---------------------------------------------------------------------------
# SLO watchdog
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SLOThresholds:
    """Watchdog limits; ``None`` disables a check.

    ``ttft_p95`` / ``tpot_p95`` are seconds against the telemetry
    histograms (need ``telemetry=`` installed); ``queue_depth`` bounds the
    L escalation queue; ``ece_max`` / ``offload_rate_max`` bound
    calibration drift against the audit stream (need ``audit=`` installed,
    evaluated once at least ``min_outcomes`` / ``min_requests`` ground
    truth samples exist)."""
    ttft_p95: Optional[float] = None
    tpot_p95: Optional[float] = None
    queue_depth: Optional[int] = None
    ece_max: Optional[float] = None
    offload_rate_max: Optional[float] = None
    min_outcomes: int = 20
    min_requests: int = 5


class SLOWatchdog:
    """Once-per-tick SLO evaluation over host state the scheduler already
    holds.  Breaches are appended to :attr:`breaches` (one dict per breach
    per tick: ``tick`` / ``kind`` / ``value`` / ``limit``), surfaced as
    telemetry instant events (Chrome ``i`` markers) and flight-recorder
    dump triggers by the scheduler."""

    def __init__(self, thresholds: SLOThresholds):
        self.thresholds = thresholds
        self.breaches: List[Dict[str, Any]] = []

    def evaluate(self, tick: int, *, tel=None, audit=None,
                 gauges: Optional[Dict[str, float]] = None
                 ) -> List[Dict[str, Any]]:
        th = self.thresholds
        found: List[Dict[str, Any]] = []

        def breach(kind: str, value: float, limit: float) -> None:
            found.append({"tick": tick, "kind": kind,
                          "value": float(value), "limit": float(limit)})

        if tel is not None:
            for name, limit in (("ttft", th.ttft_p95),
                                ("tpot", th.tpot_p95)):
                h = tel.hists.get(name)
                if limit is not None and h is not None and h.count:
                    v = h.quantile(0.95)
                    if v > limit:
                        breach(f"{name}_p95", v, limit)
        if gauges is not None and th.queue_depth is not None:
            v = gauges.get("l_queue_depth", 0.0)
            if v > th.queue_depth:
                breach("queue_depth", v, th.queue_depth)
        if audit is not None:
            if th.ece_max is not None and audit.outcomes >= th.min_outcomes:
                v = audit.ece()
                if v > th.ece_max:
                    breach("ece", v, th.ece_max)
            if th.offload_rate_max is not None:
                req = sum(c.requests for c in audit.classes.values())
                if req >= th.min_requests:
                    v = audit.offload_rate()
                    if v > th.offload_rate_max:
                        breach("offload_rate", v, th.offload_rate_max)
        self.breaches.extend(found)
        return found
