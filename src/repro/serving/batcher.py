"""Request batching: pad/pack variable-length prompts into fixed shapes.

XLA serving needs static shapes; the batcher rounds prompt lengths up to a
bucket and pads the batch to the engine's configured size (same discipline as
the HI router's static capacity).

Two consumers:

* :class:`Batcher` — the DRAIN path: accumulate ``batch_size`` requests, emit
  one fixed (B, bucket) batch for ``HIEngine.serve``.
* :class:`AdmissionQueue` — the CONTINUOUS path: requests are bucketized on
  submit and handed to the scheduler ONE at a time, the moment a decode slot
  frees up (``HIEngine.serve_stream``).  No draining, no batch boundary.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # <= 0 -> greedy
    eos_id: Optional[int] = None        # early stop (continuous path only)
    latency_budget: Optional[float] = None  # seconds; expired S->L escalations
    #                                       are dropped (the S answer stands)
    tclass: str = ""                    # traffic class for per-class gate
    #                                   audit aggregates (GateAudit); ""
    #                                   buckets into the overall stream only


@dataclass
class Batch:
    tokens: np.ndarray                   # (B, S) right-padded
    lengths: np.ndarray                  # (B,)
    request_ids: np.ndarray              # (B,) -1 = padding slot
    max_new_tokens: int

    @property
    def bucket(self) -> int:
        """The padded sequence length — with the batch size, this keys the
        engine's compiled-executable cache."""
        return self.tokens.shape[1]


def pad_to_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n.  A prompt longer than every bucket is an ERROR —
    silently clamping (the old behaviour) let the pack loop truncate the
    prompt and serve a corrupted request."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest bucket {max(buckets)}; "
        f"raise the bucket ladder or split the prompt")


class Batcher:
    """``sort_by_length`` groups same-bucket prompts into the same batch:
    fewer (batch, bucket) shapes reach the engine, so fewer compiled
    executables and less padding waste.  Off by default (FIFO preserves
    submission order / request latency fairness)."""

    def __init__(self, batch_size: int, buckets: Sequence[int] = (32, 64, 128),
                 pad_id: int = 0, sort_by_length: bool = False):
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.pad_id = pad_id
        self.sort_by_length = sort_by_length
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.buckets[-1]:
            raise ValueError(
                f"request {req.request_id}: prompt length {len(req.prompt)} "
                f"exceeds the largest bucket {self.buckets[-1]}")
        self.queue.append(req)

    def ready(self) -> bool:
        return len(self.queue) >= self.batch_size

    def next_batch(self) -> Optional[Batch]:
        if not self.queue:
            return None
        if self.sort_by_length:
            # stable: equal-length requests keep submission order
            self.queue.sort(key=lambda r: len(r.prompt))
        take = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        max_len = max(len(r.prompt) for r in take)
        bucket = pad_to_bucket(max_len, self.buckets)
        tokens = np.full((self.batch_size, bucket), self.pad_id, np.int32)
        lengths = np.zeros((self.batch_size,), np.int32)
        rids = np.full((self.batch_size,), -1, np.int32)
        for i, r in enumerate(take):
            L = len(r.prompt)
            tokens[i, :L] = r.prompt
            lengths[i] = L
            rids[i] = r.request_id
        return Batch(tokens, lengths, rids,
                     max(r.max_new_tokens for r in take))


@dataclass
class AdmittedRequest:
    """One request, bucketized and ready for a decode slot.

    ``page_hashes`` / ``full_hash`` are the prompt's content addresses in the
    KV pool's prefix index — computed ONCE here at submit (the prompt never
    changes) and reused by every tier the request visits, including the S→L
    escalation replay (the hashes key each tier's own index).
    """
    request: Request
    tokens: np.ndarray                  # (bucket,) right-padded to its bucket
    bucket: int                         # padded prompt length (= prefill pos)
    page_hashes: Optional[Tuple[bytes, ...]] = None  # rolling chain, per page
    full_hash: Optional[bytes] = None   # chain extended over the partial tail
    submit_time: float = 0.0            # monotonic; drives the drop policy
    admit_retries: int = 0              # fruitless admission ticks so far; the
    #                                     scheduler rejects the request outright
    #                                     past RetryPolicy.admit_retry_limit
    #                                     (reset when the S->L escalation
    #                                     re-enters L-tier admission)


def _chain(prev: bytes, chunk: np.ndarray) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(prev)
    h.update(np.ascontiguousarray(chunk, np.int32).tobytes())
    return h.digest()


def prompt_hashes(tokens: np.ndarray, page_size: int
                  ) -> Tuple[Tuple[bytes, ...], bytes]:
    """Rolling chain hash of a padded prompt at page granularity.

    ``h_i = H(h_{i-1} || tokens[i*page:(i+1)*page])`` — a chain hash keys the
    WHOLE prefix ending at page i, so a flat hash->page dict behaves as a
    prefix trie: walking a new prompt's chain until the first miss yields its
    longest cached prefix.  The full-prompt key extends the chain over the
    partial tail page (or a length-domain separator when the prompt is
    page-aligned, so it can never collide with a page key).
    """
    n_full = len(tokens) // page_size
    prev = b"hi-prefix-v1"
    hashes = []
    for i in range(n_full):
        prev = _chain(prev, tokens[i * page_size:(i + 1) * page_size])
        hashes.append(prev)
    tail = tokens[n_full * page_size:]
    full = _chain(prev, tail if len(tail)
                  else np.asarray([-1], np.int32))
    return tuple(hashes), full


class AdmissionQueue:
    """FIFO admission queue for the continuous scheduler.

    Requests are validated + bucketized at ``submit`` (same ``pad_to_bucket``
    ladder as the drain path, so the two paths see IDENTICAL padded prompts —
    the token-equivalence guarantee depends on this) and popped one at a time
    as slots free up.  When ``page_size`` is set, submit also content-hashes
    the padded prompt for the pool's prefix index.
    """

    def __init__(self, buckets: Sequence[int] = (32, 64, 128),
                 pad_id: int = 0, page_size: Optional[int] = None):
        self.buckets = tuple(sorted(buckets))
        self.pad_id = pad_id
        self.page_size = page_size
        self._queue: List[AdmittedRequest] = []
        self.submitted = 0

    def submit(self, req: Request) -> None:
        bucket = pad_to_bucket(len(req.prompt), self.buckets)   # raises if too long
        tokens = np.full((bucket,), self.pad_id, np.int32)
        tokens[: len(req.prompt)] = req.prompt
        hashes = full = None
        if self.page_size:
            hashes, full = prompt_hashes(tokens, self.page_size)
        self._queue.append(AdmittedRequest(req, tokens, bucket, hashes, full,
                                           time.monotonic()))
        self.submitted += 1

    def pop(self) -> Optional[AdmittedRequest]:
        return self._queue.pop(0) if self._queue else None

    def push_front(self, adm: AdmittedRequest) -> None:
        """Put a popped request back at the head (admission retry)."""
        self._queue.insert(0, adm)

    # deque-compatible aliases: the scheduler treats the S-tier admission
    # queue and the L-tier escalation deque through one head-pop interface
    popleft = pop
    appendleft = push_front

    def __len__(self) -> int:
        return len(self._queue)
