"""Request batching: pad/pack variable-length prompts into fixed shapes.

XLA serving needs static shapes; the batcher rounds prompt lengths up to a
bucket and pads the batch to the engine's configured size (same discipline as
the HI router's static capacity).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16


@dataclass
class Batch:
    tokens: np.ndarray                   # (B, S) right-padded
    lengths: np.ndarray                  # (B,)
    request_ids: np.ndarray              # (B,) -1 = padding slot
    max_new_tokens: int

    @property
    def bucket(self) -> int:
        """The padded sequence length — with the batch size, this keys the
        engine's compiled-executable cache."""
        return self.tokens.shape[1]


def pad_to_bucket(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class Batcher:
    """``sort_by_length`` groups same-bucket prompts into the same batch:
    fewer (batch, bucket) shapes reach the engine, so fewer compiled
    executables and less padding waste.  Off by default (FIFO preserves
    submission order / request latency fairness)."""

    def __init__(self, batch_size: int, buckets: Sequence[int] = (32, 64, 128),
                 pad_id: int = 0, sort_by_length: bool = False):
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.pad_id = pad_id
        self.sort_by_length = sort_by_length
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def ready(self) -> bool:
        return len(self.queue) >= self.batch_size

    def next_batch(self) -> Optional[Batch]:
        if not self.queue:
            return None
        if self.sort_by_length:
            # stable: equal-length requests keep submission order
            self.queue.sort(key=lambda r: len(r.prompt))
        take = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        max_len = max(len(r.prompt) for r in take)
        bucket = pad_to_bucket(max_len, self.buckets)
        tokens = np.full((self.batch_size, bucket), self.pad_id, np.int32)
        lengths = np.zeros((self.batch_size,), np.int32)
        rids = np.full((self.batch_size,), -1, np.int32)
        for i, r in enumerate(take):
            L = min(len(r.prompt), bucket)
            tokens[i, :L] = r.prompt[:L]
            lengths[i] = L
            rids[i] = r.request_id
        return Batch(tokens, lengths, rids,
                     max(r.max_new_tokens for r in take))
