"""Serving telemetry: typed counters, per-request span trees, tick-phase
timing, pool gauges, and streaming histograms — zero overhead when disabled.

The paper's claims are latency/bandwidth/energy claims, and every planned
policy (the arXiv:2112.11413 drop rule, online theta per arXiv:2304.00891)
acts on per-request, per-phase timing signals.  This module is where those
signals live.  Everything here is HOST-side bookkeeping over state the
scheduler already holds: enabling telemetry never adds a device dispatch, a
host sync, or an operand — ``stream_compiles == 1`` and one-sync-per-tick
are untouched (tests/test_telemetry.py asserts both), and with telemetry
disabled (the default) the scheduler's hooks are a single ``is None`` test.

Three faces:

**1. Typed counters** (:class:`SchedCounters` / :class:`EngineCounters`).
The ad-hoc ``stats`` dicts of ``ContinuousScheduler`` and ``HIEngine`` are
now read/write VIEWS (:class:`StatsView` / :class:`EngineStatsView`) over
dataclasses of typed fields — same dict API (``stats["ticks"]``,
``.items()``, ``**stats``), no test or bench churn, but one authoritative
store.  The engine no longer copies-and-zeroes the scheduler's fault
counters: :class:`EngineStatsView` reads the live scheduler's counters
through the view (``engine total = retired base + live scheduler``), so the
two can never diverge (test-asserted).

**2. Per-request span trees** (:class:`Telemetry`, :class:`Span`,
:class:`RequestTrace`).  Every request accumulates a flat list of spans that
reads as the tree::

    queued -> admitted -> prefill_chunk[i] -> decode_block[j]
           -> escalate_attempt[k] -> l_verify -> terminal

with the terminal status (``ok`` / ``degraded_local`` / ``dropped`` /
``rejected``), TTFT, TPOT, queue-wait ticks, and retry counts attached.
Span kinds:

* ``queued``          — submit to S-tier slot admission;
* ``admitted``        — the admission tick (args: tier, slot, prefill
  ``start``, ``chunked``/``restore`` flags);
* ``prefill_chunk``   — one span per chunk-lane tick (args: ``i`` chunk
  index, ``fed``, ``keep``);
* ``decode_block``    — one span per tick the slot decoded (args: ``j``
  block index, ``steps``);
* ``escalate_attempt``— one span per S->L transport attempt, send to
  arrival/failure (args: ``k`` = attempt, ``outcome``);
* ``l_verify``        — L-tier residency for the escalation (admission to
  finish/abort); in speculative mode, one per escalated verify block;
* ``terminal``        — zero-length marker carrying the final status.

Span timestamps are ``time.monotonic()`` seconds (the clock the scheduler
already uses for ``submit_time``/TTFT); device work inside a tick is
attributed to the tick's wall bracket — the host cannot see finer without a
second sync, which telemetry refuses to add by design.

**3. Tick-phase timing + gauges** (:class:`TickRecord`).  Each scheduler
tick is decomposed host-side into wall-time buckets:

* ``fault_tick``     — breaker/transport/drop bookkeeping + slot admission;
* ``build_operands`` — numpy operand assembly (``tick_inputs``);
* ``dispatch``       — executable call (submit; XLA may run async);
* ``host_fetch``     — the tick's single device->host sync (device time
  surfaces here on async backends);
* ``postprocess``    — token absorb, finish/escalation bookkeeping.

plus per-tick pool gauges sampled from host state the scheduler already
holds: free pages, total refcounts, prefix-index size, COW copies, breaker
state, L-queue depth, in-flight escalations, busy slots per tier.

Decision-quality observability (``serving/audit.py``, PR 9)
-----------------------------------------------------------
The time-blind half of observability lives next door: ``GateAudit`` is the
per-decision gate audit stream.  Each :class:`~repro.serving.audit.
AuditRecord` carries ``(rid, tier, tclass, kind, conf, theta_in_effect,
offload, ok)`` where ``kind`` is one of ``admit`` / ``chunk`` / ``decode``
(per-token gate evaluations), ``block`` (speculative draft-block escalation
decision), ``request`` (the request-level escalation decision), ``draft``
(a verify-lane ground-truthed position, ``ok`` = L accepted the S token)
or ``l_agree`` (completed escalation: S tokens matched L's).
``theta_in_effect`` records the threshold the device ACTUALLY used —
``FAIL_LOCAL_THETA`` while the circuit breaker is open.  Aggregates:
streaming reliability bins (``core/calibrate.p_histogram`` bin semantics),
running ECE + offload-rate per ``Request.tclass`` traffic class, a
theta-margin histogram, and empirical-regret counters vs the verify-lane
oracle.  When both collectors are installed the scheduler binds
``telemetry.audit = audit`` so the ``hi_audit_*`` families ride
:meth:`Telemetry.prometheus_text` and the audit gauges become Chrome-trace
counter tracks.

The SLO watchdog (:class:`~repro.serving.audit.SLOWatchdog`, configured by
:class:`~repro.serving.audit.SLOThresholds`: ``ttft_p95`` / ``tpot_p95``
seconds, ``queue_depth``, ``ece_max`` / ``offload_rate_max`` drift bounds
with ``min_outcomes`` / ``min_requests`` warm-up floors) is evaluated once
per tick; breaches append to ``watchdog.breaches``, emit
:meth:`Telemetry.instant` events (Chrome ``i`` markers), and trigger the
flight recorder (``serving/flight_recorder.py`` — a bounded ring of
deterministic per-tick snapshots dumped as postmortem JSON on watchdog
breach, breaker-open, ``check_invariants`` failure, or the idle-tick
stall bound).

Exporters
---------
* :meth:`Telemetry.histogram_summary` — log-bucketed streaming histograms
  (TTFT / TPOT / queue-wait / escalation latency) with p50/p95/p99;
* :meth:`Telemetry.prometheus_text` — a Prometheus text-format snapshot.
  Every family carries ``# HELP``/``# TYPE`` lines and label values are
  escaped per the text exposition format.  Keys: ``hi_<counter>_total``
  one per :class:`SchedCounters` field (e.g. ``hi_requests_total``,
  ``hi_degraded_local_total``),
  ``hi_tick_phase_seconds_total{phase=...}`` per tick-phase bucket,
  ``hi_gauge{name=...,tier=...}`` last-sampled pool gauges, and per
  histogram ``hi_<name>_seconds`` a ``_count`` / ``_sum`` /
  ``_bucket{le=...}`` family (``hi_ttft_seconds``, ``hi_tpot_seconds``,
  ``hi_queue_wait_ticks``, ``hi_esc_latency_seconds``; the unbounded
  overflow bucket folds into ``+Inf`` — no finite ``le`` edge).  With a
  ``GateAudit`` bound, the audit families are appended:
  ``hi_audit_decisions_total``, ``hi_audit_outcomes_total``,
  ``hi_audit_regret_total{kind=...}``, ``hi_audit_regret_cost``,
  ``hi_audit_ece{tclass=...}``, ``hi_audit_offload_rate{tclass=...}``,
  ``hi_audit_reliability_total{bin=...,outcome=...}``, and
  ``hi_audit_theta_margin`` (histogram);
* ``serving/trace_export.py`` — Chrome ``trace_event`` JSON (one track per
  slot per tier, escalations as S->L flow events, watchdog breaches as
  instant markers, audit aggregates as counter tracks), loadable in
  Perfetto.

``benchmarks/bench_serving.py --trace-out`` wires it to traffic and reports
the overhead (budget: <2% req/s when enabled, 0 when disabled — gated in CI
by ``--telemetry-smoke``; the audit stream has the same budget, gated by
``--audit-smoke``).
"""
from __future__ import annotations

import math
import time
from collections.abc import MutableMapping
from dataclasses import dataclass, field, fields
from typing import Any, Dict, List, Optional, Tuple

# Tick-phase wall-time buckets, in intra-tick order.  ``transfer_overlap``
# only accumulates in mesh mode: it brackets the escalation staging-buffer
# operand build + device copy dispatched at tick top, i.e. the S->L transfer
# work that the sharded executable overlaps with the same tick's S-side
# prefill/decode (bench_serving --mesh-smoke asserts it is nonzero).
PHASES = ("fault_tick", "transfer_overlap", "build_operands", "dispatch",
          "host_fetch", "postprocess")

_now = time.monotonic

# one-line HELP strings for the prometheus_text metric families
_HELP = {
    "hi_tick_phase_seconds_total":
        "Cumulative wall seconds per scheduler tick phase.",
    "hi_gauge":
        "Last-sampled per-tick pool/breaker/queue gauge (tier-labelled).",
    "ttft": "Time to first token.",
    "tpot": "Time per output token (after the first).",
    "queue_wait": "Escalation queue wait.",
    "esc_latency": "Escalation send-to-terminal latency.",
}


def escape_label(value: str) -> str:
    """Escape a Prometheus label VALUE per the text exposition format
    (backslash, double quote, newline)."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


# ---------------------------------------------------------------------------
# typed counters + dict views
# ---------------------------------------------------------------------------

@dataclass
class SchedCounters:
    """The ContinuousScheduler's typed counters (one instance per scheduler;
    ``scheduler.stats`` is a :class:`StatsView` over it)."""
    requests: int = 0
    offloaded: int = 0
    dropped: int = 0
    ticks: int = 0
    compiles: int = 0
    serve_time: float = 0.0
    blocks: int = 0
    escalated_blocks: int = 0
    drafted: int = 0
    accepted: int = 0
    degraded_local: int = 0
    rejected: int = 0
    breaker_open_ticks: int = 0
    breaker_opens: int = 0
    esc_retries: int = 0
    esc_lost: int = 0


@dataclass
class EngineCounters:
    """HIEngine's own counter store.  For the keys the scheduler also
    counts, this holds the RETIRED base (drain-path contributions plus the
    folded totals of replaced schedulers); :class:`EngineStatsView` adds the
    live scheduler's counters on read."""
    requests: int = 0
    offloaded: int = 0
    dropped: int = 0
    serve_time: float = 0.0
    compiles: int = 0
    stream_compiles: int = 0
    stream_ticks: int = 0
    prefill_tokens_saved: int = 0
    degraded_local: int = 0
    rejected: int = 0
    breaker_open_ticks: int = 0
    breaker_opens: int = 0
    esc_retries: int = 0
    esc_lost: int = 0


class StatsView(MutableMapping):
    """Dict-API view over a counters dataclass: ``view["ticks"] += 1``
    mutates ``counters.ticks``.  Unknown keys raise KeyError (typos that a
    plain dict would silently absorb)."""

    def __init__(self, counters: Any):
        self._c = counters
        self._keys = tuple(f.name for f in fields(counters))

    def __getitem__(self, k):
        if k not in self._keys:
            raise KeyError(k)
        return getattr(self._c, k)

    def __setitem__(self, k, v):
        if k not in self._keys:
            raise KeyError(k)
        setattr(self._c, k, v)

    def __delitem__(self, k):
        raise TypeError("typed counters cannot be deleted")

    def __iter__(self):
        return iter(self._keys)

    def __len__(self):
        return len(self._keys)

    def __repr__(self):
        return f"StatsView({dict(self)})"


# engine key -> scheduler counter attribute it mirrors live
_MIRROR = {
    "requests": "requests", "offloaded": "offloaded", "dropped": "dropped",
    "serve_time": "serve_time", "stream_ticks": "ticks",
    "degraded_local": "degraded_local", "rejected": "rejected",
    "breaker_open_ticks": "breaker_open_ticks",
    "breaker_opens": "breaker_opens", "esc_retries": "esc_retries",
    "esc_lost": "esc_lost",
}


class EngineStatsView(StatsView):
    """Engine stats = retired base + the LIVE scheduler's typed counters.

    The engine used to copy the scheduler's fault counters key by key after
    every ``serve_stream`` and zero the originals — two stores that could
    silently diverge.  Now there is one authority: the scheduler's
    :class:`SchedCounters`.  Reads of a mirrored key add the attached
    scheduler's live value; writes adjust the base so the observed total
    becomes the written value (``stats[k] += x`` adds exactly ``x``
    regardless of live activity).  ``prefill_tokens_saved`` mirrors the
    pools' prefix stats the same way.  When the engine replaces its cached
    scheduler, :meth:`detach` folds the live totals into the base so
    nothing is lost."""

    def __init__(self, counters: EngineCounters):
        super().__init__(counters)
        self._sched = None

    def attach(self, sched) -> None:
        if self._sched is not None and self._sched is not sched:
            self.detach()
        self._sched = sched

    def detach(self) -> None:
        """Fold the attached scheduler's live counters into the base."""
        if self._sched is None:
            return
        sched, self._sched = self._sched, None
        for k in tuple(_MIRROR) + ("prefill_tokens_saved",):
            setattr(self._c, k, getattr(self._c, k) + self._live(sched, k))

    @staticmethod
    def _live(sched, k):
        if k == "prefill_tokens_saved":
            return sched.prefix_stats.get("tokens_saved", 0)
        return getattr(sched.counters, _MIRROR[k])

    def __getitem__(self, k):
        v = super().__getitem__(k)
        if self._sched is not None and (k in _MIRROR
                                        or k == "prefill_tokens_saved"):
            v = v + self._live(self._sched, k)
        return v

    def __setitem__(self, k, v):
        if self._sched is not None and (k in _MIRROR
                                        or k == "prefill_tokens_saved"):
            v = v - self._live(self._sched, k)
        super().__setitem__(k, v)


# ---------------------------------------------------------------------------
# streaming histograms
# ---------------------------------------------------------------------------

class Histogram:
    """Streaming log-bucketed histogram: base-2 buckets over [lo, hi).

    Bucket 0 is the underflow (< lo), the last bucket the overflow; bucket
    ``i`` covers ``[lo * 2^(i-1), lo * 2^i)``.  Constant memory, O(1)
    record, quantiles by cumulative-count walk (geometric-midpoint estimate
    within the landing bucket — exact min/max are tracked separately)."""

    def __init__(self, lo: float = 1e-4, hi: float = 100.0,
                 unit: str = "seconds"):
        if lo <= 0 or hi <= lo:
            raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
        self.lo = lo
        self.unit = unit
        self.n_buckets = int(math.ceil(math.log2(hi / lo))) + 2
        self.counts = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, v: float) -> None:
        if not math.isfinite(v):
            return
        if v < self.lo:
            i = 0
        else:
            i = min(self.n_buckets - 1,
                    1 + int(math.floor(math.log2(v / self.lo))))
        self.counts[i] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def upper_edge(self, i: int) -> float:
        return self.lo * 2.0 ** i           # bucket i covers [edge/2, edge)

    def quantile(self, q: float) -> float:
        if not self.count:
            return math.nan
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                if i == 0:
                    return min(self.lo, self.vmax)
                hi = min(self.upper_edge(i), self.vmax)
                lo = max(self.upper_edge(i - 1), self.vmin)
                return math.sqrt(lo * hi) if lo > 0 else hi / 2
        return self.vmax

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {"count": self.count,
                "mean": self.total / self.count,
                "min": self.vmin, "max": self.vmax,
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


# ---------------------------------------------------------------------------
# spans + traces + ticks
# ---------------------------------------------------------------------------

@dataclass
class Span:
    kind: str                   # queued / admitted / prefill_chunk / ...
    t0: float                   # monotonic seconds
    t1: float                   # t0 == t1 for instant markers
    tier: str                   # "S" / "L" / "" (scheduler-level)
    slot: int = -1
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class RequestTrace:
    """One request's span tree (flat list, tree by construction order)."""
    rid: int
    submit_t: float = 0.0
    spans: List[Span] = field(default_factory=list)
    status: str = ""            # set at terminal
    ttft: float = math.nan
    tpot: float = math.nan
    n_tokens: int = 0
    queue_wait_ticks: int = 0
    escalation_retries: int = 0

    @property
    def complete(self) -> bool:
        return bool(self.status)


@dataclass
class TickRecord:
    index: int                  # global scheduler tick number
    t0: float
    t1: float = 0.0
    # ordered (phase, start, end) wall segments within the tick
    segments: List[Tuple[str, float, float]] = field(default_factory=list)
    gauges: Dict[str, float] = field(default_factory=dict)


class Telemetry:
    """Per-run collector threaded through ContinuousScheduler / HIEngine.

    The scheduler holds ``tel = None`` by default; every hook call site is
    guarded by ``if tel is not None`` — disabled telemetry costs one branch
    per site and allocates nothing.  One Telemetry instance may span several
    ``serve_stream`` calls (counters/histograms accumulate; ticks/spans
    append)."""

    def __init__(self):
        self.traces: Dict[int, RequestTrace] = {}
        self.ticks: List[TickRecord] = []
        self.phase_time: Dict[str, float] = {p: 0.0 for p in PHASES}
        self.hists: Dict[str, Histogram] = {
            "ttft": Histogram(1e-4, 100.0),
            "tpot": Histogram(1e-5, 100.0),
            "queue_wait": Histogram(0.5, 4096.0, unit="ticks"),
            "esc_latency": Histogram(1e-4, 100.0),
        }
        self.counters: Optional[SchedCounters] = None   # bound by scheduler
        # GateAudit bound by the scheduler when both are installed — its
        # hi_audit_* families ride prometheus_text; None = no audit lines
        self.audit = None
        # (t, name, args) instant events (SLO watchdog breaches) — rendered
        # as Chrome ``i`` markers on the scheduler track by trace_export
        self.events: List[Tuple[float, str, Dict[str, Any]]] = []
        self._tick: Optional[TickRecord] = None
        self._mark_t = 0.0
        # (rid, kind) -> open span awaiting its close
        self._open: Dict[Tuple[int, str], Span] = {}
        # per-(rid, kind) occurrence counters for the [i]/[j]/[k] indices
        self._seq: Dict[Tuple[int, str], int] = {}

    # -- tick lifecycle -----------------------------------------------------

    def begin_tick(self, index: int) -> None:
        t = _now()
        self._tick = TickRecord(index=index, t0=t)
        self._mark_t = t

    def mark(self, phase: str) -> None:
        """Close the wall segment since the previous mark under ``phase``."""
        t = _now()
        tick = self._tick
        if tick is not None:
            tick.segments.append((phase, self._mark_t, t))
        self.phase_time[phase] = self.phase_time.get(phase, 0.0) \
            + (t - self._mark_t)
        self._mark_t = t

    def end_tick(self, gauges: Dict[str, float]) -> None:
        tick = self._tick
        if tick is None:
            return
        tick.t1 = _now()
        tick.gauges = gauges
        self.ticks.append(tick)
        self._tick = None

    def instant(self, name: str, **args: Any) -> None:
        """Record a scheduler-level instant event (e.g. an SLO watchdog
        breach) — exported as a Chrome ``i`` marker on the tick track."""
        self.events.append((_now(), name, args))

    @property
    def tick_bracket(self) -> Tuple[float, float]:
        """(start, now) of the in-flight tick — device work inside the tick
        is attributed to this bracket."""
        t = _now()
        return (self._tick.t0 if self._tick is not None else t, t)

    # -- request spans ------------------------------------------------------

    def _trace(self, rid: int, submit_t: float = 0.0) -> RequestTrace:
        tr = self.traces.get(rid)
        if tr is None:
            tr = self.traces[rid] = RequestTrace(rid, submit_t=submit_t)
        return tr

    def _next_idx(self, rid: int, kind: str) -> int:
        i = self._seq.get((rid, kind), 0)
        self._seq[(rid, kind)] = i + 1
        return i

    def span_point(self, rid: int, kind: str, tier: str, slot: int,
                   **args) -> Span:
        """Closed span covering the current tick bracket."""
        t0, t1 = self.tick_bracket
        sp = Span(kind, t0, t1, tier, slot, args)
        self._trace(rid).spans.append(sp)
        return sp

    def span_open(self, rid: int, kind: str, tier: str, slot: int,
                  **args) -> Span:
        sp = Span(kind, _now(), math.nan, tier, slot, args)
        self._trace(rid).spans.append(sp)
        self._open[(rid, kind)] = sp
        return sp

    def span_close(self, rid: int, kind: str, **args) -> Optional[Span]:
        sp = self._open.pop((rid, kind), None)
        if sp is not None:
            sp.t1 = _now()
            sp.args.update(args)
        return sp

    # -- scheduler hooks ----------------------------------------------------

    def req_admitted(self, tier: str, slot: int, rid: int, submit_t: float,
                     *, chunked: bool = False, restore: bool = False,
                     start: int = 0) -> None:
        tr = self._trace(rid, submit_t)
        t0, t1 = self.tick_bracket
        # mesh mode names the S replicas "S0".."S{R-1}"; every S-side tier
        # label starts the queued span, the exact "L" label opens l_verify
        if tier.startswith("S") and not any(s.kind == "queued"
                                            for s in tr.spans):
            tr.submit_t = submit_t
            tr.spans.append(Span("queued", submit_t, t0, "S"))
        tr.spans.append(Span("admitted", t0, t1, tier, slot,
                             {"chunked": chunked, "restore": restore,
                              "start": start}))
        if tier == "L":
            # L residency: admission to finish/abort
            self.span_open(rid, "l_verify", "L", slot)

    def req_chunk(self, tier: str, slot: int, rid: int, fed: int,
                  keep: int) -> None:
        self.span_point(rid, "prefill_chunk", tier, slot,
                        i=self._next_idx(rid, f"{tier}:prefill_chunk"),
                        fed=fed, keep=keep)

    def req_decode(self, tier: str, slot: int, rid: int, steps: int) -> None:
        self.span_point(rid, "decode_block", tier, slot,
                        j=self._next_idx(rid, f"{tier}:decode_block"),
                        steps=steps)

    def req_esc_send(self, rid: int, slot: int, attempt: int) -> None:
        self.span_open(rid, "escalate_attempt", "S", slot, k=attempt)

    def req_esc_end(self, rid: int, outcome: str) -> None:
        """Close the in-flight escalate_attempt span: ``outcome`` is
        ``arrived`` / ``lost`` / ``timeout`` / ``aborted`` / ``gave_up``."""
        self.span_close(rid, "escalate_attempt", outcome=outcome)

    def req_esc_retry(self, rid: int, attempt: int,
                      resend_tick: int) -> None:
        self.span_point(rid, "escalate_backoff", "S", -1, k=attempt,
                        resend_tick=resend_tick)

    def req_l_verify(self, slot: int, rid: int, accepted: int,
                     emitted: int) -> None:
        """Speculative path: one escalated verify block."""
        self.span_point(rid, "l_verify", "L", slot, accepted=accepted,
                        emitted=emitted)

    def req_l_release(self, rid: int, outcome: str) -> None:
        self.span_close(rid, "l_verify", outcome=outcome)

    def req_terminal(self, rid: int, record: Dict[str, Any]) -> None:
        """The request reached its FINAL status: close open spans, stamp the
        terminal marker, and feed the latency histograms."""
        tr = self._trace(rid)
        t = _now()
        self.req_esc_end(rid, "gave_up")
        self.req_l_release(rid, record.get("status", ""))
        tr.status = str(record.get("status", "ok"))
        tr.ttft = float(record.get("ttft", math.nan))
        tr.n_tokens = int(len(record.get("tokens", ())))
        tr.queue_wait_ticks = int(record.get("queue_wait_ticks", 0))
        tr.escalation_retries = int(record.get("escalation_retries", 0))
        if tr.n_tokens > 1 and math.isfinite(tr.ttft):
            first = tr.submit_t + tr.ttft
            tr.tpot = max(t - first, 0.0) / (tr.n_tokens - 1)
            self.hists["tpot"].record(tr.tpot)
        if math.isfinite(tr.ttft):
            self.hists["ttft"].record(tr.ttft)
        self.hists["queue_wait"].record(tr.queue_wait_ticks)
        esc0 = next((s for s in tr.spans
                     if s.kind == "escalate_attempt"), None)
        if esc0 is not None:
            self.hists["esc_latency"].record(t - esc0.t0)
        tr.spans.append(Span("terminal", t, t, "S", -1,
                             {"status": tr.status}))

    # -- exporters ----------------------------------------------------------

    def request_records(self) -> List[Dict[str, Any]]:
        """Structured per-request records (the span-tree face), alongside —
        never replacing — the scheduler's result records."""
        out = []
        for rid in sorted(self.traces):
            tr = self.traces[rid]
            out.append({
                "request_id": rid,
                "status": tr.status,
                "ttft": tr.ttft,
                "tpot": tr.tpot,
                "n_tokens": tr.n_tokens,
                "queue_wait_ticks": tr.queue_wait_ticks,
                "escalation_retries": tr.escalation_retries,
                "spans": [{"kind": s.kind, "tier": s.tier, "slot": s.slot,
                           "t0": s.t0, "t1": s.t1, **s.args}
                          for s in tr.spans],
            })
        return out

    def histogram_summary(self) -> Dict[str, Dict[str, float]]:
        return {name: h.summary() for name, h in self.hists.items()}

    def phase_summary(self) -> Dict[str, float]:
        """Cumulative wall seconds per tick-phase bucket."""
        return dict(self.phase_time)

    def prometheus_text(self) -> str:
        """Prometheus text-format snapshot (see module docstring for the
        key schema).  Every family carries ``# HELP`` + ``# TYPE`` lines,
        label values are escaped per the text exposition format, and the
        histograms' unbounded overflow bucket is folded into ``+Inf``
        (finite ``le`` edges stop at the last bounded bucket)."""
        lines: List[str] = []
        if self.counters is not None:
            for f in fields(self.counters):
                v = getattr(self.counters, f.name)
                metric = f"hi_{f.name}_total"
                lines.append(f"# HELP {metric} Cumulative scheduler "
                             f"counter '{f.name}'.")
                lines.append(f"# TYPE {metric} counter")
                lines.append(f"{metric} {v}")
        lines.append("# HELP hi_tick_phase_seconds_total "
                     f"{_HELP['hi_tick_phase_seconds_total']}")
        lines.append("# TYPE hi_tick_phase_seconds_total counter")
        for p in PHASES:
            lines.append(
                f'hi_tick_phase_seconds_total{{phase="{escape_label(p)}"}} '
                f"{self.phase_time.get(p, 0.0):.9f}")
        if self.ticks:
            lines.append(f"# HELP hi_gauge {_HELP['hi_gauge']}")
            lines.append("# TYPE hi_gauge gauge")
            for k, v in sorted(self.ticks[-1].gauges.items()):
                name, _, tier = k.partition("@")
                # mesh-replica tiers ("S0".."S{R-1}") split into a stable
                # tier="S" plus a replica label, so one PromQL selector
                # aggregates over replicas; plain "S"/"L" stay single-label
                if len(tier) > 1 and tier[0] == "S" and tier[1:].isdigit():
                    tag = f',tier="S",replica="{tier[1:]}"'
                elif tier:
                    tag = f',tier="{escape_label(tier)}"'
                else:
                    tag = ""
                lines.append(
                    f'hi_gauge{{name="{escape_label(name)}"{tag}}} {v}')
        for name, h in self.hists.items():
            unit = h.unit
            metric = f"hi_{name}_{unit}"
            lines.append(f"# HELP {metric} "
                         f"{_HELP.get(name, f'{name} distribution.')}")
            lines.append(f"# TYPE {metric} histogram")
            cum = 0
            # the last bucket is the unbounded overflow: it must NOT emit a
            # finite ``le`` edge — its count reaches the +Inf line only
            last = h.n_buckets - 1
            for i, c in enumerate(h.counts):
                cum += c
                if c and i < last:
                    edge = h.upper_edge(i)
                    lines.append(f'{metric}_bucket{{le="{edge:g}"}} {cum}')
            lines.append(f'{metric}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{metric}_sum {h.total:.9f}")
            lines.append(f"{metric}_count {h.count}")
        if self.audit is not None:
            lines.extend(self.audit.prometheus_lines())
        return "\n".join(lines) + "\n"
