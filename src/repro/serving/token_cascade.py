"""Token-level HI: the cascade at BLOCK granularity inside one generation.

The paper gates whole samples; its §2 notes early-exit (BranchyNet-style)
composes with HI.  For LM serving the natural unit between "sample" and
"layer" is a BLOCK of tokens: the S-tier drafts a block of k tokens with
per-token confidences (the same fused hi_gate statistic); if the minimum
confidence in the block falls under theta, the L-tier regenerates the block
— catching its cache up by prefilling the accepted prefix (one bulk forward,
not k decode steps) and decoding the block itself.

Cost accounting mirrors the paper exactly, one level down:
  - accepted blocks cost only the S-tier draft;
  - escalated blocks cost beta (the L-tier catch-up + regeneration).
Savings = (1 - escalated_fraction) of the L-tier work, with the S-tier draft
as the paper's "extra local inference" term.

Decoder-only text families; host-driven loop over jitted per-tier programs
(the same architecture as HIEngine, one granularity finer).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core.confidence import confidence as conf_fn
from repro.models import model_zoo
from repro.serving import sampler


def _draft_block(params, cfg: ModelConfig, cache, last_logits, steps: int,
                 metric: str):
    """Greedy-draft ``steps`` tokens from current logits; returns
    (tokens (B, steps), min confidence (B,), cache, last logits)."""

    def body(carry, _):
        cache, logits = carry
        tok = sampler.greedy(logits)
        conf = conf_fn(logits, metric)
        logits, cache = model_zoo.decode_step(params, cfg, tok[:, None], cache)
        return (cache, logits), (tok, conf)

    (cache, logits), (toks, confs) = jax.lax.scan(
        body, (cache, last_logits), None, length=steps)
    return toks.T, confs.min(axis=0), cache, logits


def _feed_tokens(params, cfg: ModelConfig, cache, tokens):
    """Catch a tier's cache up over ``tokens`` (B, K); returns last logits."""

    def body(carry, t):
        cache, _ = carry
        logits, cache = model_zoo.decode_step(params, cfg, t[:, None], cache)
        return (cache, logits), None

    b = tokens.shape[0]
    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((b, cfg.vocab_size))), tokens.T)
    return cache, logits


@dataclass
class TokenCascade:
    """Block-granularity HI over one batched generation."""

    s_cfg: ModelConfig
    l_cfg: ModelConfig
    s_params: Any
    l_params: Any
    hi: HIConfig
    block: int = 4
    cache_len: int = 128

    def __post_init__(self):
        self._s_draft = jax.jit(partial(_draft_block, cfg=self.s_cfg,
                                        steps=self.block,
                                        metric=self.hi.metric))
        self._s_feed = jax.jit(partial(_feed_tokens, cfg=self.s_cfg))
        self._l_feed = jax.jit(partial(_feed_tokens, cfg=self.l_cfg))
        self._l_draft = jax.jit(partial(_draft_block, cfg=self.l_cfg,
                                        steps=self.block,
                                        metric=self.hi.metric))
        self.stats = {"blocks": 0, "escalated": 0}

    def generate(self, prompt: np.ndarray, num_blocks: int) -> Dict[str, Any]:
        """prompt: (B, P) -> dict(tokens (B, num_blocks*block), stats).

        The whole batch escalates a block together (static shapes); per-
        request escalation is the sample-level router's job one level up.
        """
        b = prompt.shape[0]
        s_cache = model_zoo.init_cache(self.s_cfg, b, self.cache_len)
        l_cache = model_zoo.init_cache(self.l_cfg, b, self.cache_len)
        prompt_j = jnp.asarray(prompt)
        s_cache, s_logits = self._s_feed(self.s_params, cache=s_cache,
                                         tokens=prompt_j)
        l_cache, l_logits = self._l_feed(self.l_params, cache=l_cache,
                                         tokens=prompt_j)

        out: List[np.ndarray] = []
        for _ in range(num_blocks):
            toks, conf, s_cache_new, s_logits_new = self._s_draft(
                self.s_params, cache=s_cache, last_logits=s_logits)
            self.stats["blocks"] += 1
            if float(conf.min()) < self.hi.theta:
                # escalate: L regenerates the block from ITS state
                self.stats["escalated"] += 1
                toks, _, l_cache, l_logits = self._l_draft(
                    self.l_params, cache=l_cache, last_logits=l_logits)
                # S must follow L's choice: rewind by re-feeding L's tokens
                s_cache, s_logits = self._s_feed(self.s_params, cache=s_cache,
                                                 tokens=toks)
            else:
                # accepted: L's cache catches up over the drafted block
                s_cache, s_logits = s_cache_new, s_logits_new
                l_cache, l_logits = self._l_feed(self.l_params, cache=l_cache,
                                                 tokens=toks)
            out.append(np.asarray(toks))
        return {
            "tokens": np.concatenate(out, axis=1),
            "blocks": self.stats["blocks"],
            "escalated": self.stats["escalated"],
            "escalation_frac": self.stats["escalated"]
            / max(self.stats["blocks"], 1),
        }


def build_token_cascade(cfg: ModelConfig, hi: HIConfig, rng=None,
                        block: int = 4, cache_len: int = 64) -> TokenCascade:
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    s_cfg = cfg.s_variant(hi.s_scale)
    return TokenCascade(
        s_cfg=s_cfg, l_cfg=cfg,
        s_params=model_zoo.init_params(k1, s_cfg),
        l_params=model_zoo.init_params(k2, cfg),
        hi=hi, block=block, cache_len=cache_len)
