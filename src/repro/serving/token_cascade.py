"""Token-level HI: the cascade at BLOCK granularity inside one generation.

The paper gates whole samples; its §2 notes early-exit (BranchyNet-style)
composes with HI.  For LM serving the natural unit between "sample" and
"layer" is a BLOCK of tokens: the S-tier drafts a block of k tokens with
per-token confidences (the same fused hi_gate statistic); if the minimum
confidence in the block falls under theta, the L-tier regenerates the block
— catching its cache up by prefilling the accepted prefix (one bulk forward,
not k decode steps) and decoding the block itself.

Cost accounting mirrors the paper exactly, one level down:
  - accepted blocks cost only the S-tier draft;
  - escalated blocks cost beta (the L-tier catch-up + regeneration).
Savings = (1 - escalated_fraction) of the L-tier work, with the S-tier draft
as the paper's "extra local inference" term.

Two block policies live here:

* :meth:`TokenCascade.generate` — the original REGENERATION policy: an
  escalated block is fully re-drafted by the L tier from its own state.
* :meth:`TokenCascade.generate_speculative` — DRAFT-VERIFY: an escalated
  block gets one L pass over the drafted tokens, the longest prefix the L
  tier agrees with is kept, the first divergence takes the L token (the
  "bonus" correction), and both tiers rewind to the accepted boundary.

The speculative loop is the host-driven ORACLE for the scheduler's fused
in-tick cascade (``serve_stream(..., speculative=True)``): same block
decisions, same emitted tokens, asserted by tests/test_speculative.py.
Host-driven loop over jitted per-tier programs (the same architecture as
HIEngine, one granularity finer).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig, ModelConfig
from repro.core.confidence import confidence as conf_fn
from repro.models import model_zoo
from repro.serving import sampler


def _draft_block(params, cfg: ModelConfig, cache, last_logits, steps: int,
                 metric: str):
    """Greedy-draft ``steps`` tokens from current logits; returns
    (tokens (B, steps), per-token confidences (steps, B), cache,
    last logits)."""

    def body(carry, _):
        cache, logits = carry
        tok = sampler.greedy(logits)
        conf = conf_fn(logits, metric)
        logits, cache = model_zoo.decode_step(params, cfg, tok[:, None], cache)
        return (cache, logits), (tok, conf)

    (cache, logits), (toks, confs) = jax.lax.scan(
        body, (cache, last_logits), None, length=steps)
    return toks.T, confs, cache, logits


def _verify_block(params, cfg: ModelConfig, cache, last_logits, draft):
    """One verify pass over a drafted block: before feeding each draft token
    the tier's greedy alternative for that position is recorded, then the
    draft token is fed — so ``lv[:, j]`` is what this tier would have emitted
    INSTEAD of ``draft[:, j]`` given the same history.  Returns
    (lv (B, steps), cache, last logits) with the cache fully caught up over
    the draft (the accepted-block path needs exactly that)."""

    def body(carry, d_t):
        cache, logits = carry
        lv = sampler.greedy(logits)
        logits, cache = model_zoo.decode_step(params, cfg, d_t[:, None],
                                              cache)
        return (cache, logits), lv

    (cache, logits), lvs = jax.lax.scan(body, (cache, last_logits), draft.T)
    return lvs.T, cache, logits


def _feed_tokens(params, cfg: ModelConfig, cache, tokens):
    """Catch a tier's cache up over ``tokens`` (B, K); returns last logits."""

    def body(carry, t):
        cache, _ = carry
        logits, cache = model_zoo.decode_step(params, cfg, t[:, None], cache)
        return (cache, logits), None

    b = tokens.shape[0]
    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((b, cfg.vocab_size))), tokens.T)
    return cache, logits


@dataclass
class TokenCascade:
    """Block-granularity HI over one batched generation."""

    s_cfg: ModelConfig
    l_cfg: ModelConfig
    s_params: Any
    l_params: Any
    hi: HIConfig
    block: int = 4
    cache_len: int = 128

    def __post_init__(self):
        self._s_draft = jax.jit(partial(_draft_block, cfg=self.s_cfg,
                                        steps=self.block,
                                        metric=self.hi.metric))
        self._s_feed = jax.jit(partial(_feed_tokens, cfg=self.s_cfg))
        self._l_feed = jax.jit(partial(_feed_tokens, cfg=self.l_cfg))
        self._l_draft = jax.jit(partial(_draft_block, cfg=self.l_cfg,
                                        steps=self.block,
                                        metric=self.hi.metric))
        self._l_verify = jax.jit(partial(_verify_block, cfg=self.l_cfg))
        self.stats = {"blocks": 0, "escalated": 0}

    def generate(self, prompt: np.ndarray, num_blocks: int) -> Dict[str, Any]:
        """prompt: (B, P) -> dict(tokens (B, num_blocks*block), stats).

        The whole batch escalates a block together (static shapes); per-
        request escalation is the sample-level router's job one level up.
        """
        b = prompt.shape[0]
        s_cache = model_zoo.init_cache(self.s_cfg, b, self.cache_len)
        l_cache = model_zoo.init_cache(self.l_cfg, b, self.cache_len)
        prompt_j = jnp.asarray(prompt)
        s_cache, s_logits = self._s_feed(self.s_params, cache=s_cache,
                                         tokens=prompt_j)
        l_cache, l_logits = self._l_feed(self.l_params, cache=l_cache,
                                         tokens=prompt_j)

        out: List[np.ndarray] = []
        for _ in range(num_blocks):
            toks, conf, s_cache_new, s_logits_new = self._s_draft(
                self.s_params, cache=s_cache, last_logits=s_logits)
            self.stats["blocks"] += 1
            if float(conf.min()) < self.hi.theta:
                # escalate: L regenerates the block from ITS state
                self.stats["escalated"] += 1
                toks, _, l_cache, l_logits = self._l_draft(
                    self.l_params, cache=l_cache, last_logits=l_logits)
                # S must follow L's choice: rewind by re-feeding L's tokens
                s_cache, s_logits = self._s_feed(self.s_params, cache=s_cache,
                                                 tokens=toks)
            else:
                # accepted: L's cache catches up over the drafted block
                s_cache, s_logits = s_cache_new, s_logits_new
                l_cache, l_logits = self._l_feed(self.l_params, cache=l_cache,
                                                 tokens=toks)
            out.append(np.asarray(toks))
        return {
            "tokens": np.concatenate(out, axis=1),
            "blocks": self.stats["blocks"],
            "escalated": self.stats["escalated"],
            "escalation_frac": self.stats["escalated"]
            / max(self.stats["blocks"], 1),
        }

    def generate_speculative(self, prompt: np.ndarray, max_new: int
                             ) -> Dict[str, Any]:
        """DRAFT-VERIFY block policy, host-driven — the scheduler's fused
        in-tick cascade oracle.  ``prompt``: (1, P) (single sequence: the
        accepted prefix length is per-sequence data, which the host loop
        resolves by rewinding — batch-level speculation is the fused
        scheduler's job).  Greedy-only, mirroring the one-program lane.

        Round structure (identical to one scheduler tick for one slot):
        token 0 is the prompt's greedy continuation (the admission token,
        emitted unconditionally); each round drafts ``self.block`` tokens
        with per-token confidences; a round whose MIN confidence clears
        theta is accepted wholesale (S-tier cost only — the HI argument at
        block granularity); otherwise ONE L verify pass re-derives each
        position, the longest prefix where L agrees is kept, the first
        divergence emits L's token, and BOTH tiers rewind to the accepted
        boundary (re-feeding the emitted tokens from the pre-round caches —
        bitwise the state the fused lane's snapshot rollback restores).

        Returns dict(tokens (1, <= max_new), rounds [(escalated, n_emit)],
        blocks, escalated, accept_rate)."""
        if prompt.shape[0] != 1:
            raise ValueError("generate_speculative runs one sequence "
                             "(B = 1); batch speculation is the scheduler's")
        k = self.block
        prompt_j = jnp.asarray(prompt)
        s_cache = model_zoo.init_cache(self.s_cfg, 1, self.cache_len)
        l_cache = model_zoo.init_cache(self.l_cfg, 1, self.cache_len)
        s_cache, s_logits = self._s_feed(self.s_params, cache=s_cache,
                                         tokens=prompt_j)
        l_cache, l_logits = self._l_feed(self.l_params, cache=l_cache,
                                         tokens=prompt_j)
        tok0 = sampler.greedy(s_logits)                    # admission token
        emitted: List[int] = [int(tok0[0])]
        s_cache, s_logits = self._s_feed(self.s_params, cache=s_cache,
                                         tokens=tok0[:, None])
        l_cache, l_logits = self._l_feed(self.l_params, cache=l_cache,
                                         tokens=tok0[:, None])

        rounds: List[Tuple[bool, int]] = []
        drafted = accepted = 0
        while len(emitted) < max_new:
            pre = (s_cache, s_logits, l_cache, l_logits)
            toks, confs, s_cache2, s_logits2 = self._s_draft(
                self.s_params, cache=s_cache, last_logits=s_logits)
            drafted += k
            esc = bool(float(confs.min()) < self.hi.theta)
            if not esc:
                # accepted at S-tier cost; the L verify doubles as catch-up
                s_cache, s_logits = s_cache2, s_logits2
                _, l_cache, l_logits = self._l_verify(
                    self.l_params, cache=l_cache, last_logits=l_logits,
                    draft=toks)
                out_toks, n = toks, k
                accepted += k
            else:
                lv, l_cache2, l_logits2 = self._l_verify(
                    self.l_params, cache=l_cache, last_logits=l_logits,
                    draft=toks)
                mism = np.flatnonzero(np.asarray(lv[0]) != np.asarray(toks[0]))
                m = int(mism[0]) if len(mism) else k
                accepted += m
                if m == k:                     # L agrees with every draft
                    s_cache, s_logits = s_cache2, s_logits2
                    l_cache, l_logits = l_cache2, l_logits2
                    out_toks, n = toks, k
                else:
                    # keep the agreed prefix + L's correction, rewind both
                    # tiers to the pre-round caches and re-feed the kept
                    # tokens (the host mirror of the fused lane's snapshot
                    # rollback + positional rewind)
                    out_toks = jnp.concatenate(
                        [toks[:, :m], lv[:, m:m + 1]], axis=1)
                    n = m + 1
                    s_cache, s_logits, l_cache, l_logits = pre
                    s_cache, s_logits = self._s_feed(
                        self.s_params, cache=s_cache, tokens=out_toks)
                    l_cache, l_logits = self._l_feed(
                        self.l_params, cache=l_cache, tokens=out_toks)
            rounds.append((esc, n))
            self.stats["blocks"] += 1
            if esc:
                self.stats["escalated"] += 1
            emitted.extend(int(t) for t in np.asarray(out_toks[0]))
        return {
            "tokens": np.asarray(emitted[:max_new], np.int32)[None, :],
            "rounds": rounds,
            "blocks": len(rounds),
            "escalated": sum(1 for e, _ in rounds if e),
            "accept_rate": accepted / max(drafted, 1),
        }


def build_token_cascade(cfg: ModelConfig, hi: HIConfig, rng=None,
                        block: int = 4, cache_len: int = 64) -> TokenCascade:
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    s_cfg = cfg.s_variant(hi.s_scale)
    return TokenCascade(
        s_cfg=s_cfg, l_cfg=cfg,
        s_params=model_zoo.init_params(k1, s_cfg),
        l_params=model_zoo.init_params(k2, cfg),
        hi=hi, block=block, cache_len=cache_len)
