"""Chrome ``trace_event`` JSON export for serving telemetry.

Converts a :class:`repro.serving.telemetry.Telemetry` collector into the
Trace Event Format consumed by Perfetto (https://ui.perfetto.dev) and
``chrome://tracing``:

* **pid 0 — scheduler**: one ``X`` (complete) event per tick-phase wall
  segment on tid 0, plus ``C`` (counter) events for the per-tick pool
  gauges (free pages, refcount total, prefix-index size, COW copies,
  breaker state, queue depths);
* **pid 1 — S tier / pid 2 — L tier**: one thread (tid = slot) per serving
  slot, carrying that slot's request spans (``admitted``,
  ``prefill_chunk[i]``, ``decode_block[j]``, ``l_verify``); queue-resident
  spans (``queued``, ``escalate_attempt[k]``) live on a dedicated
  ``queue``/``transport`` track;
* escalations are drawn as **flow events** (``ph: "s"`` at the S-side
  ``escalate_attempt`` start, ``ph: "f"`` binding to the enclosing slice at
  the L-side ``l_verify`` start, ``id`` = request id) so Perfetto renders
  an S->L arrow per escalation attempt;
* terminal statuses appear as ``i`` (instant) markers named
  ``terminal:<status>``;
* watchdog/audit events recorded via :meth:`Telemetry.instant` (e.g.
  ``slo_breach:<kind>``) render as global ``i`` markers on pid 0 under
  ``cat: "slo"``; when a :class:`~repro.serving.audit.GateAudit` is
  installed its per-tick aggregates (running ECE, offload rate, regret
  cost) arrive through the tick gauges and so become counter tracks.

Timestamps are microseconds relative to the collector's earliest event, so
traces start at t=0 regardless of the host's monotonic epoch.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List

# transport/queue pseudo-slots get a tid far above any real slot index
_QUEUE_TID = 1000
_TIER_PID = {"S": 1, "L": 2, "": 0}
# mesh replicas: first replica-specific pid; replica r renders as its own
# process so Perfetto shows one lane group per S shard
_REPLICA_PID0 = 3


def _tier_pid(tier: str) -> int:
    """pid for a tier label: "S"/"L"/"" are fixed; mesh replica labels
    ("S0".."S{R-1}") map to stable per-replica pids (S0 shares pid 1 with
    the historical single-S process — replica 0 IS that process at a 1x1
    debug mesh)."""
    if len(tier) > 1 and tier[0] == "S" and tier[1:].isdigit():
        r = int(tier[1:])
        return 1 if r == 0 else _REPLICA_PID0 + (r - 1)
    return _TIER_PID.get(tier, 0)


def _epoch(tel) -> float:
    t0 = math.inf
    for tick in tel.ticks:
        t0 = min(t0, tick.t0)
    for tr in tel.traces.values():
        for s in tr.spans:
            t0 = min(t0, s.t0)
    for t, _name, _args in getattr(tel, "events", ()):
        t0 = min(t0, t)
    return 0.0 if math.isinf(t0) else t0


def chrome_trace(tel) -> Dict[str, Any]:
    """Render a Telemetry collector as a Chrome trace_event dict."""
    epoch = _epoch(tel)

    def us(t: float) -> float:
        return round((t - epoch) * 1e6, 3)

    ev: List[Dict[str, Any]] = []

    def meta(pid: int, tid: int | None, key: str, name: str) -> None:
        e = {"ph": "M", "pid": pid, "name": key, "args": {"name": name}}
        if tid is not None:
            e["tid"] = tid
        ev.append(e)

    meta(0, None, "process_name", "scheduler")
    meta(0, 0, "thread_name", "tick phases")
    meta(1, None, "process_name", "S tier")
    meta(2, None, "process_name", "L tier")
    meta(1, _QUEUE_TID, "thread_name", "admission queue")
    meta(1, _QUEUE_TID + 1, "thread_name", "escalation transport")
    # mesh replicas beyond S0 get their own process lanes, named up front
    # from the tier labels actually present in the trace
    named_pids = {0, 1, 2}
    for tr in tel.traces.values():
        for s in tr.spans:
            pid = _tier_pid(s.tier)
            if pid not in named_pids:
                named_pids.add(pid)
                meta(pid, None, "process_name", f"S tier replica {s.tier[1:]}")
    seen_tids = set()

    # -- scheduler ticks: phase slices + gauge counters ---------------------
    for tick in tel.ticks:
        for phase, t0, t1 in tick.segments:
            ev.append({"ph": "X", "pid": 0, "tid": 0, "name": phase,
                       "cat": "tick", "ts": us(t0),
                       "dur": max(round((t1 - t0) * 1e6, 3), 0.001),
                       "args": {"tick": tick.index}})
        for k, v in tick.gauges.items():
            ev.append({"ph": "C", "pid": 0, "name": k, "ts": us(tick.t0),
                       "args": {"value": v}})

    # -- watchdog / audit instant events ------------------------------------
    for t, name, args in getattr(tel, "events", ()):
        ev.append({"ph": "i", "pid": 0, "tid": 0, "s": "g", "name": name,
                   "cat": "slo", "ts": us(t), "args": dict(args)})

    # -- request spans ------------------------------------------------------
    for rid in sorted(tel.traces):
        tr = tel.traces[rid]
        for s in tr.spans:
            pid = _tier_pid(s.tier)
            if s.kind == "queued":
                tid = _QUEUE_TID
            elif s.kind in ("escalate_attempt", "escalate_backoff"):
                tid = _QUEUE_TID + 1
            else:
                tid = s.slot if s.slot >= 0 else _QUEUE_TID
            if (pid, tid) not in seen_tids and tid < _QUEUE_TID:
                seen_tids.add((pid, tid))
                meta(pid, tid, "thread_name", f"slot {tid}")
            t1 = s.t0 if not math.isfinite(s.t1) else s.t1
            args = {"request_id": rid, **s.args}
            if s.kind == "terminal":
                ev.append({"ph": "i", "pid": pid, "tid": tid, "s": "t",
                           "name": f"terminal:{s.args.get('status', '?')}",
                           "cat": "request", "ts": us(s.t0), "args": args})
                continue
            name = s.kind
            for idx_key in ("i", "j", "k"):
                if idx_key in s.args:
                    name = f"{s.kind}[{s.args[idx_key]}]"
                    break
            ev.append({"ph": "X", "pid": pid, "tid": tid, "name": name,
                       "cat": "request", "ts": us(s.t0),
                       "dur": max(round((t1 - s.t0) * 1e6, 3), 0.001),
                       "args": args})
            if s.kind == "escalate_attempt":
                ev.append({"ph": "s", "pid": pid, "tid": tid,
                           "name": "escalate", "cat": "flow",
                           "id": rid, "ts": us(s.t0)})
            elif s.kind == "l_verify":
                ev.append({"ph": "f", "pid": pid, "tid": tid, "bp": "e",
                           "name": "escalate", "cat": "flow",
                           "id": rid, "ts": us(s.t0)})

    return {"traceEvents": ev, "displayTimeUnit": "ms",
            "otherData": {"exporter": "repro.serving.trace_export"}}


def write_chrome_trace(tel, path: str) -> Dict[str, Any]:
    """Serialize :func:`chrome_trace` to ``path``; returns the dict."""
    doc = chrome_trace(tel)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc
