"""Token samplers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(rng, logits: jnp.ndarray, temp: float = 1.0) -> jnp.ndarray:
    if temp <= 0:
        return greedy(logits)
    return jax.random.categorical(rng, logits / temp, axis=-1).astype(jnp.int32)
