"""Token samplers.

``sample`` is the serving path's entry point: greedy when temperature <= 0
(bitwise-identical to :func:`greedy`, which keeps the drain/stream
equivalence tests exact), categorical otherwise.  Keys are PER REQUEST and
PER TOKEN INDEX (:func:`request_keys`), so a request's sampled continuation
is reproducible regardless of which batch, slot, or tick it lands in — the
property that makes temperature serving testable across schedulers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(rng, logits: jnp.ndarray, temp: float = 1.0) -> jnp.ndarray:
    if temp <= 0:
        return greedy(logits)
    return jax.random.categorical(rng, logits / temp, axis=-1).astype(jnp.int32)


def request_keys(seeds: jnp.ndarray, token_idx) -> jnp.ndarray:
    """Per-request, per-token PRNG keys.

    seeds: (B,) int32 request-derived seeds; token_idx: scalar or (B,) int32
    index of the token being sampled within each request's generation.
    Returns (B, 2) uint32 keys: ``fold_in(PRNGKey(seed), token_idx)``.
    """
    idx = jnp.broadcast_to(jnp.asarray(token_idx, jnp.uint32), seeds.shape)
    keys = jax.vmap(jax.random.PRNGKey)(seeds.astype(jnp.uint32))
    return jax.vmap(jax.random.fold_in)(keys, idx)


def sample(keys: jnp.ndarray, logits: jnp.ndarray, temp) -> jnp.ndarray:
    """keys: (B, 2) uint32; logits: (B, V); temp: traced scalar or (B,).

    temp <= 0 selects greedy EXACTLY (the categorical branch is computed and
    discarded — temp stays a traced operand so per-request temperatures and
    online changes never retrace)."""
    t = jnp.broadcast_to(jnp.asarray(temp, jnp.float32), (logits.shape[0],))
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    cat = jax.vmap(lambda k, row: jax.random.categorical(k, row))(keys, scaled)
    return jnp.where(t > 0, cat.astype(jnp.int32), greedy(logits))
