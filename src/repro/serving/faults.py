"""Fault model for the S→L escalation path: the ED↔ES transport, made
literal.

The paper's robustness claim is that an ED stays USEFUL when the ES path
degrades — the local answer stands and only samples that genuinely need help
cross the link.  The scheduler's L-tier queue models that link; this module
models the link FAILING, entirely host-side, so the compiled tick executable
is untouched (``stream_compiles == 1`` with fault injection enabled —
degradation never changes compiled shapes).

Three pieces:

* :class:`FaultSchedule` — a deterministic, seeded injector for the ED↔ES
  transport: per-escalation delivery delay in ticks, escalation loss,
  L-tier outage windows ``[tick_a, tick_b)`` (the ES is down: queued and
  in-flight escalations fail, nothing is admitted), and L latency-spike
  windows (the ES stalls: escalations queue but are not admitted).  Every
  decision is a pure function of ``(seed, request_id, attempt)`` or of the
  run-relative tick — independent of call order, so a replayed run sees the
  IDENTICAL fault sequence.
* :class:`EscalationLink` — the simulated transport between the S scheduler
  and the L tier.  Escalations are ``send()``-ed, arrive ``delay`` ticks
  later (or never, when lost), time out after ``ack_timeout_ticks`` and
  re-enter via capped exponential backoff (``schedule_retry``).
* :class:`CircuitBreaker` — closed → open → half-open over CONSECUTIVE link
  failures (arXiv:2304.00891's uncertain-offload regime): while open the
  scheduler runs FAIL-LOCAL (escalation queue paused, the hi_gate threshold
  operand lowered to :data:`FAIL_LOCAL_THETA` so the gate itself stops
  offloading — theta is already a traced operand, so no recompile); after
  ``breaker_cooldown_ticks`` a half-open probe re-admits a single trial
  escalation, and its success closes the breaker.

The per-request outcome vocabulary lives here too (:data:`STATUSES`): every
request that enters ``serve_stream`` terminates with exactly one result
record carrying one of ``ok`` / ``degraded_local`` / ``dropped`` /
``rejected``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

# Every serve_stream result record carries exactly one of these:
#   ok            — served normally (locally, or remotely after escalation);
#   degraded_local— the request wanted escalation but the L path failed
#                   (loss/timeout retries exhausted, outage, open breaker,
#                   or L admission starvation): the S-tier answer stands;
#   dropped       — the arXiv:2112.11413 budget policy expired the queued
#                   escalation: the S-tier answer stands;
#   rejected      — admission gave up (page demand unsatisfiable after
#                   ``admit_retry_limit`` fruitless ticks): no tokens.
STATUSES = ("ok", "degraded_local", "dropped", "rejected")

# Fail-local gate threshold: every confidence metric lives in [0, 1] (see
# core/confidence.py), so ``conf < 0.0`` never offloads.  Passed as the tick
# executable's theta OPERAND while the breaker is open — same compiled
# program, the gate simply stops firing.
FAIL_LOCAL_THETA = 0.0


@dataclass(frozen=True)
class FaultSchedule:
    """Seeded, deterministic ED↔ES transport faults (all host-side).

    ``delay_ticks``/``delay_jitter`` — delivery delay of an escalation in
    scheduler ticks: base plus a per-(request, attempt) uniform draw from
    ``0..delay_jitter``.
    ``loss_prob`` — probability an escalation send is lost outright (the
    host only learns via ack timeout).
    ``outages`` — ``(a, b)`` windows of RUN-RELATIVE ticks during which the
    L tier is down: escalations queued at or arriving at the ES fail, and
    in-flight L-tier work is aborted (its slot and KV pages released).
    ``spikes`` — windows during which the L tier stalls (latency spike):
    arrivals queue but nothing is admitted; budgets keep running.
    """
    seed: int = 0
    delay_ticks: int = 0
    delay_jitter: int = 0
    loss_prob: float = 0.0
    outages: Tuple[Tuple[int, int], ...] = ()
    spikes: Tuple[Tuple[int, int], ...] = ()

    @property
    def active(self) -> bool:
        return (self.delay_ticks > 0 or self.delay_jitter > 0
                or self.loss_prob > 0 or bool(self.outages)
                or bool(self.spikes))

    def _unit(self, *parts: int) -> float:
        """Uniform [0, 1) from (seed, *parts) — order-independent."""
        h = hashlib.blake2b(
            np.asarray([self.seed, *parts], np.int64).tobytes(),
            digest_size=8)
        return int.from_bytes(h.digest(), "little") / 2.0 ** 64

    def transit(self, request_id: int, attempt: int) -> Optional[int]:
        """Delivery delay in ticks for this (request, attempt) send, or
        None when the escalation is lost on the wire."""
        if self._unit(request_id, attempt, 0) < self.loss_prob:
            return None
        d = self.delay_ticks
        if self.delay_jitter:
            d += int(self._unit(request_id, attempt, 1)
                     * (self.delay_jitter + 1))
        return d

    def in_outage(self, tick: int) -> bool:
        return any(a <= tick < b for a, b in self.outages)

    def in_spike(self, tick: int) -> bool:
        return any(a <= tick < b for a, b in self.spikes)

    def l_paused(self, tick: int) -> bool:
        """Is L-tier admission stalled this tick (outage or spike)?"""
        return self.in_outage(tick) or self.in_spike(tick)


NO_FAULTS = FaultSchedule()


@dataclass(frozen=True)
class RetryPolicy:
    """Resilience knobs for the escalation path (host-side, per run).

    Retries use capped exponential backoff: attempt ``n`` (1-based) resends
    ``min(backoff_base_ticks << (n - 1), backoff_cap_ticks)`` ticks after
    the failure.  ``admit_retry_limit`` bounds the ADMISSION retry spin: a
    request whose page demand stays unsatisfiable for that many fruitless
    ticks fails with ``status="rejected"`` instead of spinning forever.
    """
    ack_timeout_ticks: int = 4
    max_retries: int = 3
    backoff_base_ticks: int = 1
    backoff_cap_ticks: int = 8
    breaker_threshold: int = 3
    breaker_cooldown_ticks: int = 8
    admit_retry_limit: int = 64

    def backoff(self, attempt: int) -> int:
        return min(self.backoff_base_ticks << max(attempt - 1, 0),
                   self.backoff_cap_ticks)


@dataclass
class Escalation:
    """One S→L escalation's transport state (host bookkeeping only)."""
    adm: Any                      # batcher.AdmittedRequest
    rid: int
    created_tick: int             # run-relative tick of the S finish
    attempt: int = 0              # completed (failed) send attempts
    sent_tick: int = -1
    arrive_tick: Optional[int] = None   # None = lost / will time out
    resend_tick: int = -1
    l_admit_tick: int = -1


class EscalationLink:
    """Simulated ED↔ES transport: in-flight sends + backoff retries.

    The scheduler ``send()``s an escalation, then each tick ``step()``
    partitions the in-flight set into arrivals (delivered to the L queue)
    and failures (lost sends past their ack timeout, or deliveries landing
    inside an outage window).  Failed escalations the scheduler decides to
    retry re-enter through ``schedule_retry`` and are re-sent when due.
    """

    def __init__(self, faults: FaultSchedule, policy: RetryPolicy):
        self.faults = faults
        self.policy = policy
        self.in_flight: List[Escalation] = []
        self.backoff: List[Escalation] = []
        self.lost = 0

    @property
    def pending(self) -> int:
        return len(self.in_flight) + len(self.backoff)

    def send(self, esc: Escalation, tick: int) -> None:
        esc.sent_tick = tick
        d = self.faults.transit(esc.rid, esc.attempt)
        if d is None or d > self.policy.ack_timeout_ticks:
            # lost outright, or so late the host retransmits first — either
            # way the ack timeout is what the scheduler observes
            esc.arrive_tick = None
            self.lost += 1
        else:
            esc.arrive_tick = tick + d
        self.in_flight.append(esc)

    def step(self, tick: int) -> Tuple[List[Escalation], List[Escalation]]:
        """Advance the transport to ``tick``: (arrived, failed)."""
        arrived: List[Escalation] = []
        failed: List[Escalation] = []
        keep: List[Escalation] = []
        for esc in self.in_flight:
            if esc.arrive_tick is not None and esc.arrive_tick <= tick:
                # delivery into an outage window fails (ES down)
                (failed if self.faults.in_outage(tick)
                 else arrived).append(esc)
            elif esc.arrive_tick is None and \
                    tick - esc.sent_tick >= self.policy.ack_timeout_ticks:
                failed.append(esc)
            else:
                keep.append(esc)
        self.in_flight = keep
        return arrived, failed

    def schedule_retry(self, esc: Escalation, tick: int) -> None:
        esc.attempt += 1
        esc.resend_tick = tick + self.policy.backoff(esc.attempt)
        self.backoff.append(esc)

    def due_resends(self, tick: int) -> List[Escalation]:
        return [e for e in self.backoff if e.resend_tick <= tick]

    def take(self, esc: Escalation) -> Escalation:
        """Remove ``esc`` from the backoff set (about to resend or give
        up)."""
        self.backoff.remove(esc)
        return esc


class CircuitBreaker:
    """closed → open → half-open over consecutive L-path failures.

    * closed: escalations flow normally; each success resets the failure
      count, ``breaker_threshold`` CONSECUTIVE failures open the breaker.
    * open: fail-local mode — nothing is admitted to L, resends hold, and
      the scheduler's gate stops offloading (theta operand =
      :data:`FAIL_LOCAL_THETA`).  After ``breaker_cooldown_ticks`` the
      breaker half-opens.
    * half-open: exactly ONE trial escalation (the probe) is re-admitted.
      Its success closes the breaker; any failure re-opens it (cooldown
      restarts).
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"
    # numeric encoding for telemetry gauges / Chrome-trace counter tracks
    STATE_IDS = {CLOSED: 0, OPEN: 1, HALF_OPEN: 2}

    def __init__(self, policy: RetryPolicy):
        self.policy = policy
        self.state = self.CLOSED
        self.failures = 0
        self.opened_tick = -1
        self.opens = 0

    @property
    def closed(self) -> bool:
        return self.state == self.CLOSED

    @property
    def state_id(self) -> int:
        """Numeric state (0=closed, 1=open, 2=half_open) for gauge export."""
        return self.STATE_IDS[self.state]

    def state_at(self, tick: int) -> str:
        """Current state, applying the open → half-open cooldown edge."""
        if self.state == self.OPEN and \
                tick - self.opened_tick >= self.policy.breaker_cooldown_ticks:
            self.state = self.HALF_OPEN
        return self.state

    def record_failure(self, tick: int) -> None:
        self.failures += 1
        if self.state == self.HALF_OPEN or \
                (self.state == self.CLOSED
                 and self.failures >= self.policy.breaker_threshold):
            self.state = self.OPEN
            self.opened_tick = tick
            self.opens += 1
        elif self.state == self.OPEN:
            self.opened_tick = tick      # failures while open extend cooldown

    def record_success(self) -> None:
        self.failures = 0
        self.state = self.CLOSED
