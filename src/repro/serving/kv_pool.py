"""Paged KV pool: ONE donated device allocation per tier + a host-side page
allocator.

The drain-path engine keeps a donated contiguous cache per COMPILED SHAPE —
every (batch, bucket) pair owns a full (L, B, cache_len, K, Dh) buffer.  The
pool replaces all of them with a single physical allocation per tier,
``model_zoo.init_paged_cache``: attention K/V is cut into ``num_pages`` pages
of ``page_size`` positions, and an int32 block table maps each decode slot's
logical pages to physical ones.  Buckets stop being a compile-time property
of the cache: every prompt length shares the same buffers and therefore the
same executable.

Page 0 is the NULL page: freed block-table rows and idle slots point at it,
it receives the (benign, raced) writes of idle slots, and no positional mask
ever exposes its contents.  The allocator is deliberately host-side and
trivial — a LIFO free list — because allocation happens at request admission
(milliseconds), not inside the device program (microseconds).

SSM-family tiers have constant-size per-slot state instead of pages; the
pool still tracks slot occupancy through the same interface so the scheduler
is family-agnostic (the block table is simply ignored by the SSM decode).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model_zoo


class KVPool:
    """Device page pool + block tables + free-list allocator for one tier.

    ``buffers`` is the device pytree that the scheduler threads (donated)
    through every tick; ``block`` is the host-side (num_slots, n_pages) int32
    block table passed as a small operand each tick.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_context: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=jnp.bfloat16):
        if max_context % page_size:
            raise ValueError(f"max_context {max_context} must be a multiple "
                             f"of page_size {page_size}")
        self.cfg = cfg
        self.num_slots = num_slots
        self.page_size = page_size
        self.n_pages_per_slot = max_context // page_size
        if num_pages is None:
            # enough for every slot to hold a full-context sequence, + null
            num_pages = num_slots * self.n_pages_per_slot + 1
        if num_pages < 2:
            raise ValueError("need at least one non-null page")
        self.num_pages = num_pages
        self.buffers = model_zoo.init_paged_cache(cfg, num_slots, num_pages,
                                                  page_size, dtype)
        self.block = np.zeros((num_slots, self.n_pages_per_slot), np.int32)
        # LIFO free list; physical page 0 is the null page, never allocated
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}

    # -- allocator ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, context_len: int) -> int:
        return -(-context_len // self.page_size)        # ceil div

    def can_alloc(self, context_len: int) -> bool:
        return self.pages_needed(context_len) <= len(self._free)

    def alloc(self, slot: int, context_len: int) -> None:
        """Give ``slot`` enough pages for ``context_len`` positions; the rest
        of its block-table row points at the null page."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already holds pages")
        n = self.pages_needed(context_len)
        if n > self.n_pages_per_slot:
            raise ValueError(
                f"context {context_len} needs {n} pages > per-slot maximum "
                f"{self.n_pages_per_slot}")
        if n > len(self._free):
            raise ValueError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        pages = [self._free.pop() for _ in range(n)]
        self.block[slot, :] = 0
        self.block[slot, :n] = pages
        self._owned[slot] = pages

    def free(self, slot: int) -> None:
        """Return ``slot``'s pages to the free list and null its row.  Stale
        page contents are never scrubbed — the positional mask plus the
        prefill overwrite make them unobservable to the next owner."""
        pages = self._owned.pop(slot, None)
        if pages is None:
            raise ValueError(f"slot {slot} holds no pages")
        self._free.extend(reversed(pages))
        self.block[slot, :] = 0

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    def check_invariants(self) -> None:
        """Debug/test hook: no page is simultaneously free and owned, owned
        sets are disjoint, and every non-null block-table entry is owned."""
        owned_all: List[int] = []
        for pages in self._owned.values():
            owned_all.extend(pages)
        assert len(set(owned_all)) == len(owned_all), "page owned twice"
        assert not (set(owned_all) & set(self._free)), "page free AND owned"
        assert 0 not in owned_all, "null page allocated"
        assert len(owned_all) + len(self._free) == self.num_pages - 1, \
            "pages leaked"
        for slot in range(self.num_slots):
            live = set(self.block[slot][self.block[slot] > 0].tolist())
            assert live <= set(self._owned.get(slot, [])), \
                f"slot {slot} block row references unowned pages"
