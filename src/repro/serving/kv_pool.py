"""Paged KV pool: ONE donated device allocation per tier + a host-side
REFERENCE-COUNTED page allocator with a content-addressed prefix index and
copy-on-write.

The drain-path engine keeps a donated contiguous cache per COMPILED SHAPE —
every (batch, bucket) pair owns a full (L, B, cache_len, K, Dh) buffer.  The
pool replaces all of them with a single physical allocation per tier,
``model_zoo.init_paged_cache``: attention K/V is cut into ``num_pages`` pages
of ``page_size`` positions, and an int32 block table maps each decode slot's
logical pages to physical ones.  Buckets stop being a compile-time property
of the cache: every prompt length shares the same buffers and therefore the
same executable.

Ownership model (the PR-3 refactor)
-----------------------------------
Pages are no longer slot-owned: a physical page carries a REFCOUNT — one per
decode slot whose block row references it, one per prefix-index entry that
retains it.  A page returns to the free list only when its refcount reaches
zero.  Three reference kinds exist:

* slot references — the classic "this slot's block row points here";
* page-index references — full prompt pages are content-addressed by a
  ROLLING CHAIN HASH (``h_i = H(h_{i-1} || tokens of page i)``), which
  encodes the whole trie of prompt prefixes in one flat dict: looking up a
  new prompt walks its chain until the first miss, and every hit page is
  aliased read-only into the new slot's block row (refcount bump, no copy,
  no prefill);
* full-entry references — a completed prompt additionally registers a
  FULL-PROMPT entry (same chain hash extended over the partial tail page)
  that pins every prompt page plus one row of the device-side prefix cache
  (last-position logits, and recurrent state + conv window for the SSM
  families).  A later identical prompt restores from it and skips prefill
  entirely.

COPY-ON-WRITE: a slot may only write pages it holds EXCLUSIVELY (no other
slot referencing them).  Shared pages are read-only; when a full-prompt
restore would have to append decode tokens into a retained partial tail
page, admission allocates a fresh page and schedules an on-device page copy
(``cow`` pairs executed at the top of the scheduler tick) — the index keeps
the original, the slot appends into its private copy.  ``write_block`` gives
the decode step a table with every non-exclusive page masked to the null
page, so a violation of the invariant drops the write harmlessly instead of
corrupting another request's cache.

Eviction is LRU over index entries (pages pinned only by the index are
reclaimable; pages referenced by live slots never move).  The allocator
remains deliberately host-side — allocation happens at request admission
(milliseconds), not inside the device program (microseconds).

Page 0 is the NULL page: freed block-table rows and idle slots point at it,
it receives the (benign, raced) writes of idle slots, and no positional mask
ever exposes its contents.

QUANTIZED POOLS (``dtype=jnp.int8``): the K/V pages are stored as int8 with
symmetric per-page-per-head fp32 scales.  The scale tensors (``ks``/``vs``,
shape ``(L, num_pages, K)``) live in the SAME ``buffers`` pytree as the page
pools — they are part of the one donated allocation and move with it through
every tick.  Scale rows are indexed by PHYSICAL page, so everything the
allocator does to a page (alias, COW copy, free, evict) applies to its scale
row by construction: aliasing shares the row, ``copy_pages`` moves it with
the page (the kernel is rank/dtype generic), and freeing leaves it stale but
unobservable — the first write into a recycled page resets its scale before
quantizing (prefill overwrites it wholesale; the decode append zeroes it at
page offset 0).  Dequantization is fused into the Pallas page-gather kernels
(a ``(1, 1)`` scale block rides the same block-table index_map as its page),
so the gathered K/V never exists in HBM at full precision.  The bf16 default
keeps the cache pytree exactly ``{"kp", "vp"}`` — bitwise identical to the
unquantized build.

SSM-family tiers have constant-size per-slot state instead of pages; the
pool still tracks slot occupancy through the same interface so the scheduler
is family-agnostic (the block table is simply ignored by the SSM decode),
and their prefix reuse runs entirely through the full-entry snapshots.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention import MAX_PREFETCH_PAGES
from repro.models import model_zoo


@dataclass
class _PageEntry:
    """One content-addressed full prompt page retained by the index."""
    page: int
    ready: int          # first tick whose lookups may alias this page
    used: int           # LRU stamp


@dataclass
class _FullEntry:
    """One full-prompt snapshot: pinned prompt pages + a prefix-cache row."""
    row: int            # row in the device prefix cache (logits / state)
    pages: List[int]    # every prompt page incl. the partial tail (pinned)
    bucket: int
    ready: int
    used: int


@dataclass
class AdmitPlan:
    """Host-side admission decision for one request (consumed by the tick).

    ``start``     — first token position the admit lane must prefill (page
                    aligned for partial hits; == bucket for full restores);
    ``restore_row`` — prefix-cache row to restore from (-1 = none);
    ``save_row``    — prefix-cache row this admission fills (-1 = none);
    ``cow``         — (src, dst) physical page copy to run before prefill.
    """
    slot: int
    start: int = 0
    restore_row: int = -1
    save_row: int = -1
    cow: Optional[Tuple[int, int]] = None

    @property
    def is_restore(self) -> bool:
        return self.restore_row >= 0


class KVPool:
    """Device page pool + block tables + refcounted allocator for one tier.

    ``buffers`` is the family cache pytree the scheduler threads (donated)
    through every tick; ``prefix_buffers`` (present when
    ``prefix_entries > 0``) holds the device-side prefix cache rows;
    ``block`` is the host-side (num_slots, n_pages) int32 block table passed
    as a small operand each tick.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, max_context: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 dtype=jnp.bfloat16, prefix_entries: int = 0,
                 alloc: bool = True):
        if max_context % page_size:
            raise ValueError(f"max_context {max_context} must be a multiple "
                             f"of page_size {page_size}")
        from repro.configs.base import DENSE, MOE, VLM
        # page-granular partial hits need per-position attention pages; the
        # recurrent families (SSM, and the hybrid's mamba half) have no state
        # snapshot at mid-prompt boundaries, so they share whole prompts only
        self.partial_prefix = cfg.family in (DENSE, VLM, MOE)
        self.cfg = cfg
        self.num_slots = num_slots
        self.page_size = page_size
        self.n_pages_per_slot = max_context // page_size
        if self.n_pages_per_slot > MAX_PREFETCH_PAGES:
            # the decode kernels scalar-prefetch one block-table row into
            # SMEM; a wider row than the kernels' bound would silently read
            # out of the prefetch block, so fail loudly at construction —
            # every alloc/admit_prefix row is bounded by n_pages_per_slot
            raise ValueError(
                f"max_context {max_context} / page_size {page_size} implies "
                f"a {self.n_pages_per_slot}-page block-table row, wider than "
                f"the kernels' scalar-prefetch bound MAX_PREFETCH_PAGES="
                f"{MAX_PREFETCH_PAGES}; raise page_size or lower max_context")
        if num_pages is None:
            # enough for every slot to hold a full-context sequence, + null
            num_pages = num_slots * self.n_pages_per_slot + 1
        if num_pages < 2:
            raise ValueError("need at least one non-null page")
        self.num_pages = num_pages
        # ``alloc=False`` (mesh-sharded serving): this pool is one REPLICA's
        # host-side allocator — free list, block table, refcounts, prefix
        # index — while the device allocation lives in the scheduler's ONE
        # stacked, data-sharded, donated pool tree.  The buffers here are
        # ShapeDtypeStructs (shape/dtype only), which every consumer that
        # stays host-side (gauges, kv_bytes_total, check_invariants) already
        # tolerates: they only read ``.shape`` / ``.dtype``.
        self._alloc = bool(alloc)
        if alloc:
            self.buffers = model_zoo.init_paged_cache(
                cfg, num_slots, num_pages, page_size, dtype)
        else:
            self.buffers = jax.eval_shape(
                lambda: model_zoo.init_paged_cache(cfg, num_slots, num_pages,
                                                   page_size, dtype))
        self.kv_dtype = str(jnp.dtype(dtype))
        # byte accounting over the donated pool allocation (pages + scale
        # rows + recurrent state), fixed at construction — gauges() reports
        # these without touching the device
        self.kv_bytes_total = int(sum(
            int(np.prod(b.shape)) * jnp.dtype(b.dtype).itemsize
            for b in self.buffers.values()))
        self.bytes_per_slot = self.kv_bytes_total // num_slots
        self.prefix_entries = prefix_entries
        if prefix_entries > 0:
            self.prefix_buffers = (
                model_zoo.init_prefix_cache(cfg, prefix_entries, dtype)
                if alloc else jax.eval_shape(
                    lambda: model_zoo.init_prefix_cache(cfg, prefix_entries,
                                                        dtype)))
        else:
            self.prefix_buffers = None
        self.block = np.zeros((num_slots, self.n_pages_per_slot), np.int32)
        # LIFO free list; physical page 0 is the null page, never allocated
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs = np.zeros((num_pages,), np.int32)       # all references
        self._slot_refs = np.zeros((num_pages,), np.int32)  # slot refs only
        self._slot_pages: Dict[int, List[int]] = {}
        self._page_index: Dict[bytes, _PageEntry] = {}
        self._full_index: Dict[bytes, _FullEntry] = {}
        self._row_free: List[int] = list(range(prefix_entries - 1, -1, -1))
        self.stats: Dict[str, int] = {
            "hits": 0, "full_hits": 0, "tokens_saved": 0, "cow_copies": 0,
            "evictions": 0}

    # -- allocator ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def gauges(self) -> Dict[str, int]:
        """Telemetry snapshot of allocator state the host already holds —
        sampled once per scheduler tick, no device traffic."""
        return {
            "free_pages": len(self._free),
            "refcount_total": int(self._refs.sum()),
            "prefix_index": len(self._page_index) + len(self._full_index),
            "cow_copies": self.stats["cow_copies"],
            # pool-footprint gauges (constant per pool; numeric so the
            # Chrome-trace exporter tracks them as counter series)
            "kv_bytes_total": self.kv_bytes_total,
            "bytes_per_slot": self.bytes_per_slot,
            "kv_bits": 8 if self.kv_dtype == "int8" else 16,
        }

    @property
    def held_slots(self) -> List[int]:
        """Slots currently holding pages — empty after a clean drain.  The
        fault-path tests assert this: every abandoned escalation (lost,
        expired, outage-aborted, or in flight when the run drains) must have
        released its pages through ``free``/``retract``."""
        return sorted(self._slot_pages)

    def pages_needed(self, context_len: int) -> int:
        return -(-context_len // self.page_size)        # ceil div

    def can_alloc(self, context_len: int, tick: Optional[int] = None) -> bool:
        """Optimistic capacity check: free pages plus what eviction could
        reclaim (index-retained pages with no slot refs; entries still
        PENDING at ``tick`` are excluded, matching eviction's own rule).
        ``alloc``/``admit_prefix`` remain the authority — admission paths
        treat their failure as backpressure and retry."""
        n = self.pages_needed(context_len)
        return n <= len(self._free) + self._reclaimable(tick)

    def _pop_page(self, slot: int) -> int:
        p = self._free.pop()
        self._refs[p] += 1
        self._slot_refs[p] += 1
        self._slot_pages[slot].append(p)
        return p

    def _alias_page(self, slot: int, page: int) -> int:
        self._refs[page] += 1
        self._slot_refs[page] += 1
        self._slot_pages[slot].append(page)
        return page

    def alloc(self, slot: int, context_len: int,
              tick: Optional[int] = None) -> None:
        """Give ``slot`` enough EXCLUSIVE pages for ``context_len`` positions;
        the rest of its block-table row points at the null page."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range (0..{self.num_slots - 1})")
        if slot in self._slot_pages:
            raise ValueError(f"slot {slot} already holds pages")
        n = self.pages_needed(context_len)
        if n > self.n_pages_per_slot:
            raise ValueError(
                f"context {context_len} needs {n} pages > per-slot maximum "
                f"{self.n_pages_per_slot}")
        if n > len(self._free) and not self._evict_pages(n, tick=tick):
            raise ValueError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        self._slot_pages[slot] = []
        pages = [self._pop_page(slot) for _ in range(n)]
        self.block[slot, :] = 0
        self.block[slot, :n] = pages

    def free(self, slot: int) -> None:
        """Drop ``slot``'s references; pages whose refcount hits zero return
        to the free list.  Stale page contents are never scrubbed — the
        positional mask plus the prefill overwrite make them unobservable to
        the next owner.  Double frees and frees of foreign/unknown slots
        raise instead of corrupting the free list."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range (0..{self.num_slots - 1})")
        pages = self._slot_pages.pop(slot, None)
        if pages is None:
            raise ValueError(f"double free: slot {slot} holds no pages")
        for p in reversed(pages):
            if self._refs[p] <= 0 or self._slot_refs[p] <= 0:
                raise ValueError(
                    f"foreign free: page {p} of slot {slot} is not held "
                    f"(refcount underflow)")
            self._refs[p] -= 1
            self._slot_refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
        self.block[slot, :] = 0

    def owned(self, slot: int) -> List[int]:
        return list(self._slot_pages.get(slot, []))

    def truncate(self, slot: int, length: int) -> None:
        """Rewind guard for the speculative rollback: verify that moving
        ``slot``'s write position back to ``length`` can never append into a
        page another slot can SEE.  Every page of the slot's block row from
        the one covering position ``length`` onward must be held by exactly
        this one slot (``slot_refs == 1``) — a co-resident alias there would
        mean the rolled-back decode could overwrite positions another request
        reads, so this RAISES instead of proceeding (copy-on-write
        territory; admission guarantees the decode region is freshly
        allocated, making this pure defense in depth).  A prefix-INDEX
        retention on the partial prompt-tail page is fine: index readers
        only ever alias prompt offsets, restores copy-on-write before
        appending, and the rewound writer (length > prompt) never touches
        prompt offsets — the same invariant the normal append path relies
        on.  Refcounts are unchanged: the slot keeps its allocation and the
        stale tail contents are shadowed by the positional mask, exactly
        like the drain path's discarded overrun steps."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(
                f"slot {slot} out of range (0..{self.num_slots - 1})")
        if slot not in self._slot_pages:
            raise ValueError(f"truncate: slot {slot} holds no pages")
        if length < 0:
            raise ValueError(f"truncate to negative length {length}")
        held = self._slot_pages[slot]
        first = length // self.page_size
        for p in self.block[slot, first:]:
            if p == 0:
                continue
            if self._slot_refs[p] != 1 or p not in held:
                raise ValueError(
                    f"truncate would rewind slot {slot} into shared page "
                    f"{int(p)} (slot_refs={int(self._slot_refs[p])}): "
                    f"copy-on-write required")

    # -- prefix index -------------------------------------------------------

    def _reclaimable(self, tick: Optional[int] = None) -> int:
        """Pages that eviction could free: index-retained, no slot refs.
        Pending entries (``ready > tick``) are not evictable, so their pages
        don't count when a tick is given."""
        pages = set()
        pending = set()
        for e in self._page_index.values():
            (pages if tick is None or e.ready <= tick else pending).add(e.page)
        for e in self._full_index.values():
            (pages if tick is None or e.ready <= tick
             else pending).update(e.pages)
        return sum(1 for p in pages - pending if self._slot_refs[p] == 0
                   and self._refs[p] > 0)

    def _evict_pages(self, need: int, tick: Optional[int] = None) -> bool:
        """Evict LRU index entries until at least ``need`` pages are free.
        Entries whose pages are still slot-referenced release only the index
        pin (the pages stay with their slots).  PENDING entries (registered
        this tick, device write still in flight) are never evicted — their
        prefix-cache row would be double-booked mid-dispatch."""
        if len(self._free) >= need:
            return True
        # merged LRU over both index kinds, least-recently-used first
        cand: List[Tuple[int, int, Any]] = []
        for h, e in self._page_index.items():
            if tick is None or e.ready <= tick:
                cand.append((e.used, 0, h))
        for h, e in self._full_index.items():
            if tick is None or e.ready <= tick:
                cand.append((e.used, 1, h))
        cand.sort()
        for _, kind, h in cand:
            if len(self._free) >= need:
                break
            # only evict entries that can contribute pages: an entry whose
            # every page is still slot-referenced frees nothing — dropping it
            # would wipe retention without making progress (the slots, not
            # the index, are what's holding the pool)
            if kind == 0:
                if self._slot_refs[self._page_index[h].page] > 0:
                    continue
                self._drop_page_entry(h)
            else:
                if all(self._slot_refs[p] > 0
                       for p in self._full_index[h].pages):
                    continue
                self._drop_full_entry(h)
            self.stats["evictions"] += 1
        return len(self._free) >= need

    def _unref(self, page: int) -> None:
        self._refs[page] -= 1
        if self._refs[page] == 0:
            self._free.append(page)

    def _drop_page_entry(self, h: bytes) -> None:
        e = self._page_index.pop(h)
        self._unref(e.page)

    def _drop_full_entry(self, h: bytes) -> None:
        e = self._full_index.pop(h)
        for p in e.pages:
            self._unref(p)
        self._row_free.append(e.row)

    def lookup(self, page_hashes: Sequence[bytes], full_hash: bytes,
               bucket: int, tick: int) -> Tuple[Optional[_FullEntry], List[int]]:
        """Longest cached prefix of a prompt.  Returns (full_entry | None,
        hit pages).  Only entries whose fill tick has completed are visible
        (``ready <= tick``), so two identical prompts admitted in the same
        tick never alias pages still being written.  The hit walk is capped
        so at least one prompt position is always left to prefill — the
        admit lane must produce last-position logits for a partial hit."""
        fe = self._full_index.get(full_hash)
        if fe is not None and fe.ready <= tick and fe.bucket == bucket:
            fe.used = tick
            return fe, list(fe.pages)
        max_pages = ((bucket - 1) // self.page_size if self.partial_prefix
                     else 0)
        pages: List[int] = []
        for h in page_hashes[:max_pages]:
            e = self._page_index.get(h)
            if e is None or e.ready > tick:
                break
            e.used = tick
            pages.append(e.page)
        return None, pages

    def admit_prefix(self, slot: int, context_len: int, bucket: int,
                     page_hashes: Optional[Sequence[bytes]],
                     full_hash: Optional[bytes], tick: int, *,
                     register: bool = True) -> Optional[AdmitPlan]:
        """Admission with prefix reuse: alias the longest cached prefix into
        ``slot``'s block row, allocate fresh pages for the rest, and decide
        restore / save / copy-on-write.  Returns None (no side effects) when
        even eviction cannot produce enough fresh pages.

        ``register=False`` (chunked-prefill admission): the prompt's pages
        fill over SEVERAL ticks, so neither the page index nor a full-prompt
        entry may advertise them at ``tick + 1`` — the admission still READS
        cached prefixes (aliasing, ``plan.start``) but retains nothing."""
        if not 0 <= slot < self.num_slots:
            raise ValueError(f"slot {slot} out of range (0..{self.num_slots - 1})")
        if slot in self._slot_pages:
            raise ValueError(f"slot {slot} already holds pages")
        if page_hashes is None or full_hash is None:
            try:
                self.alloc(slot, context_len, tick=tick)
            except ValueError:
                return None
            return AdmitPlan(slot=slot)
        n_ctx = self.pages_needed(context_len)
        if n_ctx > self.n_pages_per_slot:
            raise ValueError(
                f"context {context_len} needs {n_ctx} pages > per-slot "
                f"maximum {self.n_pages_per_slot}")
        fe, hit = self.lookup(page_hashes, full_hash, bucket, tick)
        n_alias = len(hit) if fe is None else bucket // self.page_size
        # alias the hit pages FIRST — the slot reference pins them so the
        # eviction pass below can never reclaim a page we are about to use
        self._slot_pages[slot] = []
        row: List[int] = []
        plan = AdmitPlan(slot=slot)
        for p in (hit if fe is None else fe.pages[: n_alias]):
            row.append(self._alias_page(slot, p))
        if not self._evict_pages(n_ctx - n_alias, tick=tick):
            for p in reversed(self._slot_pages.pop(slot)):   # rollback
                self._refs[p] -= 1
                self._slot_refs[p] -= 1
                if self._refs[p] == 0:
                    self._free.append(p)
            return None
        if fe is not None:
            # full restore: every FULL prompt page is aliased; the partial
            # tail page (decode appends into it) is copy-on-write; fresh
            # pages cover the decode region
            tail = bucket % self.page_size
            if tail:
                dst = self._pop_page(slot)
                row.append(dst)
                plan.cow = (fe.pages[-1], dst)
                self.stats["cow_copies"] += 1
            while len(row) < n_ctx:
                row.append(self._pop_page(slot))
            plan.start = bucket
            plan.restore_row = fe.row
            self.stats["full_hits"] += 1
            self.stats["hits"] += 1
            self.stats["tokens_saved"] += bucket
        else:
            plan.start = len(hit) * self.page_size
            if hit:
                self.stats["hits"] += 1
                self.stats["tokens_saved"] += plan.start
            # fresh pages for the uncached prompt suffix; register the FULL
            # ones in the page index (their content lands this tick, usable
            # from the next)
            n_full = (bucket // self.page_size
                      if self.partial_prefix and register else 0)
            for i in range(len(row), n_ctx):
                p = self._pop_page(slot)
                row.append(p)
                if i < n_full and page_hashes[i] not in self._page_index:
                    self._refs[p] += 1
                    self._page_index[page_hashes[i]] = \
                        _PageEntry(p, ready=tick + 1, used=tick)
            if register:
                plan.save_row = self._reserve_full_entry(
                    full_hash, row, bucket, tick)
        self.block[slot, :] = 0
        self.block[slot, : len(row)] = row
        return plan

    def retract(self, slot: int, page_hashes: Optional[Sequence[bytes]],
                full_hash: Optional[bytes], tick: int) -> None:
        """Undo the index registrations a SAME-TICK admission made, for an
        admission that is being rolled back before its tick ran (paired
        speculative admission where the partner tier failed).  Registered
        entries become visible at ``tick + 1``; any entry for this prompt
        still pending (``ready == tick + 1``) whose pages belong to ``slot``
        was created by this admission — its pages will now never be written,
        so it must not survive for a later lookup to alias garbage.  Entries
        owned by a co-admitted identical prompt (different slot) are left
        alone: their prefill still runs.  Call BEFORE ``free(slot)``."""
        held = set(self._slot_pages.get(slot, ()))
        for h in page_hashes or ():
            e = self._page_index.get(h)
            if e is not None and e.ready == tick + 1 and e.page in held:
                self._drop_page_entry(h)
        if full_hash is not None:
            fe = self._full_index.get(full_hash)
            if fe is not None and fe.ready == tick + 1 \
                    and set(fe.pages) <= held:
                self._drop_full_entry(full_hash)

    def _reserve_full_entry(self, full_hash: bytes, row: List[int],
                            bucket: int, tick: int) -> int:
        """Pin this admission's prompt pages + one prefix-cache row so the
        whole prompt can be restored later.  Returns the row or -1 when no
        row is available (all in use and nothing evictable)."""
        if self.prefix_entries == 0 or full_hash in self._full_index:
            return -1
        if not self._row_free:
            # evict the least-recently-used NON-PENDING full entry to
            # recycle its row (a pending row has a device write in flight)
            cand = [kv for kv in self._full_index.items()
                    if kv[1].ready <= tick]
            if not cand:
                return -1
            lru = min(cand, key=lambda kv: kv[1].used)
            self._drop_full_entry(lru[0])
            self.stats["evictions"] += 1
        r = self._row_free.pop()
        pages = row[: self.pages_needed(bucket)]
        for p in pages:
            self._refs[p] += 1
        self._full_index[full_hash] = _FullEntry(
            row=r, pages=list(pages), bucket=bucket, ready=tick + 1,
            used=tick)
        return r

    def write_block(self) -> np.ndarray:
        """Block table for the DECODE WRITE path: pages referenced by more
        than one slot are masked to the null page, so an (invariant-breaking)
        append into a shared page drops instead of corrupting a co-resident
        request.  Host admission guarantees the written page is exclusive
        (COW), making this pure defense in depth."""
        shared = self._slot_refs[self.block] > 1
        return np.where(shared, 0, self.block).astype(np.int32)

    def check_invariants(self) -> None:
        """Debug/test hook: refcount conservation — every page's refcount
        equals its slot references + index retentions, free pages carry no
        references, and live + free pages partition the pool.  Quantized
        pools additionally check scale-row accounting: every page pool has
        fp32 scale tensors with one row per PHYSICAL page, so every
        allocator move of a page implicitly moves its scale row."""
        if "ks" in self.buffers:
            for pool_key, scale_key in (("kp", "ks"), ("vp", "vs")):
                pool, scale = self.buffers[pool_key], self.buffers[scale_key]
                assert jnp.dtype(pool.dtype) == jnp.int8, \
                    f"quantized pool {pool_key} must be int8, got {pool.dtype}"
                assert jnp.dtype(scale.dtype) == jnp.float32, \
                    f"scale {scale_key} must be fp32, got {scale.dtype}"
                # pools are (..., P, page, K, Dh), scales (..., P, K): one
                # scale row per physical page and head
                assert scale.shape[-2] == self.num_pages, \
                    f"scale {scale_key} has {scale.shape[-2]} rows for " \
                    f"{self.num_pages} pages"
                assert scale.shape[:-2] == pool.shape[:-4] and \
                    scale.shape[-1] == pool.shape[-2], \
                    f"scale {scale_key} shape {scale.shape} does not match " \
                    f"pool {pool_key} shape {pool.shape}"
        else:
            assert "vs" not in self.buffers, "vs scale without ks"
        refs = np.zeros((self.num_pages,), np.int32)
        slot_refs = np.zeros((self.num_pages,), np.int32)
        for pages in self._slot_pages.values():
            for p in pages:
                refs[p] += 1
                slot_refs[p] += 1
        for e in self._page_index.values():
            refs[e.page] += 1
        for e in self._full_index.values():
            for p in e.pages:
                refs[p] += 1
        assert (refs == self._refs).all(), "refcount conservation violated"
        assert (slot_refs == self._slot_refs).all(), \
            "slot refcount conservation violated"
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert 0 not in free, "null page freed"
        assert self._refs[0] == 0, "null page referenced"
        live = {p for p in range(self.num_pages) if self._refs[p] > 0}
        assert not (free & live), "page free AND referenced"
        assert len(free) + len(live) == self.num_pages - 1, "pages leaked"
        for slot in range(self.num_slots):
            row = set(self.block[slot][self.block[slot] > 0].tolist())
            assert row <= set(self._slot_pages.get(slot, [])), \
                f"slot {slot} block row references unheld pages"
        rows = [e.row for e in self._full_index.values()]
        assert len(set(rows)) == len(rows), "prefix-cache row double-booked"
        assert not (set(rows) & set(self._row_free)), \
            "prefix-cache row free AND in use"
        if self.prefix_entries:
            assert len(rows) + len(self._row_free) == self.prefix_entries, \
                "prefix-cache rows leaked"
