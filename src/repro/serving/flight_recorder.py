"""Flight recorder: a bounded ring of per-tick scheduler snapshots that
dumps a deterministic postmortem JSON when something goes wrong.

Before this module a misbehaving run died with a bare ``RuntimeError``
("scheduler stalled") or a bare ``AssertionError`` out of
``KVPool.check_invariants`` — the state that explained the failure (queue
depths, breaker state, pool gauges, recent audit aggregates) was gone by the
time anyone looked.  The recorder keeps the last ``capacity`` tick snapshots
in a ``deque`` and freezes them the moment a trigger fires:

* **breaker-open** — the circuit breaker transitioned to OPEN this tick;
* **SLO breach** — the :class:`~repro.serving.audit.SLOWatchdog` tripped;
* **invariant failure** — ``check_invariants`` raised (``validate=True``);
* **stall** — the scheduler's idle-tick bound tripped.

Snapshots are built by the scheduler from host state it already holds —
recording costs no device traffic and nothing when not installed
(``flight_recorder=None`` is the default, same contract as
``telemetry=None``).

Determinism: a snapshot carries tick index, typed counters (minus the
wall-clock ``serve_time``), gauges, queue depth, and audit aggregates — all
deterministic functions of the request trace + fault schedule, so a dump
triggered by a seeded :class:`~repro.serving.faults.FaultSchedule` is
byte-identical across runs (test-asserted).  Wall-clock phase timings are
EXCLUDED unless ``include_timings=True`` (for humans; breaks byte-identity).

``path`` (optional) writes the most recent dump as sorted-keys JSON — CI
uploads it as a workflow artifact when a chaos gate fails.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Any, Dict, List, Optional


class FlightRecorder:
    """Bounded tick-snapshot ring + postmortem dump trigger."""

    def __init__(self, capacity: int = 64, path: Optional[str] = None,
                 include_timings: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.ring: deque = deque(maxlen=self.capacity)
        self.dumps: List[Dict[str, Any]] = []
        self.path = path
        self.include_timings = bool(include_timings)

    def record(self, snapshot: Dict[str, Any]) -> None:
        """Append one per-tick snapshot (the scheduler calls this once per
        tick; the ring drops the oldest snapshot past ``capacity``)."""
        self.ring.append(snapshot)

    def trigger(self, reason: str, tick: int,
                detail: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Freeze the ring into a postmortem dump.  Returns the dump dict
        (also appended to :attr:`dumps`; written to :attr:`path` when
        configured — last trigger wins the file)."""
        dump = {
            "reason": reason,
            "tick": int(tick),
            "seq": len(self.dumps),
            "detail": dict(detail) if detail else {},
            "ring": [dict(s) for s in self.ring],
        }
        self.dumps.append(dump)
        if self.path:
            with open(self.path, "w") as f:
                f.write(self.dump_json(dump))
        return dump

    @property
    def last_dump(self) -> Optional[Dict[str, Any]]:
        return self.dumps[-1] if self.dumps else None

    @staticmethod
    def dump_json(dump: Dict[str, Any]) -> str:
        """Canonical serialization: sorted keys, fixed separators — two
        dumps with equal content serialize byte-identically."""
        return json.dumps(dump, sort_keys=True, indent=1,
                          separators=(",", ": "), default=float) + "\n"
