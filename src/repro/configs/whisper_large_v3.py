"""whisper-large-v3 — encoder-decoder audio model [arXiv:2212.04356].

32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866; conv/mel frontend is a
STUB per the assignment carve-out — input_specs provides precomputed frame
embeddings of shape (batch, num_audio_frames, d_model).
"""
from repro.configs.base import ModelConfig, ENCDEC

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family=ENCDEC,
    num_layers=32,            # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    num_audio_frames=1500,
    qkv_bias=True,
    source="Whisper [arXiv:2212.04356]",
)
