"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
The attention block weights are SHARED (applied every `shared_attn_every`
layers), per the Zamba2 design.
"""
from repro.configs.base import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    shared_attn_every=6,
    source="Zamba2 [arXiv:2411.15242]",
)
