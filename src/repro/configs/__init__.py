from repro.configs.base import (  # noqa: F401
    DENSE, MOE, SSM, HYBRID, ENCDEC, VLM, FAMILIES,
    ModelConfig, ShapeConfig, HIConfig, TrainConfig,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, SHAPES,
)
