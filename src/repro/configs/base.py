"""Configuration system for the HI framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances.  Configs are frozen
dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"   # whisper-style audio encoder-decoder
VLM = "vlm"         # llava-style decoder with patch-embedding prefix

FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM)


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Fields unused by a family stay at their defaults."""

    name: str
    family: str
    num_layers: int
    d_model: int
    vocab_size: int
    # -- attention ----------------------------------------------------------
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                  # 0 -> d_model // num_heads
    d_ff: int = 0
    qkv_bias: bool = False
    sliding_window: int = 0            # 0 -> full causal attention
    local_global_ratio: int = 0        # gemma3: N local layers per 1 global
    rope_theta: float = 10_000.0
    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0        # deepseek fine-grained shared experts
    d_ff_expert: int = 0               # routed-expert hidden size
    moe_dense_residual: bool = False   # arctic: dense FFN residual in parallel
    router_aux_coef: float = 0.01
    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_ngroups: int = 1
    # -- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0         # insert the shared attn block every k layers
    # -- encoder-decoder (whisper) --------------------------------------------
    encoder_layers: int = 0
    num_audio_frames: int = 1500       # stubbed conv/mel frontend output length
    # -- VLM (llava) -----------------------------------------------------------
    num_patches: int = 0               # stubbed vision-tower patch embeddings
    # -- misc -------------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                   # citation for the config numbers

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (bounded per-token attention cost)."""
        if self.family in (SSM, HYBRID):
            return True
        if self.family == DENSE and (self.sliding_window or self.local_global_ratio):
            return True
        return False

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                num_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family (assignment: <=2 layers,
        d_model<=512, <=4 experts)."""
        nh = max(1, min(self.num_heads, 4))
        nkv = max(1, min(self.num_kv_heads, nh))
        hd = max(8, d_model // max(nh, 1)) if self.num_heads else 0
        changes = dict(
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=nh if self.num_heads else 0,
            num_kv_heads=nkv if self.num_kv_heads else 0,
            head_dim=hd,
            d_ff=4 * d_model if self.d_ff else 0,
            vocab_size=min(self.vocab_size, vocab),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.num_experts:
            changes.update(
                num_experts=min(self.num_experts, num_experts),
                experts_per_token=min(self.experts_per_token, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                d_ff_expert=2 * d_model if self.d_ff_expert else 0,
            )
        if self.family in (SSM, HYBRID):
            changes.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=16,
                           ssm_chunk=32)
        if self.family == HYBRID:
            changes.update(shared_attn_every=2)
        if self.family == ENCDEC:
            changes.update(encoder_layers=2, num_audio_frames=16)
        if self.family == VLM:
            changes.update(num_patches=8)
        return dataclasses.replace(self, **changes)

    def s_variant(self, scale: int = 4) -> "ModelConfig":
        """The S-ML tier for the HI cascade: same family, ~1/scale params."""
        d = max(128, self.d_model // scale)
        nh = max(1, self.num_heads // scale) if self.num_heads else 0
        nkv = 0
        if nh:
            nkv = max(1, min(self.num_kv_heads, nh))
            while nh % nkv:              # GQA needs kv | heads
                nkv -= 1
        changes = dict(
            name=self.name + f"-s{scale}",
            num_layers=max(2, self.num_layers // scale),
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=self.resolved_head_dim if nh else 0,
            d_ff=max(128, self.d_ff // scale) if self.d_ff else 0,
        )
        if self.num_experts:
            changes.update(num_experts=max(4, self.num_experts // scale),
                           d_ff_expert=max(64, self.d_ff_expert // scale))
        if self.family == ENCDEC:
            changes.update(encoder_layers=max(2, self.encoder_layers // scale))
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class HIConfig:
    """Paper §4 decision-rule + cost-model parameters."""

    metric: str = "max_prob"        # max_prob | margin | entropy
    theta: float = 0.607            # paper's calibrated theta* for CIFAR-10
    beta: float = 0.5               # offload cost in [0, 1)
    capacity_factor: float = 0.5    # static offload capacity / batch
    s_scale: int = 4                # S-variant reduction factor
    binary_relevance: bool = False  # dog-breed rule: offload iff p >= theta


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    grad_accum: int = 1            # microbatch accumulation (lax.scan)
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    bf16_state: bool = True        # keep Adam moments in bf16 (memory)
    factored_v: bool = False       # Adafactor-style factored second moment
                                   # (row+col stats for matrices — kills the
                                   # per-param v buffer on 100B+ models)
    accum_dtype: str = "float32"   # grad-accumulation buffer dtype
    remat: bool = True
    seed: int = 0
