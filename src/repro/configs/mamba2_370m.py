"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, d_ff=0, vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-370m",
    family=SSM,
    num_layers=48,
    d_model=1024,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv_width=4,
    ssm_ngroups=1,
    source="SSD / Mamba-2 [arXiv:2405.21060]",
)
