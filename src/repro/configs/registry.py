"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.configs import (
    mamba2_370m,
    deepseek_moe_16b,
    whisper_large_v3,
    granite_3_2b,
    zamba2_2p7b,
    gemma3_1b,
    llava_next_34b,
    arctic_480b,
    qwen2_1p5b,
    h2o_danube_3_4b,
)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        mamba2_370m.CONFIG,
        deepseek_moe_16b.CONFIG,
        whisper_large_v3.CONFIG,
        granite_3_2b.CONFIG,
        zamba2_2p7b.CONFIG,
        gemma3_1b.CONFIG,
        llava_next_34b.CONFIG,
        arctic_480b.CONFIG,
        qwen2_1p5b.CONFIG,
        h2o_danube_3_4b.CONFIG,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; choose from {sorted(SHAPES)}")
    return SHAPES[name]


def shape_applicable(arch: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment skip rules.  Returns (applicable, reason-if-not)."""
    if shape.name == "long_500k":
        if arch.family == "encdec":
            return False, "enc-dec audio model: no 500k-token decode exists"
        if not arch.sub_quadratic:
            return False, "pure full-attention arch: long_500k requires sub-quadratic attention"
    return True, ""
