"""arctic-480b — 128-expert top-2 MoE + dense residual [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic's dense-MoE hybrid: a dense FFN residual path runs in parallel with the
routed experts.
"""
from repro.configs.base import ModelConfig, MOE

CONFIG = ModelConfig(
    name="arctic-480b",
    family=MOE,
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    d_ff_expert=4864,
    vocab_size=32_000,
    num_experts=128,
    experts_per_token=2,
    num_shared_experts=0,
    moe_dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)
