"""llava-next-34b — VLM decoder with anyres tiling [hf:llava-hf/llava-v1.6-*].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower +
projector are a STUB per the assignment carve-out: input_specs provides
precomputed anyres patch embeddings (batch, num_patches, d_model) which the
decoder consumes as a prefix.
"""
from repro.configs.base import ModelConfig, VLM

CONFIG = ModelConfig(
    name="llava-next-34b",
    family=VLM,
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    num_patches=2880,   # anyres: base 576 patches x up-to-4 tiles + base image
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34b variant dims)",
)
