"""gemma3-1b — dense, 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.  Local layers use a
1024-token sliding window; every 6th layer is global.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="gemma3-1b",
    family=DENSE,
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    sliding_window=1024,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt",
)
