"""Zamba2-style hybrid: Mamba-2 backbone + a SHARED attention block applied
every ``shared_attn_every`` layers [arXiv:2411.15242].

Structured as ``G = num_layers // shared_attn_every`` groups; each group is an
inner scan over its mamba layers followed by the shared attention block (one
set of weights, applied G times — Zamba2's parameter-sharing trick).  The
outer scan carries the per-group KV-cache slots for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.sharding import act

Params = Dict[str, Any]


def _num_groups(cfg: ModelConfig) -> int:
    k = cfg.shared_attn_every
    if cfg.num_layers % k:
        raise ValueError(f"num_layers {cfg.num_layers} must divide by "
                         f"shared_attn_every {k}")
    return cfg.num_layers // k


def init_model(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 5)
    layer_rngs = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda r: mamba2.init_mamba_block(r, cfg, dtype))(layer_rngs)
    g, k = _num_groups(cfg), cfg.shared_attn_every
    # reshape the layer stack to (G, k, ...) for the nested scan
    stacked = jax.tree.map(lambda a: a.reshape(g, k, *a.shape[1:]), stacked)
    shared = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[1], cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dtype=dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": L.embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "shared_attn": shared,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(ks[4], cfg.d_model, cfg.vocab_size, dtype),
    }


def _shared_block(sp: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    a = L.attention_forward(sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps),
                            num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                            head_dim=cfg.resolved_head_dim,
                            rope_theta=cfg.rope_theta)
    x = x + a
    return x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            remat: bool = False, use_kernel: bool = False,
            last_only: bool = False) -> jnp.ndarray:
    h = params["embed"][tokens]
    sp = params["shared_attn"]

    def inner(carry, lp):
        x = act.shard_hidden(carry)
        y = mamba2.mamba_block(lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                               use_kernel=use_kernel)
        return x + y, None

    def outer(carry, group_params):
        x = carry
        x, _ = lax.scan(inner, x, group_params)
        x = _shared_block(sp, cfg, x)
        return act.shard_hidden(x), None

    if remat:
        outer = jax.checkpoint(outer, prevent_cse=False)
    h, _ = lax.scan(outer, act.shard_hidden(h), params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    return act.shard_logits((h @ params["lm_head"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    g = _num_groups(cfg)
    ssm = mamba2.init_cache(cfg, batch, seq_len, dtype)
    kv_shape = (g, batch, seq_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    gk = _num_groups(cfg), cfg.shared_attn_every
    ssm_state = ssm["state"].reshape(gk[0], gk[1], *ssm["state"].shape[1:])
    ssm_conv = ssm["conv"].reshape(gk[0], gk[1], *ssm["conv"].shape[1:])
    return {
        "state": ssm_state, "conv": ssm_conv,
        "k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Params) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token]
    sp = params["shared_attn"]
    pos = cache["pos"]

    def inner(carry, xs):
        x = carry
        lp, st, cw = xs
        y, st, cw = mamba2.mamba_block_step(
            lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps), st, cw)
        return x + y, (st, cw)

    def outer(carry, xs):
        x = carry
        gp, st_g, cw_g, ck, cv = xs
        x, (st_g, cw_g) = lax.scan(inner, x, (gp, st_g, cw_g))
        a, ck, cv = L.attention_decode(sp["attn"],
                                       L.rmsnorm(sp["ln1"], x, cfg.norm_eps),
                                       ck, cv, pos,
                                       num_heads=cfg.num_heads,
                                       num_kv=cfg.num_kv_heads,
                                       head_dim=cfg.resolved_head_dim,
                                       rope_theta=cfg.rope_theta)
        x = x + a
        x = x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))
        return x, (st_g, cw_g, ck, cv)

    h, (ns, ncw, nk, nv) = lax.scan(
        outer, h, (params["layers"], cache["state"], cache["conv"],
                   cache["k"], cache["v"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw, "k": nk, "v": nv, "pos": pos + 1}


# ---------------------------------------------------------------------------
# paged cache API (continuous batching)
# ---------------------------------------------------------------------------
#
# Mamba states are per-slot (constant-size, nothing to page); the shared
# attention block's KV is a paged pool with a per-GROUP leading axis,
# (G, P, page, K, Dh), indexed by the scheduler's single block table (one
# physical page holds one group's K/V for a page worth of positions).


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    g, k = _num_groups(cfg), cfg.shared_attn_every
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    kv_shape = (g, num_pages, page_size, cfg.num_kv_heads,
                cfg.resolved_head_dim)
    quant = jnp.dtype(dtype) == jnp.int8
    # recurrent state stays full-precision under int8 KV quantization (it is
    # per-slot and constant-size — paging/quantizing it buys nothing)
    conv_dtype = jnp.bfloat16 if quant else dtype
    cache = {
        "state": jnp.zeros((g, k, num_slots, h, p, n), jnp.float32),
        "conv": jnp.zeros((g, k, num_slots, cfg.ssm_conv_width - 1, conv_dim),
                          conv_dtype),
        "kp": jnp.zeros(kv_shape, dtype), "vp": jnp.zeros(kv_shape, dtype),
    }
    if quant:
        sshape = (g, num_pages, cfg.num_kv_heads)
        cache["ks"] = jnp.zeros(sshape, jnp.float32)
        cache["vs"] = jnp.zeros(sshape, jnp.float32)
    return cache


def _prefill_outer(params: Params, cfg: ModelConfig, s: int, b: int,
                   kv_dtype, conv_dtype, use_kernel: bool, length, store_kv,
                   page: int = 0, quant: bool = False):
    """The per-group prefill scan body shared by :func:`prefill` (contiguous
    cache) and :func:`prefill_paged` (page pool).  ``store_kv(kv, k, v)``
    writes the group's shared-attention K/V into whichever layout the caller
    scans through; everything else is identical between the two paths.

    ``quant`` (int8 page pool): in-pass attention sees K/V fake-quantized
    through the per-page int8 grid while RAW values flow to ``store_kv``,
    whose quantize-on-write recomputes the identical scales."""
    sp = params["shared_attn"]
    hd = cfg.resolved_head_dim
    pos = jnp.arange(s)

    def inner(carry, lp):
        x = carry
        y, st, cw = mamba2.mamba_block_prefill(
            lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps),
            use_kernel=use_kernel, conv_dtype=conv_dtype, length=length)
        return x + y, (st, cw)

    def outer(carry, xs):
        x = carry
        gp, kv = xs
        x, (st_g, cw_g) = lax.scan(inner, x, gp)
        xn = L.rmsnorm(sp["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(sp["attn"], xn, cfg.num_heads, cfg.num_kv_heads, hd)
        if cfg.rope_theta > 0:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        if quant:
            k_raw, v_raw = k, v
            k = L.quant_dequant_pages(k, page)
            v = L.quant_dequant_pages(v, page)
        else:
            k = k.astype(kv_dtype)
            v = v.astype(kv_dtype)
        a = L._sdpa(q, k, v, L.causal_window_mask(s, s))
        x = x + a.reshape(b, s, cfg.num_heads * hd) @ sp["attn"]["wo"]
        x = x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))
        stored = store_kv(kv, k_raw, v_raw) if quant else store_kv(kv, k, v)
        return act.shard_hidden(x), (st_g, cw_g, stored)

    return outer


def init_prefix_cache(cfg: ModelConfig, entries: int, dtype=jnp.bfloat16):
    """Full-prompt snapshot rows for the recurrent half of the hybrid: the
    per-group mamba states + conv windows at the prompt boundary.  The
    shared-attention K/V needs no snapshot — its prompt pages are retained
    by the pool's prefix index and aliased on restore."""
    g, k = _num_groups(cfg), cfg.shared_attn_every
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    if jnp.dtype(dtype) == jnp.int8:
        dtype = jnp.bfloat16
    return {
        "state": jnp.zeros((g, k, entries, h, p, n), jnp.float32),
        "conv": jnp.zeros((g, k, entries, cfg.ssm_conv_width - 1, conv_dim),
                          dtype),
    }


def snapshot_save(cfg: ModelConfig, cache: Params, prefix: Params,
                  rows: jnp.ndarray, slots: jnp.ndarray) -> Params:
    return dict(prefix,
                state=prefix["state"].at[:, :, rows].set(
                    cache["state"][:, :, slots], mode="drop"),
                conv=prefix["conv"].at[:, :, rows].set(
                    cache["conv"][:, :, slots], mode="drop"))


def snapshot_restore(cfg: ModelConfig, cache: Params, prefix: Params,
                     rows: jnp.ndarray, slots: jnp.ndarray) -> Params:
    return dict(cache,
                state=cache["state"].at[:, :, slots].set(
                    prefix["state"][:, :, rows], mode="drop"),
                conv=cache["conv"].at[:, :, slots].set(
                    prefix["conv"][:, :, rows], mode="drop"))


def prefill_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  lengths: jnp.ndarray, slots: jnp.ndarray,
                  block_rows: jnp.ndarray, cache: Params, *,
                  use_kernel: bool = False,
                  start=None) -> Tuple[jnp.ndarray, Params]:
    """Prefill a batch of admitted requests: per-group SSM states/conv
    windows land in slots ``slots``; shared-attention K/V lands in each
    slot's pages.  The group math is EXACTLY :func:`prefill`'s (shared
    ``_prefill_outer``); only the K/V store differs.

    ``start``: the hybrid family shares prefixes only at whole-prompt
    granularity (the mamba state has no mid-prompt snapshot), so per row
    ``start`` is 0 (miss: full prefill) or bucket (restore: every page write
    redirected to the null page — the aliased prompt pages are read-only)."""
    h = params["embed"][tokens]
    b, s, _ = h.shape
    page = cache["kp"].shape[2]
    npg = s // page
    quant = "ks" in cache
    wrows = (block_rows[:, :npg] if start is None
             else L.suffix_write_rows(block_rows, start, npg, page))

    if quant:
        def store_kv(kv, k, v):
            pk, pv, sk, sv = kv
            pk, sk = L.quant_scatter_prefill_pages(pk, sk, k, wrows)
            pv, sv = L.quant_scatter_prefill_pages(pv, sv, v, wrows)
            return (pk, pv, sk, sv)
        kv0 = (cache["kp"], cache["vp"], cache["ks"], cache["vs"])
    else:
        def store_kv(kv, k, v):
            pk, pv = kv
            return (L.scatter_prefill_pages(pk, k, wrows),
                    L.scatter_prefill_pages(pv, v, wrows))
        kv0 = (cache["kp"], cache["vp"])

    outer = _prefill_outer(params, cfg, s, b, cache["kp"].dtype,
                           cache["conv"].dtype, use_kernel, lengths, store_kv,
                           page=page, quant=quant)
    h, (ns, ncw, nkv) = lax.scan(
        outer, act.shard_hidden(h), (params["layers"], kv0))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    new_cache = {
        "state": cache["state"].at[:, :, slots].set(ns, mode="drop"),
        "conv": cache["conv"].at[:, :, slots].set(ncw, mode="drop"),
        "kp": nkv[0], "vp": nkv[1],
    }
    if quant:
        new_cache["ks"], new_cache["vs"] = nkv[2], nkv[3]
    return logits, new_cache


def decode_step_paged(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                      pos: jnp.ndarray, block: jnp.ndarray, cache: Params, *,
                      use_kernel: bool = False,
                      write_block=None) -> Tuple[jnp.ndarray, Params]:
    """One decode step for all slots at per-slot positions."""
    h = params["embed"][token]
    sp = params["shared_attn"]

    def inner(carry, xs):
        x = carry
        lp, st, cw = xs
        y, st, cw = mamba2.mamba_block_step(
            lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps), st, cw)
        return x + y, (st, cw)

    quant = "ks" in cache

    def outer(carry, xs):
        x = carry
        if quant:
            gp, st_g, cw_g, pk, pv, sk, sv = xs
        else:
            gp, st_g, cw_g, pk, pv = xs
        x, (st_g, cw_g) = lax.scan(inner, x, (gp, st_g, cw_g))
        if quant:
            a, pk, pv, sk, sv = L.attention_decode_paged(
                sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                use_kernel=use_kernel, write_block=write_block,
                scale_k=sk, scale_v=sv)
        else:
            a, pk, pv = L.attention_decode_paged(
                sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                use_kernel=use_kernel, write_block=write_block)
        x = x + a
        x = x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))
        return x, ((st_g, cw_g, pk, pv, sk, sv) if quant
                   else (st_g, cw_g, pk, pv))

    if quant:
        h, (ns, ncw, nk, nv, nsk, nsv) = lax.scan(
            outer, h, (params["layers"], cache["state"], cache["conv"],
                       cache["kp"], cache["vp"], cache["ks"], cache["vs"]))
    else:
        h, (ns, ncw, nk, nv) = lax.scan(
            outer, h, (params["layers"], cache["state"], cache["conv"],
                       cache["kp"], cache["vp"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    new_cache = {"state": ns, "conv": ncw, "kp": nk, "vp": nv}
    if quant:
        new_cache["ks"], new_cache["vs"] = nsk, nsv
    return logits, new_cache


def forward_chunk_paged(params: Params, cfg: ModelConfig,
                        tokens: jnp.ndarray, pos: jnp.ndarray,
                        block: jnp.ndarray, cache: Params, *,
                        use_kernel: bool = False,
                        write_block=None) -> Tuple[jnp.ndarray, Params, dict]:
    """Chunked token lane for the hybrid: a ``lax.scan`` of per-token steps
    (bitwise identical to C sequential ``decode_step_paged`` calls — the
    mamba half is inherently sequential) emitting per-step {state, conv}
    CHUNK-BOUNDARY SNAPSHOTS so a chunk can be rolled back to any intra-chunk
    position; the shared-attention K/V lands in the pages positionally (a
    rollback just rewinds the host position).  Token i of slot b writes at
    ``pos[b] + i`` through ``write_block``.  Returns (logits (B, C, V) fp32,
    cache, staged)."""

    def step(carry, xs):
        cache = carry
        tok, j = xs
        logits, cache = decode_step_paged(params, cfg, tok[:, None], pos + j,
                                          block, cache, use_kernel=use_kernel,
                                          write_block=write_block)
        return cache, (logits, {"state": cache["state"],
                                "conv": cache["conv"]})

    c = tokens.shape[1]
    cache, (logits, staged) = lax.scan(step, cache,
                                       (tokens.T, jnp.arange(c)))
    return logits.transpose(1, 0, 2), cache, staged


def chunk_stage(cfg: ModelConfig, cache: Params) -> dict:
    """Rollback-able recurrent slice (slot axis 2: leaves (G, K, B, ...))."""
    return {"state": cache["state"], "conv": cache["conv"]}


def restore_stage(cfg: ModelConfig, cache: Params, stage: dict,
                  mask: jnp.ndarray) -> Params:
    return dict(cache,
                state=jnp.where(mask[None, None, :, None, None, None],
                                stage["state"], cache["state"]),
                conv=jnp.where(mask[None, None, :, None, None],
                               stage["conv"], cache["conv"]))


def select_stage(cfg: ModelConfig, staged: dict, keep: jnp.ndarray) -> dict:
    """staged leaves (C, G, K, B, ...) -> snapshot after ``keep`` inputs."""
    idx = jnp.maximum(keep - 1, 0)

    def sel(a):
        i = idx.reshape((1, 1, 1, -1) + (1,) * (a.ndim - 4))
        return jnp.take_along_axis(a, i, axis=0)[0]

    return {"state": sel(staged["state"]), "conv": sel(staged["conv"])}


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Params, *, use_kernel: bool = False
            ) -> Tuple[jnp.ndarray, Params]:
    """Consume the whole (B, S) prompt in one batched pass, writing the SSM
    states, conv windows, and the per-group shared-attention KV slots.
    ``cache`` supplies the buffers and is overwritten (donation-safe).

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    h = params["embed"][tokens]
    b, s, _ = h.shape

    def store_kv(kv, k, v):
        ck, cv = kv
        return (lax.dynamic_update_slice(ck, k, (0, 0, 0, 0)),
                lax.dynamic_update_slice(cv, v, (0, 0, 0, 0)))

    outer = _prefill_outer(params, cfg, s, b, cache["k"].dtype,
                           cache["conv"].dtype, use_kernel, None, store_kv)
    h, (ns, ncw, (nk, nv)) = lax.scan(
        outer, act.shard_hidden(h), (params["layers"],
                                     (cache["k"], cache["v"])))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, -1, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw, "k": nk, "v": nv,
                    "pos": jnp.asarray(s, jnp.int32)}
