"""Zamba2-style hybrid: Mamba-2 backbone + a SHARED attention block applied
every ``shared_attn_every`` layers [arXiv:2411.15242].

Structured as ``G = num_layers // shared_attn_every`` groups; each group is an
inner scan over its mamba layers followed by the shared attention block (one
set of weights, applied G times — Zamba2's parameter-sharing trick).  The
outer scan carries the per-group KV-cache slots for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.sharding import act

Params = Dict[str, Any]


def _num_groups(cfg: ModelConfig) -> int:
    k = cfg.shared_attn_every
    if cfg.num_layers % k:
        raise ValueError(f"num_layers {cfg.num_layers} must divide by "
                         f"shared_attn_every {k}")
    return cfg.num_layers // k


def init_model(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 5)
    layer_rngs = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda r: mamba2.init_mamba_block(r, cfg, dtype))(layer_rngs)
    g, k = _num_groups(cfg), cfg.shared_attn_every
    # reshape the layer stack to (G, k, ...) for the nested scan
    stacked = jax.tree.map(lambda a: a.reshape(g, k, *a.shape[1:]), stacked)
    shared = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(ks[1], cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim,
                                 dtype=dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": L.embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "shared_attn": shared,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(ks[4], cfg.d_model, cfg.vocab_size, dtype),
    }


def _shared_block(sp: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    a = L.attention_forward(sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps),
                            num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                            head_dim=cfg.resolved_head_dim,
                            rope_theta=cfg.rope_theta)
    x = x + a
    return x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            remat: bool = False, use_kernel: bool = False,
            last_only: bool = False) -> jnp.ndarray:
    h = params["embed"][tokens]
    sp = params["shared_attn"]

    def inner(carry, lp):
        x = act.shard_hidden(carry)
        y = mamba2.mamba_block(lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                               use_kernel=use_kernel)
        return x + y, None

    def outer(carry, group_params):
        x = carry
        x, _ = lax.scan(inner, x, group_params)
        x = _shared_block(sp, cfg, x)
        return act.shard_hidden(x), None

    if remat:
        outer = jax.checkpoint(outer, prevent_cse=False)
    h, _ = lax.scan(outer, act.shard_hidden(h), params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    return act.shard_logits((h @ params["lm_head"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    g = _num_groups(cfg)
    ssm = mamba2.init_cache(cfg, batch, seq_len, dtype)
    kv_shape = (g, batch, seq_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    gk = _num_groups(cfg), cfg.shared_attn_every
    ssm_state = ssm["state"].reshape(gk[0], gk[1], *ssm["state"].shape[1:])
    ssm_conv = ssm["conv"].reshape(gk[0], gk[1], *ssm["conv"].shape[1:])
    return {
        "state": ssm_state, "conv": ssm_conv,
        "k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Params) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token]
    sp = params["shared_attn"]
    pos = cache["pos"]

    def inner(carry, xs):
        x = carry
        lp, st, cw = xs
        y, st, cw = mamba2.mamba_block_step(
            lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps), st, cw)
        return x + y, (st, cw)

    def outer(carry, xs):
        x = carry
        gp, st_g, cw_g, ck, cv = xs
        x, (st_g, cw_g) = lax.scan(inner, x, (gp, st_g, cw_g))
        a, ck, cv = L.attention_decode(sp["attn"],
                                       L.rmsnorm(sp["ln1"], x, cfg.norm_eps),
                                       ck, cv, pos,
                                       num_heads=cfg.num_heads,
                                       num_kv=cfg.num_kv_heads,
                                       head_dim=cfg.resolved_head_dim,
                                       rope_theta=cfg.rope_theta)
        x = x + a
        x = x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))
        return x, (st_g, cw_g, ck, cv)

    h, (ns, ncw, nk, nv) = lax.scan(
        outer, h, (params["layers"], cache["state"], cache["conv"],
                   cache["k"], cache["v"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw, "k": nk, "v": nv, "pos": pos + 1}


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Params, *, use_kernel: bool = False
            ) -> Tuple[jnp.ndarray, Params]:
    """Consume the whole (B, S) prompt in one batched pass, writing the SSM
    states, conv windows, and the per-group shared-attention KV slots.
    ``cache`` supplies the buffers and is overwritten (donation-safe).

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    h = params["embed"][tokens]
    b, s, _ = h.shape
    sp = params["shared_attn"]
    hd = cfg.resolved_head_dim
    conv_dtype = cache["conv"].dtype
    kv_dtype = cache["k"].dtype
    pos = jnp.arange(s)

    def inner(carry, lp):
        x = carry
        y, st, cw = mamba2.mamba_block_prefill(
            lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps),
            use_kernel=use_kernel, conv_dtype=conv_dtype)
        return x + y, (st, cw)

    def outer(carry, xs):
        x = carry
        gp, ck, cv = xs
        x, (st_g, cw_g) = lax.scan(inner, x, gp)
        xn = L.rmsnorm(sp["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(sp["attn"], xn, cfg.num_heads, cfg.num_kv_heads, hd)
        if cfg.rope_theta > 0:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        k = k.astype(kv_dtype)
        v = v.astype(kv_dtype)
        a = L._sdpa(q, k, v, L.causal_window_mask(s, s))
        x = x + a.reshape(b, s, cfg.num_heads * hd) @ sp["attn"]["wo"]
        x = x + L.swiglu(sp["mlp"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps))
        ck = lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
        cv = lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
        return act.shard_hidden(x), (st_g, cw_g, ck, cv)

    h, (ns, ncw, nk, nv) = lax.scan(
        outer, act.shard_hidden(h), (params["layers"], cache["k"], cache["v"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, -1, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw, "k": nk, "v": nv,
                    "pos": jnp.asarray(s, jnp.int32)}
