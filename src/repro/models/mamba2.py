"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Train/prefill use the chunked SSD algorithm: quadratic attention-like compute
inside fixed-size chunks + a linear inter-chunk state recurrence (lax.scan).
Decode is the O(1)-per-token recurrent update on the cached SSM state.

``kernels/ssd_scan.py`` provides the Pallas TPU kernel for the per-chunk
compute; this module is also its pure-jnp oracle entry point.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import act

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# SSD core (chunked) — pure jnp
# ---------------------------------------------------------------------------

def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over a sequence.

    x:  (b, l, h, p)   per-head inputs
    dt: (b, l, h)      positive step sizes (softplus already applied)
    A:  (h,)           negative decay rates
    B:  (b, l, n)      input projections (single group)
    C:  (b, l, n)      output projections (single group)
    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    orig_l = l
    if l % chunk:
        # pad with dt=0 steps: decay=1 and dx=0, so padding is a no-op on state
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        l = l + pad
    nc = l // chunk

    f32 = jnp.float32
    a = (dt.astype(f32) * A.astype(f32)).reshape(b, nc, chunk, h)        # log-decay
    dx = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, nc, chunk, h, p)
    Bc = B.astype(f32).reshape(b, nc, chunk, n)
    Cc = C.astype(f32).reshape(b, nc, chunk, n)

    a_cs = jnp.cumsum(a, axis=2)                                         # (b,nc,q,h)

    # --- intra-chunk (diagonal blocks): attention-like with decay mask
    # L[i, j] = exp(a_cs[i] - a_cs[j]) for i >= j else 0
    decay = jnp.exp(a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :])     # (b,nc,i,j,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                           # (b,nc,i,j)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, dx)

    # --- chunk summary states: S_c = sum_j exp(a_end - a_cs[j]) dx_j B_j^T
    decay_to_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)                    # (b,nc,q,h)
    S = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", decay_to_end, dx, Bc)

    # --- inter-chunk recurrence
    g = jnp.exp(a_cs[:, :, -1, :])                                       # (b,nc,h)
    h0 = jnp.zeros((b, h, p, n), f32) if init_state is None \
        else init_state.astype(f32)

    def step(hprev, xs):
        g_c, S_c = xs
        hnew = g_c[:, :, None, None] * hprev + S_c
        return hnew, hprev

    hT, h_prevs = lax.scan(step, h0, (g.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                           # (b,nc,h,p,n)

    # --- inter-chunk contribution: y_off[i] = exp(a_cs[i]) C_i . h_prev
    y_off = jnp.einsum("bcih,bcin,bchpn->bcihp",
                       jnp.exp(a_cs), Cc, h_prevs)

    y = (y_diag + y_off).reshape(b, l, h, p)[:, :orig_l]
    return y.astype(x.dtype), hT


def ssd_recurrent_step(state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
                       A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step.

    state: (b, h, p, n); x_t: (b, h, p); dt_t: (b, h); B_t/C_t: (b, n).
    Returns (y_t (b, h, p), new_state).
    """
    f32 = jnp.float32
    da = jnp.exp(dt_t.astype(f32) * A.astype(f32))                       # (b,h)
    dx = x_t.astype(f32) * dt_t.astype(f32)[..., None]                   # (b,h,p)
    upd = jnp.einsum("bhp,bn->bhpn", dx, B_t.astype(f32))
    new_state = da[:, :, None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(f32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# causal depthwise conv (width W, per-channel)
# ---------------------------------------------------------------------------

def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, L, C); w: (W, C); b: (C,).  Shift-and-sum (W is tiny)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def causal_conv_step(window: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray,
                     b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """window: (B, W-1, C) previous inputs; x_t: (B, 1, C).

    Returns (y_t (B, 1, C), new window)."""
    full = jnp.concatenate([window, x_t], axis=1)                        # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w) + b
    # keep the rolled window in the cache dtype: the concat above promotes to
    # the (fp32) activation dtype, which would change the decode-scan carry
    # type step-over-step and break jitted generation loops
    return y[:, None, :], full[:, 1:, :].astype(window.dtype)


# ---------------------------------------------------------------------------
# block init / forward
# ---------------------------------------------------------------------------

def init_mamba_block(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, di = cfg.d_model, cfg.ssm_d_inner
    n, h, W = cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv_width
    conv_dim = di + 2 * n
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln": L.rmsnorm_init(d, dtype),
        "in_proj": L.dense_init(k1, d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(k2, (W, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": L.rmsnorm_init(di, dtype),
        "out_proj": L.dense_init(k3, di, d, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xbc, dt


def mamba_block(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
                use_kernel: bool = False) -> jnp.ndarray:
    """Full-sequence mamba2 block (pre-norm residual applied by caller)."""
    b, l, _ = x.shape
    di, n, h, p = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    zxbcdt = x @ lp["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(causal_conv(xbc, lp["conv_w"], lp["conv_b"]))
    xs = xbc[..., :di].reshape(b, l, h, p)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    if use_kernel:
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xs, dt, A, B, C, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, B, C, chunk=cfg.ssm_chunk)
    y = y + lp["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(b, l, di)
    y = L.rmsnorm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ lp["out_proj"]


def mamba_block_prefill(lp: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                        use_kernel: bool = False, conv_dtype=jnp.bfloat16,
                        length: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence mamba2 block that also emits the decode-cache entries.

    Returns (y (B, L, D), final SSM state (B, H, P, N) fp32, conv window
    (B, W-1, conv_dim)).  The conv window holds the last W-1 *raw*
    (pre-activation) conv inputs, zero-padded on the left for short prompts —
    exactly the state :func:`causal_conv_step` would have accumulated.

    ``length`` (traced, scalar or (B,), paged serving): each prompt is
    right-padded to a fixed max bucket; positions >= its length get dt = 0,
    which makes them exact no-ops on the recurrent state (decay 1, zero
    input — the same trick the chunk padding uses), and the conv window is
    gathered to END at the row's length instead of the padded tail.  The
    returned y rows are only valid below their lengths.
    """
    b, l, _ = x.shape
    di, n, h, p = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    zxbcdt = x @ lp["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    if length is None:
        win = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))[:, l:, :].astype(conv_dtype)
    else:
        lens = jnp.broadcast_to(jnp.asarray(length), (b,))
        idx = lens[:, None] - (W - 1) + jnp.arange(W - 1)[None, :]  # (B, W-1)
        live = (idx >= 0)[:, :, None]
        win = (jnp.take_along_axis(xbc, jnp.clip(idx, 0, l - 1)[:, :, None],
                                   axis=1) * live).astype(conv_dtype)
    xbc = jax.nn.silu(causal_conv(xbc, lp["conv_w"], lp["conv_b"]))
    xs = xbc[..., :di].reshape(b, l, h, p)
    B = xbc[..., di:di + n]
    C = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    if length is not None:
        # dt = 0 AFTER softplus: pad steps decay by exp(0) = 1 and inject
        # dt * x = 0, so the state at the end equals the state at the row's
        # length
        dt = jnp.where(jnp.arange(l)[None, :, None] < lens[:, None, None],
                       dt, 0.0)
    A = -jnp.exp(lp["A_log"])
    if use_kernel:
        from repro.kernels import ops as kops
        y, state = kops.ssd(xs, dt, A, B, C, chunk=cfg.ssm_chunk)
    else:
        y, state = ssd_chunked(xs, dt, A, B, C, chunk=cfg.ssm_chunk)
    y = y + lp["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(b, l, di)
    y = L.rmsnorm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ lp["out_proj"], state, win


def mamba_block_step(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
                     state: jnp.ndarray, conv_win: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token mamba2 step.  x: (B, 1, D)."""
    b = x.shape[0]
    di, n, h, p = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    zxbcdt = x @ lp["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc_t, conv_win = causal_conv_step(conv_win, xbc, lp["conv_w"], lp["conv_b"])
    xbc_t = jax.nn.silu(xbc_t)
    xs = xbc_t[:, 0, :di].reshape(b, h, p)
    B = xbc_t[:, 0, di:di + n]
    C = xbc_t[:, 0, di + n:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])   # (b,h)
    A = -jnp.exp(lp["A_log"])
    y, state = ssd_recurrent_step(state, xs, dt, A, B, C)
    y = y + lp["D"].astype(y.dtype)[None, :, None] * xs
    y = y.reshape(b, 1, di)
    y = L.rmsnorm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ lp["out_proj"], state, conv_win


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_model(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 3)
    layer_rngs = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda r: init_mamba_block(r, cfg, dtype))(layer_rngs)
    return {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype),
    }


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            remat: bool = False, use_kernel: bool = False,
            last_only: bool = False) -> jnp.ndarray:
    h = params["embed"][tokens]

    def body(carry, lp):
        x = act.shard_hidden(carry)
        y = mamba_block(lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                        use_kernel=use_kernel)
        return act.shard_hidden(x + y), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, act.shard_hidden(h), params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    return act.shard_logits((h @ params["lm_head"]).astype(jnp.float32))


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """SSM decode cache: per-layer recurrent state + conv window.

    Constant-size in seq_len (the SSM advantage for long_500k)."""
    del seq_len
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    return {
        "state": jnp.zeros((cfg.num_layers, batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim),
                          dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Params) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token]

    def body(carry, xs):
        x = carry
        lp, st, cw = xs
        y, st, cw = mamba_block_step(lp, cfg, L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                                     st, cw)
        return x + y, (st, cw)

    h, (ns, ncw) = lax.scan(body, h, (params["layers"], cache["state"],
                                      cache["conv"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# paged cache API (continuous batching)
# ---------------------------------------------------------------------------
#
# The SSM decode state is CONSTANT-size per sequence (that is its whole point)
# so there is nothing to page: the "pool" is per-slot state + conv window, and
# the scheduler's block table is simply ignored by this family.  Admission
# overwrites the slot's state wholesale, which is also what makes slot reuse
# leak-free without an allocator.


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    del num_pages, page_size
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    # int8 pool dtype only quantizes *paged KV*; the SSM has none, and its
    # recurrent carry must stay full-precision, so the conv window falls back
    # to bf16 (the state is always fp32).
    if jnp.dtype(dtype) == jnp.int8:
        dtype = jnp.bfloat16
    return {
        "state": jnp.zeros((cfg.num_layers, num_slots, h, p, n), jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, num_slots, cfg.ssm_conv_width - 1,
                           conv_dim), dtype),
    }


def init_prefix_cache(cfg: ModelConfig, entries: int, dtype=jnp.bfloat16):
    """Device-side full-prompt snapshot rows: the recurrent state + conv
    window at the prompt boundary, keyed host-side by the prompt's chain
    hash.  The SSM state is constant-size, so one row restores the WHOLE
    prompt — the recurrent families' equivalent of aliasing every page."""
    h, p, n = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    if jnp.dtype(dtype) == jnp.int8:
        dtype = jnp.bfloat16
    return {
        "state": jnp.zeros((cfg.num_layers, entries, h, p, n), jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, entries, cfg.ssm_conv_width - 1,
                           conv_dim), dtype),
    }


def snapshot_save(cfg: ModelConfig, cache: Params, prefix: Params,
                  rows: jnp.ndarray, slots: jnp.ndarray) -> Params:
    """Snapshot admitted slots' post-prefill state into prefix rows.
    rows: (A,) snapshot rows (== entries sentinel drops); slots: (A,)."""
    return dict(prefix,
                state=prefix["state"].at[:, rows].set(
                    cache["state"][:, slots], mode="drop"),
                conv=prefix["conv"].at[:, rows].set(
                    cache["conv"][:, slots], mode="drop"))


def snapshot_restore(cfg: ModelConfig, cache: Params, prefix: Params,
                     rows: jnp.ndarray, slots: jnp.ndarray) -> Params:
    """Restore snapshot rows into decode slots (full-prompt prefix hit).
    slots: (A,) target slots (== num_slots sentinel drops)."""
    return dict(cache,
                state=cache["state"].at[:, slots].set(
                    prefix["state"][:, rows], mode="drop"),
                conv=cache["conv"].at[:, slots].set(
                    prefix["conv"][:, rows], mode="drop"))


def prefill_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  lengths: jnp.ndarray, slots: jnp.ndarray,
                  block_rows: jnp.ndarray, cache: Params, *,
                  use_kernel: bool = False,
                  start=None) -> Tuple[jnp.ndarray, Params]:
    """Prefill a batch of admitted requests into decode slots ``slots``.

    tokens: (A, S_max) right-padded; each row's positions >= lengths[i] are
    exact state no-ops (dt = 0) and its logits are read at lengths[i] - 1.
    Padded admission rows carry an out-of-range slot index and their state
    writes are dropped.  ``start`` is accepted for API uniformity but unused:
    the SSM families have no pages to share mid-prompt — their prefix reuse
    is the full-prompt snapshot/restore path (state scatter order: a restore
    following this prefill overwrites the slot, so a restored row may run
    here as a passive batch member)."""
    del block_rows, start
    conv_dtype = cache["conv"].dtype
    h = params["embed"][tokens]

    def body(carry, lp):
        x = act.shard_hidden(carry)
        y, st, cw = mamba_block_prefill(lp, cfg,
                                        L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                                        use_kernel=use_kernel,
                                        conv_dtype=conv_dtype, length=lengths)
        return act.shard_hidden(x + y), (st, cw)

    h, (ns, ncw) = lax.scan(body, h, params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    new_cache = {
        "state": cache["state"].at[:, slots].set(ns, mode="drop"),
        "conv": cache["conv"].at[:, slots].set(ncw, mode="drop"),
    }
    return logits, new_cache


def decode_step_paged(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                      pos: jnp.ndarray, block: jnp.ndarray, cache: Params, *,
                      use_kernel: bool = False,
                      write_block=None) -> Tuple[jnp.ndarray, Params]:
    """One decode step for all slots.  The recurrent update is position-free,
    so ``pos``/``block``/``write_block`` are unused — idle slots advance
    garbage state that admission overwrites."""
    del pos, block, use_kernel, write_block
    h = params["embed"][token]

    def body(carry, xs):
        x = carry
        lp, st, cw = xs
        y, st, cw = mamba_block_step(lp, cfg,
                                     L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                                     st, cw)
        return x + y, (st, cw)

    h, (ns, ncw) = lax.scan(body, h, (params["layers"], cache["state"],
                                      cache["conv"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw}


def forward_chunk_paged(params: Params, cfg: ModelConfig,
                        tokens: jnp.ndarray, pos: jnp.ndarray,
                        block: jnp.ndarray, cache: Params, *,
                        use_kernel: bool = False,
                        write_block=None) -> Tuple[jnp.ndarray, Params, dict]:
    """Chunked token lane for the recurrent family: the chunk is consumed by
    a ``lax.scan`` of per-token recurrent steps — bitwise identical to C
    sequential ``decode_step_paged`` calls — and every step's {state, conv}
    is emitted as a CHUNK-BOUNDARY SNAPSHOT (``staged``, leading axis C).
    ``staged[j]`` holds the state after consuming exactly ``j + 1`` chunk
    inputs, which is what lets the scheduler roll a slot back to ANY
    intra-chunk boundary (speculative rejection) or commit a partial final
    prefill chunk (``select_stage`` + ``restore_stage``).

    Returns (logits (B, C, V) fp32, cache with the FULL chunk absorbed,
    staged)."""
    del pos, block, use_kernel, write_block      # recurrence is position-free

    def step(carry, tok):
        cache = carry
        logits, cache = decode_step_paged(params, cfg, tok[:, None], None,
                                          None, cache)
        return cache, (logits, {"state": cache["state"],
                                "conv": cache["conv"]})

    cache, (logits, staged) = lax.scan(step, cache, tokens.T)
    return logits.transpose(1, 0, 2), cache, staged


def chunk_stage(cfg: ModelConfig, cache: Params) -> dict:
    """The rollback-able slice of the cache: per-slot recurrent state + conv
    window (attention families return {} — their state is positional)."""
    return {"state": cache["state"], "conv": cache["conv"]}


def restore_stage(cfg: ModelConfig, cache: Params, stage: dict,
                  mask: jnp.ndarray) -> Params:
    """Overwrite the recurrent state of slots where ``mask`` (B,) is True
    with ``stage``'s values (leaves shaped like the cache's: slot axis 1)."""
    return dict(cache,
                state=jnp.where(mask[None, :, None, None, None],
                                stage["state"], cache["state"]),
                conv=jnp.where(mask[None, :, None, None],
                               stage["conv"], cache["conv"]))


def select_stage(cfg: ModelConfig, staged: dict, keep: jnp.ndarray) -> dict:
    """Pick each slot's snapshot after exactly ``keep`` (B,) chunk inputs
    (keep >= 1; masked out by the caller otherwise): ``staged[keep - 1]``
    per slot, leaves (C, L, B, ...) -> (L, B, ...)."""
    idx = jnp.maximum(keep - 1, 0)

    def sel(a):
        i = idx.reshape((1, 1, -1) + (1,) * (a.ndim - 3))
        return jnp.take_along_axis(a, i, axis=0)[0]

    return {"state": sel(staged["state"]), "conv": sel(staged["conv"])}


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Params, *, use_kernel: bool = False
            ) -> Tuple[jnp.ndarray, Params]:
    """Consume the whole (B, S) prompt with the chunked SSD pass and write the
    per-layer recurrent state + conv window.  ``cache`` supplies shapes/dtypes
    and is fully overwritten (donation-safe).

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    s = tokens.shape[1]
    conv_dtype = cache["conv"].dtype
    h = params["embed"][tokens]

    def body(carry, lp):
        x = act.shard_hidden(carry)
        y, st, cw = mamba_block_prefill(lp, cfg,
                                        L.rmsnorm(lp["ln"], x, cfg.norm_eps),
                                        use_kernel=use_kernel,
                                        conv_dtype=conv_dtype)
        return act.shard_hidden(x + y), (st, cw)

    h, (ns, ncw) = lax.scan(body, h, params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, -1, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"state": ns, "conv": ncw, "pos": jnp.asarray(s, jnp.int32)}
