"""Shared neural-net building blocks (pure JAX, pytree params).

Conventions
-----------
* hidden states ``(B, S, D)``; attention heads ``(B, S, H, Dh)``.
* params are nested dicts of ``jnp.ndarray``; per-layer stacks add a leading
  ``L`` axis and are consumed by ``lax.scan`` (compile-time: one layer body).
* everything is differentiable and jit/pjit-safe (static shapes only).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(rng, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (Dh/2,)
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]   # (S, Dh/2)
        angles = angles[None, :, None, :]                                   # (1,S,1,Dh/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs           # (B,S,Dh/2)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(rng, d_model: int, num_heads: int, num_kv: int, head_dim: int,
                   *, bias: bool = False, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 4)
    p: Params = {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv * head_dim,), dtype)
    return p


def _qkv(params: Params, x: jnp.ndarray, num_heads: int, num_kv: int, head_dim: int):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (q.reshape(b, s, num_heads, head_dim),
            k.reshape(b, s, num_kv, head_dim),
            v.reshape(b, s, num_kv, head_dim))


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """q: (B,Sq,H,Dh)  k,v: (B,Sk,K,Dh)  GQA via head grouping.

    mask: broadcastable to (B, H, Sq, Sk), True = attend.
    """
    b, sq, h, dh = q.shape
    kheads = k.shape[2]
    groups = h // kheads
    # matmuls run in the cache dtype with fp32 accumulation
    # (preferred_element_type) — never materialise an upcast copy of the
    # K/V cache (for a 32k cache that copy would double decode HBM).
    qg = q.reshape(b, sq, kheads, groups, dh).astype(k.dtype)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    if mask is not None:
        if mask.ndim == 3:                    # (B|1, Sq, Sk)
            m = mask[:, None, None, :, :]
        else:                                  # (B|1, H, Sq, Sk)
            m = mask.reshape(mask.shape[0], kheads, groups, *mask.shape[-2:])
        scores = jnp.where(m, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def causal_window_mask(sq: int, sk: int, *, q_offset: int = 0,
                       window: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(1, Sq, Sk) boolean mask: causal, optionally sliding-window.

    ``window`` may be a traced scalar (enables gemma3's per-layer local/global
    switch inside a single scanned layer body without lax.cond).
    """
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask = mask & (qpos - kpos < window)
    return mask[None]


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_chunk: int, causal: bool,
                      window: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Query-chunked attention: bounds the score buffer to (B,H,qc,Sk).

    Each query block sees its complete key row, so plain (not online) softmax
    is exact.  Memory per block: B*H*qc*Sk fp32 instead of B*H*Sq*Sk.
    """
    b, sq, h, dh = q.shape
    if sq % q_chunk:
        raise ValueError(f"seq {sq} not divisible by q_chunk {q_chunk}")
    nblk = sq // q_chunk
    qb = q.reshape(b, nblk, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def body(carry, args):
        i, qi = args
        mask = None
        if causal:
            mask = causal_window_mask(q_chunk, k.shape[1],
                                      q_offset=i * q_chunk, window=window)
        out = _sdpa(qi, k, v, mask)
        return carry, out

    _, outs = lax.scan(body, None, (jnp.arange(nblk), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def attention_forward(params: Params, x: jnp.ndarray, *, num_heads: int,
                      num_kv: int, head_dim: int, rope_theta: float,
                      causal: bool = True,
                      window: Optional[jnp.ndarray] = None,
                      positions: Optional[jnp.ndarray] = None,
                      q_chunk: int = 512) -> jnp.ndarray:
    """Full-sequence self-attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, num_heads, num_kv, head_dim)
    if rope_theta > 0:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    if s > q_chunk and s % q_chunk == 0:
        out = chunked_attention(q, k, v, q_chunk=q_chunk, causal=causal,
                                window=window)
    else:
        mask = causal_window_mask(s, s, window=window) if causal else None
        out = _sdpa(q, k, v, mask)
    return out.reshape(b, s, num_heads * head_dim) @ params["wo"]


def _decode_attn_streamed(q: jnp.ndarray, cache_k: jnp.ndarray,
                          cache_v: jnp.ndarray, valid: jnp.ndarray,
                          block_s: int) -> jnp.ndarray:
    """Online-softmax decode attention streaming the cache in S blocks —
    the jnp mirror of kernels/decode_attention.py.  Bounds the working set
    to one (B, block_s, K, D) tile (the full-cache _sdpa path would force an
    upcast copy of the entire cache)."""
    b, _, h, d = q.shape
    s, kh = cache_k.shape[1], cache_k.shape[2]
    g = h // kh
    nblk = s // block_s
    qg = (q.reshape(b, 1, kh, g, d).astype(cache_k.dtype)
          / math.sqrt(d))

    def body(carry, i):
        m, l, acc = carry
        sl = i * block_s
        kb = lax.dynamic_slice_in_dim(cache_k, sl, block_s, axis=1)
        vb = lax.dynamic_slice_in_dim(cache_v, sl, block_s, axis=1)
        vm = lax.dynamic_slice_in_dim(valid, sl, block_s, axis=0)
        scores = jnp.einsum("bqkgd,bskd->bkgs", qg, kb,
                            preferred_element_type=jnp.float32)
        scores = jnp.where(vm[None, None, None, :], scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None]) \
            * vm[None, None, None, :].astype(jnp.float32)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgs,bskd->bkgd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kh, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kh, g), jnp.float32)
    a0 = jnp.zeros((b, kh, g, d), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nblk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, d).astype(q.dtype)


# stream the cache for long contexts; below this, one full-row _sdpa is fine
_DECODE_STREAM_THRESHOLD = 8192
_DECODE_BLOCK_S = 2048


def _no_mesh() -> bool:
    from repro.sharding import act
    return act.current_mesh() is None


def attention_decode(params: Params, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos: jnp.ndarray, *, num_heads: int,
                     num_kv: int, head_dim: int, rope_theta: float,
                     window: Optional[jnp.ndarray] = None,
                     use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  x: (B, 1, D); cache_k/v: (B, S, K, Dh); pos: scalar.

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    q, k, v = _qkv(params, x, num_heads, num_kv, head_dim)
    if rope_theta > 0:
        p1 = jnp.full((1,), pos, dtype=jnp.int32)
        q = apply_rope(q, p1, rope_theta)
        k = apply_rope(k, p1, rope_theta)
    cache_k = lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                       (0, pos, 0, 0))
    cache_v = lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                       (0, pos, 0, 0))
    s = cache_k.shape[1]
    kpos = jnp.arange(s)
    valid = kpos <= pos
    if window is not None:
        valid = valid & (pos - kpos < window)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, cache_k, cache_v, valid)
    elif s >= _DECODE_STREAM_THRESHOLD and s % _DECODE_BLOCK_S == 0 \
            and _no_mesh():
        # streaming bounds the working set for LOCAL serving; under a mesh
        # the cache is sequence-sharded and block-slicing it would all-gather
        # (measured +129 GB/step on llava decode) — GSPMD's partial-softmax
        # over the sharded S dim is the right plan there.
        out = _decode_attn_streamed(q, cache_k, cache_v, valid,
                                    _DECODE_BLOCK_S)
    else:
        mask = valid[None, None, :]            # (1, 1, S) -> broadcast (B,Sq,Sk)
        out = _sdpa(q, cache_k, cache_v, mask)
    out = out.reshape(b, 1, num_heads * head_dim) @ params["wo"]
    return out, cache_k, cache_v


def attention_decode_paged(params: Params, x: jnp.ndarray, pool_k: jnp.ndarray,
                           pool_v: jnp.ndarray, block: jnp.ndarray,
                           pos: jnp.ndarray, *, num_heads: int, num_kv: int,
                           head_dim: int, rope_theta: float,
                           window: Optional[jnp.ndarray] = None,
                           use_kernel: bool = False,
                           write_block: Optional[jnp.ndarray] = None,
                           scale_k: Optional[jnp.ndarray] = None,
                           scale_v: Optional[jnp.ndarray] = None):
    """One-token decode against a PAGED KV pool (one layer's slice of it).

    x: (B, 1, D); pool_k/v: (P, page, K, Dh) — ONE physical allocation shared
    by every slot; block: (B, n_pages) int32 block table mapping each slot's
    logical pages to physical ones (0 = the null/trash page); pos: (B,) int32
    per-slot positions (continuous batching: slots decode at DIFFERENT
    positions, unlike the contiguous cache's single scalar).

    The new token's K/V is scattered into page ``block[b, pos_b // page]`` at
    offset ``pos_b % page``; reads gather every slot's pages back through the
    table (or stream them inside the Pallas kernel when ``use_kernel``).
    Masking is positional (``kpos <= pos_b``) so stale page contents are never
    observable.

    ``write_block`` (defaults to ``block``): the table used for the APPEND
    only.  With prefix sharing, pages aliased by several slots are read-only
    — admission copy-on-writes any page a slot will append into, and the
    scheduler masks shared pages to the null page in ``write_block`` so a
    violated exclusivity invariant drops the write instead of corrupting a
    co-resident request's cache.

    ``scale_k/v`` (P, K) fp32 mark the pools int8-quantized (per-page-
    per-head symmetric scales): the append quantizes through a monotone
    running-max page scale (see :func:`quant_append_page`) and the gather
    dequantizes — fused into the Pallas kernel under ``use_kernel``.

    Returns (out (B,1,D), pool_k, pool_v) — plus (scale_k, scale_v) when
    quantized.
    """
    b = x.shape[0]
    page = pool_k.shape[1]
    n_pages = block.shape[1]
    s_tot = n_pages * page
    quant = scale_k is not None
    q, k, v = _qkv(params, x, num_heads, num_kv, head_dim)
    if rope_theta > 0:
        pq = pos[:, None]                    # (B, 1) absolute positions
        q = apply_rope(q, pq, rope_theta)
        k = apply_rope(k, pq, rope_theta)
    rows = jnp.arange(b)
    wb = block if write_block is None else write_block
    pg = wb[rows, pos // page]               # (B,) physical page of this token
    off = pos % page
    # duplicate (page 0) targets from idle slots race benignly: the null page
    # is never covered by any slot's positional mask
    if quant:
        pool_k, scale_k = quant_append_page(pool_k, scale_k, pg, off, k[:, 0])
        pool_v, scale_v = quant_append_page(pool_v, scale_v, pg, off, v[:, 0])
    else:
        pool_k = pool_k.at[pg, off].set(k[:, 0].astype(pool_k.dtype),
                                        mode="drop")
        pool_v = pool_v.at[pg, off].set(v[:, 0].astype(pool_v.dtype),
                                        mode="drop")
    kpos = jnp.arange(s_tot)[None, :]        # logical key positions per slot
    valid = kpos <= pos[:, None]
    if window is not None:
        valid = valid & (pos[:, None] - kpos < window)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.decode_attention_paged(q, pool_k, pool_v, block, valid,
                                          scale_k, scale_v)
    else:
        kk = dequant_gather(pool_k, scale_k, block, num_kv, head_dim)
        vv = dequant_gather(pool_v, scale_v, block, num_kv, head_dim)
        out = _sdpa(q, kk, vv, valid[:, None, :])
    out = out.reshape(b, 1, num_heads * head_dim) @ params["wo"]
    if quant:
        return out, pool_k, pool_v, scale_k, scale_v
    return out, pool_k, pool_v


def attention_chunk_paged(params: Params, x: jnp.ndarray, pool_k: jnp.ndarray,
                          pool_v: jnp.ndarray, block: jnp.ndarray,
                          pos: jnp.ndarray, *, num_heads: int, num_kv: int,
                          head_dim: int, rope_theta: float,
                          window: Optional[jnp.ndarray] = None,
                          use_kernel: bool = False,
                          write_block: Optional[jnp.ndarray] = None,
                          scale_k: Optional[jnp.ndarray] = None,
                          scale_v: Optional[jnp.ndarray] = None):
    """CHUNK attention against the paged KV pool: C tokens per slot at
    per-slot start positions — the multi-token generalisation of
    :func:`attention_decode_paged` that powers the unified chunked token lane
    (chunked prefill admission and the speculative verify pass).

    x: (B, C, D); pos: (B,) int32 — token i of slot b sits at absolute
    position ``pos[b] + i``.  All C tokens' K/V are scattered into the slot's
    pages FIRST (through ``write_block``, shared pages masked to the null
    page), then every query gathers the slot's whole page row with a mask
    ``kpos <= pos[b] + i`` — positional masking supplies the intra-chunk
    causal structure, so query i sees exactly the keys a sequential
    ``attention_decode_paged`` at position ``pos[b] + i`` would have seen
    (same K/V values: both paths round to the cache dtype before the read).
    Positions past the slot's page row write to the null page.

    ``scale_k/v`` (P, K) fp32 mark the pools int8-quantized; the chunk's
    appends quantize through a monotone running-max page scale (whole chunk
    committed at the final scale — one rounding, vs the per-token path's
    potential requant, which is why chunk-vs-steps equivalence is
    tolerance-based under int8).

    Returns (out (B, C, D'), pool_k, pool_v) — plus (scale_k, scale_v) when
    quantized."""
    b, c, _ = x.shape
    page = pool_k.shape[1]
    n_pages = block.shape[1]
    s_tot = n_pages * page
    quant = scale_k is not None
    q, k, v = _qkv(params, x, num_heads, num_kv, head_dim)
    positions = pos[:, None] + jnp.arange(c)[None, :]       # (B, C) absolute
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    wb = block if write_block is None else write_block
    logical = positions // page                              # (B, C)
    in_range = logical < n_pages
    rows = jnp.arange(b)[:, None]
    pg = jnp.where(in_range, wb[rows, jnp.minimum(logical, n_pages - 1)], 0)
    off = positions % page
    if quant:
        pool_k, scale_k = quant_append_page(pool_k, scale_k, pg, off, k)
        pool_v, scale_v = quant_append_page(pool_v, scale_v, pg, off, v)
    else:
        pool_k = pool_k.at[pg, off].set(k.astype(pool_k.dtype), mode="drop")
        pool_v = pool_v.at[pg, off].set(v.astype(pool_v.dtype), mode="drop")
    kpos = jnp.arange(s_tot)[None, None, :]
    valid = kpos <= positions[:, :, None]                    # (B, C, S_tot)
    if window is not None:
        valid = valid & (positions[:, :, None] - kpos < window)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.decode_attention_chunk_paged(q, pool_k, pool_v, block,
                                                valid, scale_k, scale_v)
    else:
        kk = dequant_gather(pool_k, scale_k, block, num_kv, head_dim)
        vv = dequant_gather(pool_v, scale_v, block, num_kv, head_dim)
        out = _sdpa(q, kk, vv, valid)
    out = out.reshape(b, c, num_heads * head_dim) @ params["wo"]
    if quant:
        return out, pool_k, pool_v, scale_k, scale_v
    return out, pool_k, pool_v


# ---------------------------------------------------------------------------
# int8 page quantization (per-page-per-head symmetric scales)
# ---------------------------------------------------------------------------
#
# An int8 pool (P, page, K, Dh) carries a (P, K) fp32 scale tensor: one
# symmetric scale per physical page per kv head, dequant = int8 * scale.
# Write contract:
#   * prefill overwrites whole pages -> scale rows use SET semantics
#     (``quant_scatter_prefill_pages``), so stale scales from recycled pages
#     never survive;
#   * decode/chunk appends grow a page token-by-token -> the page scale is a
#     MONOTONE running max (``quant_append_page``); growing it requantizes
#     the page's existing content to the new grid (ratio <= 1, one extra
#     rounding), and a token at page offset 0 resets the (recycled) scale
#     first since the page has no live content yet.
# Scale rows are indexed by physical page id exactly like pages, so COW /
# truncate / eviction move them with ``cow_copy_scales`` alongside
# ``cow_copy_pages`` and the refcount machinery never needs to know about
# quantization.

_QMAX = 127.0


def _safe_scale(s: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(s, 1e-30)


def quant_append_page(pool: jnp.ndarray, scale: jnp.ndarray, pg: jnp.ndarray,
                      off: jnp.ndarray, val: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Append token K/V into an int8 pool at (pg, off) under a monotone
    per-page-per-head running-max scale.

    pool: (P, page, K, Dh) int8; scale: (P, K) fp32; pg/off: (...,) int32
    (token -> physical page / in-page offset); val: (..., K, Dh).  Pages
    whose scale grows are requantized to the new grid (duplicate pg entries
    write identical bytes — ratio and gathered content agree — so chunked
    appends race benignly, same as the null-page discipline).
    """
    v32 = val.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(v32), axis=-1)                   # (..., K)
    # offset 0 == first write into a freshly allocated page: reset the
    # recycled page's stale scale so it cannot poison this page's precision
    # (off != 0 tokens harmlessly re-zero the never-dequant-read null page)
    scale = scale.at[jnp.where(off == 0, pg, 0)].set(0.0, mode="drop")
    old = scale[pg]                                           # (..., K)
    scale = scale.at[pg].max(absmax / _QMAX, mode="drop")
    new = scale[pg]
    ratio = jnp.clip(_safe_scale(old) / _safe_scale(new), 0.0, 1.0)
    repack = jnp.round(pool[pg].astype(jnp.float32)
                       * ratio[..., None, :, None])
    pool = pool.at[pg].set(repack.astype(pool.dtype), mode="drop")
    q = jnp.clip(jnp.round(v32 / _safe_scale(new)[..., None]), -_QMAX, _QMAX)
    pool = pool.at[pg, off].set(q.astype(pool.dtype), mode="drop")
    return pool, scale


def dequant_gather(pool: jnp.ndarray, scale: Optional[jnp.ndarray],
                   block: jnp.ndarray, num_kv: int, head_dim: int
                   ) -> jnp.ndarray:
    """Gather a batch's pages as (B, n_pages * page, K, Dh), dequantizing
    through the per-page scales when given (None = full-precision pool:
    bitwise the plain gather)."""
    b, npg = block.shape
    gathered = pool[block]                       # (B, npg, page, K, Dh)
    if scale is not None:
        gathered = gathered.astype(jnp.float32) \
            * scale[block][:, :, None, :, None]
    return gathered.reshape(b, npg * pool.shape[1], num_kv, head_dim)


def quant_dequant_pages(kv: jnp.ndarray, page: int) -> jnp.ndarray:
    """Fake-quantize full-sequence K/V through the int8 per-page-per-head
    grid: exactly the values a later paged read will dequantize to
    (``quant_scatter_prefill_pages`` recomputes the identical scales from the
    same raw values).  kv: (A, S, K, Dh) with S % page == 0."""
    a, s = kv.shape[:2]
    paged = kv.astype(jnp.float32).reshape(a, s // page, page, *kv.shape[2:])
    sc = jnp.max(jnp.abs(paged), axis=(2, 4), keepdims=True) / _QMAX
    q = jnp.clip(jnp.round(paged / _safe_scale(sc)), -_QMAX, _QMAX)
    return (q * sc).reshape(kv.shape).astype(kv.dtype)


def quant_scatter_prefill_pages(pool: jnp.ndarray, scale: jnp.ndarray,
                                seq_kv: jnp.ndarray, block_rows: jnp.ndarray
                                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized :func:`scatter_prefill_pages`: write whole prefill pages
    int8 with freshly computed per-page-per-head scales (SET semantics —
    prefill owns the page, recycled scales are overwritten).  seq_kv carries
    the RAW (pre-quantization) values; rows redirected to the null page
    (padding / shared-prefix suffixing) drop both page and scale writes
    there harmlessly."""
    page = pool.shape[1]
    a, s = seq_kv.shape[:2]
    paged = seq_kv.astype(jnp.float32).reshape(a, s // page, page,
                                               *seq_kv.shape[2:])
    rows = block_rows[:, : s // page]
    sc = jnp.max(jnp.abs(paged), axis=(2, 4)) / _QMAX         # (A, npg, K)
    q = jnp.clip(jnp.round(paged / _safe_scale(sc)[:, :, None, :, None]),
                 -_QMAX, _QMAX)
    pool = pool.at[rows].set(q.astype(pool.dtype), mode="drop")
    scale = scale.at[rows].set(sc, mode="drop")
    return pool, scale


def cow_copy_scales(scale: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray
                    ) -> jnp.ndarray:
    """Scale-row companion of :func:`cow_copy_pages`: scale (..., P, K) with
    the page axis at ndim-2 (vs ndim-4 for pools); same (src, dst) pairs,
    same null-page padding discipline."""
    axis = scale.ndim - 2
    idx = (slice(None),) * axis + (dst,)
    return scale.at[idx].set(jnp.take(scale, src, axis=axis))


def scatter_prefill_pages(pool: jnp.ndarray, seq_kv: jnp.ndarray,
                          block_rows: jnp.ndarray) -> jnp.ndarray:
    """Write a batch of sequences' prefill K (or V) into their pages.

    pool: (P, page, K, Dh); seq_kv: (A, S, K, Dh) with S % page == 0;
    block_rows: (A, n_pages) — only the first S // page entries of each row
    are written.  Rows belonging to masked/padded admissions point at the
    null page 0; their (raced, garbage) writes land there harmlessly.
    """
    page = pool.shape[1]
    a, s = seq_kv.shape[:2]
    paged = seq_kv.reshape(a, s // page, page, *seq_kv.shape[2:])
    return pool.at[block_rows[:, : s // page]].set(paged.astype(pool.dtype),
                                                   mode="drop")


def suffix_write_rows(block_rows: jnp.ndarray, start: jnp.ndarray,
                      n_pages: int, page: int) -> jnp.ndarray:
    """Mask a batch of block-table rows down to the UNCACHED suffix.

    Pages below ``start // page`` belong to the shared prefix (aliased,
    possibly referenced by other slots or the prefix index) — they are
    read-only, so their prefill re-writes are redirected to the null page.
    ``start`` is page-aligned for partial hits and == bucket for full
    restores (which write nothing).
    """
    page_idx = jnp.arange(n_pages)[None, :]
    return jnp.where(page_idx < (start // page)[:, None], 0,
                     block_rows[:, :n_pages])


def substitute_prefix_kv(pool: jnp.ndarray, inpass: jnp.ndarray,
                         block_rows: jnp.ndarray, start: jnp.ndarray,
                         scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Splice cached prefix K (or V) under the in-pass suffix values.

    pool: (P, page, Kh, Dh); inpass: (A, S, Kh, Dh); block_rows: (A, n_pages);
    start: (A,) first uncached position per row.  Positions < start read the
    slot's aliased pages (bitwise the values the row's own full prefill would
    have produced — the whole sharing-equivalence argument rests on this);
    positions >= start keep the in-pass values.  The result feeds the SAME
    attention as the non-sharing path, so suffix logits and suffix K/V are
    bitwise identical to a from-scratch prefill.

    ``scale`` (P, K) marks the pool int8: cached pages dequantize through
    their per-page scales (and ``inpass`` is expected fake-quantized through
    the same grid — see :func:`quant_dequant_pages`).
    """
    a, s = inpass.shape[:2]
    page = pool.shape[1]
    rows = block_rows[:, : s // page]
    cached = pool[rows]
    if scale is not None:
        cached = cached.astype(jnp.float32) * scale[rows][:, :, None, :, None]
    cached = cached.reshape(a, s, *inpass.shape[2:])
    pos = jnp.arange(s)[None, :, None, None]
    return jnp.where(pos < start[:, None, None, None],
                     cached.astype(inpass.dtype), inpass)


def cow_copy_pages(pool: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray
                   ) -> jnp.ndarray:
    """Copy-on-write page duplication: pool[..., dst_i, :] = pool[..., src_i, :].

    pool: (..., P, page, K, Dh) with the page axis at ndim-4; src/dst: (C,)
    int32 physical page ids, padded with (0, 0) pairs — the null page copied
    onto itself is a no-op by construction.  Runs at the top of the scheduler
    tick, BEFORE prefill and decode, so an appending slot always owns a
    private copy of a retained tail page.
    """
    axis = pool.ndim - 4
    idx = (slice(None),) * axis + (dst,)
    return pool.at[idx].set(jnp.take(pool, src, axis=axis))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu_init(rng, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype),
        "wg": dense_init(ks[1], d_model, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, d_model, dtype),
    }


def swiglu(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])) @ params["wo"]


def gelu_mlp_init(rng, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 2)
    return {"wi": dense_init(ks[0], d_model, d_ff, dtype),
            "wo": dense_init(ks[1], d_ff, d_model, dtype),
            "bi": jnp.zeros((d_ff,), dtype), "bo": jnp.zeros((d_model,), dtype)}


def gelu_mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ params["wi"] + params["bi"]) @ params["wo"] + params["bo"]
