"""Mixture-of-Experts decoder (deepseek-moe fine-grained, arctic dense-residual).

Dispatch is the capacity-bounded scatter idiom (the production MoE pattern on
TPU): tokens pick top-k experts, each expert owns a static ``capacity`` slot
buffer, overflow tokens are dropped (drop rate is reported by the metrics).
This is also *exactly* the mechanism HI's sample router reuses one level up —
see DESIGN.md §2.

Expert weights are sharded expert-parallel over the ``model`` mesh axis (and
their hidden dim over ``data`` for the very large configs).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import act

Params = Dict[str, Any]


def moe_capacity(num_tokens: int, num_experts: int, k: int,
                 factor: float = 1.25) -> int:
    """Static per-expert slot count."""
    return max(1, int(math.ceil(num_tokens * k / num_experts * factor)))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_experts(rng, num_experts: int, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(rng, num_experts)
    return jax.vmap(lambda r: L.swiglu_init(r, d_model, d_ff, dtype))(ks)


def _init_layer(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    p: Params = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "router": L.dense_init(k2, cfg.d_model, cfg.num_experts, jnp.float32),
        "experts": _init_experts(k3, cfg.num_experts, cfg.d_model,
                                 cfg.d_ff_expert, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.swiglu_init(
            k4, cfg.d_model, cfg.num_shared_experts * cfg.d_ff_expert, dtype)
    if cfg.moe_dense_residual:
        p["dense"] = L.swiglu_init(k5, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_decoder(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 3)
    layer_rngs = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda r: _init_layer(r, cfg, dtype))(layer_rngs)
    return {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype),
    }


# ---------------------------------------------------------------------------
# routed FFN
# ---------------------------------------------------------------------------

def moe_ffn(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
            capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).

    Scatter-based dispatch: (E, C, D) expert buffers; no (T, E, C) one-hot
    tensor is ever materialised (it would not fit for 1M-token batches).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = moe_capacity(t, e, k, capacity_factor)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ lp["router"])              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, k)                                # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)   # renorm

    # Per-choice dispatch loop (k is small and static): avoids both the
    # (T*k, E) one-hot and the repeated-token (T*k, D) buffer.  Slot order is
    # an arbitrary bijection, which is fine — only the drop *policy* differs.
    buf = jnp.zeros((e, cap, d), x.dtype)
    base = jnp.zeros((e,), jnp.int32)                              # slots used
    poss, keeps = [], []
    for j in range(k):
        oh = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)         # (T, E)
        pos_j = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                                    idx[:, j:j + 1], axis=1)[:, 0]
        pos_j = pos_j + base[idx[:, j]]
        keep_j = pos_j < cap
        pos_cj = jnp.minimum(pos_j, cap - 1)
        src = xf * keep_j[:, None].astype(x.dtype)
        buf = buf.at[idx[:, j], pos_cj].add(src)
        base = base + oh.sum(axis=0)
        poss.append(pos_cj)
        keeps.append(keep_j)
    buf = act.shard_experts(buf)   # expert-parallel over `model`

    # expert compute, batched over E
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, lp["experts"]["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, lp["experts"]["wi"])
    out = jnp.einsum("ecf,efd->ecd", h, lp["experts"]["wo"])       # (E, C, D)

    # combine
    y = jnp.zeros((t, d), x.dtype)
    for j in range(k):
        w_j = (gate[:, j] * keeps[j]).astype(x.dtype)
        y = y + out[idx[:, j], poss[j]] * w_j[:, None]
    y = y.reshape(b, s, d)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y, aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (the production dispatch)
# ---------------------------------------------------------------------------
#
# The pjit/scatter dispatch above computes slot positions with a cumsum over
# the GLOBAL token dim; under GSPMD that becomes cross-shard prefix sums plus
# a global scatter/gather into the expert buffers — measured at ~2.5 TB/chip
# of all-reduce per train step on deepseek-moe-16b (EXPERIMENTS.md §Perf).
# The shard_map version keeps routing completely shard-local: tokens stay on
# their `data` shard (replicated across `model`), every (data, model) device
# dispatches its local tokens to its local experts, and one bf16 psum over
# `model` combines the expert partial outputs.  Comms per layer = exactly one
# (T_local, D) psum.

def moe_ffn_sharded(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
                    capacity_factor: float, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    n_model = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    e_local = e // n_model
    t_local = (b // n_data) * s
    cap = moe_capacity(t_local, e, k, capacity_factor)

    # --- schedule choice: move the SMALLER operand across `data` ----------
    # weights-move: all-gather the (E_l, D, F) expert weights per call
    # tokens-move:  all-gather the (E_l, cap, D) dispatch buffers, compute
    #               against F-sharded weights, reduce-scatter the outputs
    f = cfg.d_ff_expert
    weights_bytes = 3 * e_local * d * f * 2
    tokens_bytes = 2 * e_local * cap * n_data * d * 2
    tokens_move = tokens_bytes < weights_bytes

    def local(x_blk, router_w, wi, wg, wo):
        bl, sl, _ = x_blk.shape
        xf = x_blk.reshape(bl * sl, d)
        m_idx = jax.lax.axis_index("model")
        logits = xf.astype(jnp.float32) @ router_w                # (T_l, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        lo = m_idx * e_local
        buf = jnp.zeros((e_local, cap, d), x_blk.dtype)
        base = jnp.zeros((e_local,), jnp.int32)
        poss, keeps, locals_ = [], [], []
        for j in range(k):
            eid = idx[:, j]
            is_local = (eid >= lo) & (eid < lo + e_local)
            lid = jnp.where(is_local, eid - lo, 0)
            oh = (jax.nn.one_hot(lid, e_local, dtype=jnp.int32)
                  * is_local[:, None])
            pos_j = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                                        lid[:, None], axis=1)[:, 0]
            pos_j = pos_j + base[lid]
            keep_j = is_local & (pos_j < cap) & (pos_j >= 0)
            pos_cj = jnp.clip(pos_j, 0, cap - 1)
            buf = buf.at[lid, pos_cj].add(
                xf * keep_j[:, None].astype(x_blk.dtype))
            base = base + oh.sum(axis=0)
            poss.append(pos_cj); keeps.append(keep_j); locals_.append(lid)

        if tokens_move:
            # weights stay F-sharded over `data`; the (small) token buffers
            # travel: AG tokens -> local matmuls -> RS partial outputs
            buf_all = lax.all_gather(buf, data_axes, axis=1, tiled=True)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf_all, wg)) * \
                jnp.einsum("ecd,edf->ecf", buf_all, wi)
            out_part = jnp.einsum("ecf,efd->ecd", h, wo)   # partial over F
            out = lax.psum_scatter(out_part, data_axes, scatter_dimension=1,
                                   tiled=True)             # (E_l, cap, D)
        else:
            # small experts: gather full-F weights, tokens stay put
            wi_f = lax.all_gather(wi, data_axes, axis=2, tiled=True)
            wg_f = lax.all_gather(wg, data_axes, axis=2, tiled=True)
            wo_f = lax.all_gather(wo, data_axes, axis=1, tiled=True)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg_f)) * \
                jnp.einsum("ecd,edf->ecf", buf, wi_f)
            out = jnp.einsum("ecf,efd->ecd", h, wo_f)

        y = jnp.zeros((bl * sl, d), x_blk.dtype)
        for j in range(k):
            w_j = (gate[:, j] * keeps[j]).astype(x_blk.dtype)
            y = y + out[locals_[j], poss[j]] * w_j[:, None]
        y = lax.psum(y, "model")                       # combine expert parts

        frac_tokens = jnp.mean(jax.nn.one_hot(idx[:, 0], e,
                                              dtype=jnp.float32), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(frac_tokens * frac_probs)
        aux = lax.pmean(aux, data_axes)   # identical across `model` already
        return y.reshape(bl, sl, d), aux

    d_ax = data_axes if data_axes else None
    wi_spec = P("model", None, d_ax)          # F-sharded storage (both paths)
    wo_spec = P("model", d_ax, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(data_axes, None, None), P(None, None),
                  wi_spec, wi_spec, wo_spec),
        out_specs=(P(data_axes, None, None), P()),
        check_rep=False)
    return fn(x, lp["router"], lp["experts"]["wi"], lp["experts"]["wg"],
              lp["experts"]["wo"])


def moe_ffn_auto(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
                 capacity_factor: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pick the shard_map expert-parallel dispatch when running under a mesh
    whose `model` axis divides the expert count; else the local scatter."""
    from repro.sharding import act
    mesh = act.current_mesh()
    if (mesh is not None and "model" in mesh.shape
            and cfg.num_experts % mesh.shape["model"] == 0
            and x.shape[0] % max(mesh.shape.get("data", 1), 1) == 0):
        return moe_ffn_sharded(lp, cfg, x, capacity_factor, mesh)
    return moe_ffn(lp, cfg, x, capacity_factor)


def _block(lp: Params, cfg: ModelConfig, x: jnp.ndarray, win,
           capacity_factor: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = act.shard_hidden(x)
    a = L.attention_forward(lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                            num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                            head_dim=cfg.resolved_head_dim,
                            rope_theta=cfg.rope_theta, window=win)
    x = x + a
    xn = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    y, aux = moe_ffn_auto(lp, cfg, xn, capacity_factor)
    if "shared" in lp:
        y = y + L.swiglu(lp["shared"], xn)
    if "dense" in lp:
        y = y + L.swiglu(lp["dense"], xn)
    return act.shard_hidden(x + y), aux


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            remat: bool = False, capacity_factor: float = 1.25,
            last_only: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S, V) fp32, mean aux loss)."""
    h = params["embed"][tokens]
    seq = h.shape[1]
    win = jnp.asarray(seq, jnp.int32)

    def body(carry, lp):
        x, _ = carry, None
        x, aux = _block(lp, cfg, x, win, capacity_factor)
        return x, aux

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, auxs = lax.scan(body, act.shard_hidden(h), params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    logits = act.shard_logits((h @ params["lm_head"]).astype(jnp.float32))
    return logits, jnp.mean(auxs)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    from repro.models import transformer
    return transformer.init_cache(cfg, batch, seq_len, dtype)


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Params, *, capacity_factor: float = 2.0,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token]
    pos = cache["pos"]
    seq = cache["k"].shape[2]
    win = jnp.asarray(seq, jnp.int32)

    def body(carry, xs):
        x = carry
        lp, ck, cv = xs
        a, ck, cv = L.attention_decode(lp["attn"],
                                       L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                                       ck, cv, pos,
                                       num_heads=cfg.num_heads,
                                       num_kv=cfg.num_kv_heads,
                                       head_dim=cfg.resolved_head_dim,
                                       rope_theta=cfg.rope_theta, window=win,
                                       use_kernel=use_kernel)
        x = x + a
        xn = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, _ = moe_ffn_auto(lp, cfg, xn, capacity_factor)
        if "shared" in lp:
            y = y + L.swiglu(lp["shared"], xn)
        if "dense" in lp:
            y = y + L.swiglu(lp["dense"], xn)
        return x + y, (ck, cv)

    h, (nk, nv) = lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"k": nk, "v": nv, "pos": pos + 1}


def _prefill_body(cfg: ModelConfig, s: int, b: int, kv_dtype,
                  capacity_factor: float, block_rows=None, start=None,
                  page: int = 0, quant: bool = False):
    """The per-layer prefill scan body shared by :func:`prefill` (contiguous
    cache) and :func:`prefill_paged` (page pool).  Emits (k, v) per layer for
    the caller to store.

    With ``block_rows``/``start`` (prefix sharing) the scan also carries the
    layer's page pool and splices cached-prefix K/V under the in-pass values
    (see ``layers.substitute_prefix_kv``); routed-expert dispatch still runs
    over every position — the batch composition, and therefore the capacity
    drops, stay identical to the non-sharing pass."""
    hd = cfg.resolved_head_dim
    win = jnp.asarray(s, jnp.int32)
    pos = jnp.arange(s)
    mask = L.causal_window_mask(s, s, window=win)
    prefix = start is not None

    def body(carry, xs):
        if prefix and quant:
            lp, pk, pv, sk, sv = xs
        elif prefix:
            lp, pk, pv = xs
        else:
            lp = xs
        x = act.shard_hidden(carry)
        xq = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(lp["attn"], xq, cfg.num_heads, cfg.num_kv_heads, hd)
        if cfg.rope_theta > 0:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        if quant:
            # in-pass attention sees the fake-quantized values later paged
            # reads dequantize to; the RAW values are emitted for the
            # caller's quantize-on-write (see transformer._prefill_body)
            k_raw, v_raw = k, v
            k = L.quant_dequant_pages(k, page)
            v = L.quant_dequant_pages(v, page)
            if prefix:
                k = L.substitute_prefix_kv(pk, k, block_rows, start, sk)
                v = L.substitute_prefix_kv(pv, v, block_rows, start, sv)
        else:
            k = k.astype(kv_dtype)
            v = v.astype(kv_dtype)
            if prefix:
                k = L.substitute_prefix_kv(pk, k, block_rows, start)
                v = L.substitute_prefix_kv(pv, v, block_rows, start)
        a = L._sdpa(q, k, v, mask)
        x = x + a.reshape(b, s, cfg.num_heads * hd) @ lp["attn"]["wo"]
        xn = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, _ = moe_ffn_auto(lp, cfg, xn, capacity_factor)
        if "shared" in lp:
            y = y + L.swiglu(lp["shared"], xn)
        if "dense" in lp:
            y = y + L.swiglu(lp["dense"], xn)
        return act.shard_hidden(x + y), ((k_raw, v_raw) if quant else (k, v))

    return body


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    from repro.models import transformer
    return transformer.init_paged_cache(cfg, num_slots, num_pages, page_size,
                                        dtype)


def prefill_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  lengths: jnp.ndarray, slots: jnp.ndarray,
                  block_rows: jnp.ndarray, cache: Params, *,
                  capacity_factor: float = 2.0,
                  start=None) -> Tuple[jnp.ndarray, Params]:
    """Paged batched admission prefill (see transformer.prefill_paged).

    Routed dispatch runs over all padded (A, S_max) token rows together; the
    padded tails do consume expert capacity, so keep ``capacity_factor``
    generous (the decode-path default) — drops on the tails cannot corrupt
    real positions, but drops caused BY the tails could.

    ``start`` (prefix sharing): cached positions read their K/V from the
    aliased pages and skip the page writes; NOTE the routed dispatch remains
    batch-coupled, so unlike the dense families a page's content can depend
    on which rows were co-admitted when it was first filled (capacity drops)
    — reuse is exact only up to routing-drop determinism.
    """
    del slots
    h = params["embed"][tokens]
    b, s, _ = h.shape
    page = cache["kp"].shape[2]
    npg = s // page
    quant = "ks" in cache
    if start is None:
        body = _prefill_body(cfg, s, b, cache["kp"].dtype, capacity_factor,
                             page=page, quant=quant)
        h, (ks, vs) = lax.scan(body, h, params["layers"])
        wrows = block_rows[:, :npg]
    else:
        body = _prefill_body(cfg, s, b, cache["kp"].dtype, capacity_factor,
                             block_rows, start, page=page, quant=quant)
        xs = (params["layers"], cache["kp"], cache["vp"])
        if quant:
            xs = xs + (cache["ks"], cache["vs"])
        h, (ks, vs) = lax.scan(body, h, xs)
        wrows = L.suffix_write_rows(block_rows, start, npg, page)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    if quant:
        new_k, new_sk = jax.vmap(
            lambda p, sc, kv: L.quant_scatter_prefill_pages(p, sc, kv, wrows)
        )(cache["kp"], cache["ks"], ks)
        new_v, new_sv = jax.vmap(
            lambda p, sc, kv: L.quant_scatter_prefill_pages(p, sc, kv, wrows)
        )(cache["vp"], cache["vs"], vs)
        return logits, {"kp": new_k, "vp": new_v, "ks": new_sk, "vs": new_sv}
    shape = ks.shape[:1] + (b, npg, page) + ks.shape[3:]
    new_k = cache["kp"].at[:, wrows].set(ks.reshape(shape), mode="drop")
    new_v = cache["vp"].at[:, wrows].set(vs.reshape(shape), mode="drop")
    return logits, {"kp": new_k, "vp": new_v}


def decode_step_paged(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                      pos: jnp.ndarray, block: jnp.ndarray, cache: Params, *,
                      capacity_factor: float = 2.0, use_kernel: bool = False,
                      write_block=None) -> Tuple[jnp.ndarray, Params]:
    """One decode step for all slots at per-slot positions (paged pool)."""
    h = params["embed"][token]
    page = cache["kp"].shape[2]
    s_tot = block.shape[1] * page
    win = jnp.asarray(s_tot, jnp.int32)
    quant = "ks" in cache

    def body(carry, xs):
        x = carry
        if quant:
            lp, pk, pv, sk, sv = xs
            a, pk, pv, sk, sv = L.attention_decode_paged(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=win, use_kernel=use_kernel, write_block=write_block,
                scale_k=sk, scale_v=sv)
        else:
            lp, pk, pv = xs
            a, pk, pv = L.attention_decode_paged(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=win, use_kernel=use_kernel, write_block=write_block)
        x = x + a
        xn = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, _ = moe_ffn_auto(lp, cfg, xn, capacity_factor)
        if "shared" in lp:
            y = y + L.swiglu(lp["shared"], xn)
        if "dense" in lp:
            y = y + L.swiglu(lp["dense"], xn)
        return x + y, ((pk, pv, sk, sv) if quant else (pk, pv))

    if quant:
        h, (nk, nv, nsk, nsv) = lax.scan(
            body, h, (params["layers"], cache["kp"], cache["vp"],
                      cache["ks"], cache["vs"]))
    else:
        h, (nk, nv) = lax.scan(body, h, (params["layers"], cache["kp"],
                                         cache["vp"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    if quant:
        return logits, {"kp": nk, "vp": nv, "ks": nsk, "vs": nsv}
    return logits, {"kp": nk, "vp": nv}


def forward_chunk_paged(params: Params, cfg: ModelConfig,
                        tokens: jnp.ndarray, pos: jnp.ndarray,
                        block: jnp.ndarray, cache: Params, *,
                        capacity_factor: float = 2.0,
                        use_kernel: bool = False,
                        write_block=None) -> Tuple[jnp.ndarray, Params, dict]:
    """Chunked token lane for the MoE family: the SAME spliced attention +
    page writes as the dense chunk pass, with the routed FFN dispatched over
    all (B, C) chunk tokens together.  Routed dispatch stays batch-coupled
    (as in ``prefill_paged``) — keep ``capacity_factor`` at the generous
    decode-path default so chunk co-residency cannot introduce drops.
    Returns (logits (B, C, V) fp32, cache, staged — empty, attention state
    is positional)."""
    h = params["embed"][tokens]
    page = cache["kp"].shape[2]
    s_tot = block.shape[1] * page
    win = jnp.asarray(s_tot, jnp.int32)
    quant = "ks" in cache

    def body(carry, xs):
        x = carry
        if quant:
            lp, pk, pv, sk, sv = xs
            a, pk, pv, sk, sv = L.attention_chunk_paged(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=win, use_kernel=use_kernel, write_block=write_block,
                scale_k=sk, scale_v=sv)
        else:
            lp, pk, pv = xs
            a, pk, pv = L.attention_chunk_paged(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=win, use_kernel=use_kernel, write_block=write_block)
        x = x + a
        xn = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        y, _ = moe_ffn_auto(lp, cfg, xn, capacity_factor)
        if "shared" in lp:
            y = y + L.swiglu(lp["shared"], xn)
        if "dense" in lp:
            y = y + L.swiglu(lp["dense"], xn)
        return x + y, ((pk, pv, sk, sv) if quant else (pk, pv))

    if quant:
        h, (nk, nv, nsk, nsv) = lax.scan(
            body, h, (params["layers"], cache["kp"], cache["vp"],
                      cache["ks"], cache["vs"]))
    else:
        h, (nk, nv) = lax.scan(body, h, (params["layers"], cache["kp"],
                                         cache["vp"]))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    if quant:
        return logits, {"kp": nk, "vp": nv, "ks": nsk, "vs": nsv}, {}
    return logits, {"kp": nk, "vp": nv}, {}


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Params, *, capacity_factor: float = 2.0
            ) -> Tuple[jnp.ndarray, Params]:
    """Consume the whole (B, S) prompt in one batched pass and write the KV
    cache.  ``capacity_factor`` defaults to the decode-path value so routed
    dispatch behaves like generation, not training.  ``cache`` supplies the
    buffers and is overwritten (donation-safe).

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    h = params["embed"][tokens]
    b, s, _ = h.shape
    body = _prefill_body(cfg, s, b, cache["k"].dtype, capacity_factor)
    h, (ks, vs) = lax.scan(body, h, params["layers"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, -1, :] @ params["lm_head"]).astype(jnp.float32)
    new_k = lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0))
    new_v = lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0))
    return logits, {"k": new_k, "v": new_v, "pos": jnp.asarray(s, jnp.int32)}
