"""CNN classifiers for the paper's three use cases (§3–§5).

* ``SML_CIFAR``  — the paper's 5-layer tinyML CNN: conv → maxpool → flatten →
  dense → dense (§4, 0.45 MB TFLite, 62.58% on CIFAR-10).
* ``LML_CIFAR``  — the EfficientNet stand-in L-ML (deeper conv stack; the
  paper uses EfficientNet at 95%).
* ``FAULT_CNN``  — the 8-layer CNN of [38] for CWRU fault diagnosis (§3),
  consuming 64x64 grey images built from 4096-sample vibration windows.
* ``SML_BINARY`` — the dog/not-dog relevance filter (§5, 0.23 MB, sigmoid).

All are pure-JAX (lax.conv_general_dilated, NHWC) with pytree params.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]


@dataclass(frozen=True)
class CNNConfig:
    name: str
    in_shape: Tuple[int, int, int]          # (H, W, C)
    conv_channels: Sequence[int]            # one conv per entry
    pool_every: int                          # maxpool 2x2 after every k convs
    dense_sizes: Sequence[int]               # hidden dense layers
    num_classes: int                          # 1 => binary sigmoid head
    global_pool: bool = False                 # global max-pool before dense
                                              # (translation-invariant head —
                                              # what EfficientNet-class models
                                              # have and the tinyML S-ML lacks)


SML_CIFAR = CNNConfig("sml-cifar", (32, 32, 3), (32,), 1, (64,), 10)
LML_CIFAR = CNNConfig("lml-cifar", (32, 32, 3), (32, 64, 64, 128, 128), 2,
                      (256,), 10, global_pool=True)
FAULT_CNN = CNNConfig("fault-cnn", (64, 64, 1), (16, 32, 32, 64, 64, 64), 2,
                      (128,), 10, global_pool=True)
SML_BINARY = CNNConfig("sml-binary", (32, 32, 3), (32,), 1, (32,), 1)


def init_cnn(rng, cfg: CNNConfig, dtype=jnp.float32) -> Params:
    params: Params = {"convs": [], "dense": []}
    keys = jax.random.split(rng, len(cfg.conv_channels) + len(cfg.dense_sizes) + 1)
    c_in = cfg.in_shape[2]
    h, w = cfg.in_shape[:2]
    ki = 0
    for i, c_out in enumerate(cfg.conv_channels):
        scale = 1.0 / math.sqrt(3 * 3 * c_in)
        params["convs"].append({
            "w": (jax.random.normal(keys[ki], (3, 3, c_in, c_out)) * scale
                  ).astype(dtype),
            "b": jnp.zeros((c_out,), dtype),
        })
        ki += 1
        c_in = c_out
        if (i + 1) % cfg.pool_every == 0:
            h, w = h // 2, w // 2
    flat = c_in if cfg.global_pool else h * w * c_in
    d_in = flat
    for d_out in cfg.dense_sizes:
        scale = 1.0 / math.sqrt(d_in)
        params["dense"].append({
            "w": (jax.random.normal(keys[ki], (d_in, d_out)) * scale).astype(dtype),
            "b": jnp.zeros((d_out,), dtype),
        })
        ki += 1
        d_in = d_out
    scale = 1.0 / math.sqrt(d_in)
    params["head"] = {
        "w": (jax.random.normal(keys[ki], (d_in, cfg.num_classes)) * scale
              ).astype(dtype),
        "b": jnp.zeros((cfg.num_classes,), dtype),
    }
    return params


def apply_cnn(params: Params, cfg: CNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, W, C) -> logits (B, num_classes) fp32."""
    for i, cp in enumerate(params["convs"]):
        x = lax.conv_general_dilated(
            x, cp["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + cp["b"])
        if (i + 1) % cfg.pool_every == 0:
            x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    if cfg.global_pool:
        x = x.max(axis=(1, 2))
    x = x.reshape(x.shape[0], -1)
    for dp in params["dense"]:
        x = jax.nn.relu(x @ dp["w"] + dp["b"])
    return (x @ params["head"]["w"] + params["head"]["b"]).astype(jnp.float32)


def num_params(params: Params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))


def model_size_mb(params: Params, bytes_per_param: int = 1) -> float:
    """Size if quantised to int8 (the paper's TFLite models are quantised)."""
    return num_params(params) * bytes_per_param / 1e6
