"""Dense decoder-only transformer (granite / gemma3 / qwen2 / danube / llava LM).

Scanned over layers: the HLO contains exactly one layer body regardless of
depth, which keeps 512-device dry-run compiles tractable on one CPU core.

Per-layer heterogeneity (gemma3's 5:1 local:global attention) is expressed as
a scanned ``window`` vector — the sliding-window size enters the mask as data,
so a single uniform body covers both layer kinds with no ``lax.cond``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import act

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_decoder(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 3)
    layer_rngs = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda r: _init_layer(r, cfg, dtype))(layer_rngs)
    return {
        "embed": L.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype),
    }


def layer_windows(cfg: ModelConfig, seq_len: int) -> jnp.ndarray:
    """Per-layer sliding-window sizes; ``seq_len`` means full causal."""
    full = jnp.full((cfg.num_layers,), seq_len, dtype=jnp.int32)
    if cfg.local_global_ratio:
        idx = jnp.arange(cfg.num_layers)
        is_global = (idx % (cfg.local_global_ratio + 1)) == cfg.local_global_ratio
        return jnp.where(is_global, seq_len, cfg.sliding_window).astype(jnp.int32)
    if cfg.sliding_window:
        return jnp.full((cfg.num_layers,), cfg.sliding_window, dtype=jnp.int32)
    return full


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_hidden(params: Params, cfg: ModelConfig, h: jnp.ndarray, *,
                   remat: bool = False,
                   positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Run the scanned layer stack over already-embedded hidden states."""
    seq = h.shape[1]
    windows = layer_windows(cfg, seq)

    def body(carry, xs):
        lp, win = xs
        x = act.shard_hidden(carry)
        a = L.attention_forward(lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim,
                                rope_theta=cfg.rope_theta, window=win,
                                positions=positions)
        x = x + a
        m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return act.shard_hidden(x + m), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, act.shard_hidden(h), (params["layers"], windows))
    return h


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            remat: bool = False, last_only: bool = False,
            patch_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens: (B, S) -> logits (B, S_total, V).

    For VLM configs, ``patch_embeds`` (B, P, D) is prepended to the token
    embeddings (the stubbed vision tower's output).  ``last_only`` slices the
    final position *before* the vocab projection (prefill serving path).
    """
    h = params["embed"][tokens]
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
    h = forward_hidden(params, cfg, h, remat=remat)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    return act.shard_logits((h @ params["lm_head"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Params:
    shape = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct version of :func:`init_cache` (dry-run, no alloc)."""
    shape = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Params, *, use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, Params]:
    """token: (B, 1) -> (logits (B, V), updated cache)."""
    h = params["embed"][token]
    pos = cache["pos"]
    seq = cache["k"].shape[2]
    windows = layer_windows(cfg, seq)

    def body(carry, xs):
        x = carry
        lp, ck, cv, win = xs
        a, ck, cv = L.attention_decode(lp["attn"],
                                       L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                                       ck, cv, pos,
                                       num_heads=cfg.num_heads,
                                       num_kv=cfg.num_kv_heads,
                                       head_dim=cfg.resolved_head_dim,
                                       rope_theta=cfg.rope_theta, window=win,
                                       use_kernel=use_kernel)
        x = x + a
        m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + m, (ck, cv)

    h, (new_k, new_v) = lax.scan(body, h,
                                 (params["layers"], cache["k"], cache["v"], windows))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    new_cache = {"k": new_k, "v": new_v, "pos": pos + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# split-cache decode: ring buffers for sliding-window layers
# ---------------------------------------------------------------------------
#
# The uniform cache allocates full seq_len for every layer, but a local
# (sliding-window) layer only ever reads the last W positions.  For gemma3's
# 5:1 pattern at 500k that wastes ~80% of cache HBM and — worse for the
# memory-bound decode roofline — reads it all back every step.  The split
# cache keeps a (n_local, B, W, K, Dh) ring for local layers and full
# (n_global, B, S, K, Dh) buffers only for the global ones.
#
# Ring semantics: position p lives in slot p % W.  Slot s therefore holds
# p_s = pos - ((pos - s) mod W); it is valid iff p_s >= 0 (RoPE is applied at
# absolute positions before the write, so reads need no rotation fix-up).

def _ring_positions(pos: jnp.ndarray, w: int) -> jnp.ndarray:
    s = jnp.arange(w)
    return pos - jnp.mod(pos - s, w)


def num_local_layers(cfg: ModelConfig) -> int:
    """Static count of sliding-window layers (python ints, eval_shape-safe)."""
    if cfg.local_global_ratio:
        period = cfg.local_global_ratio + 1
        n_global = cfg.num_layers // period
        return cfg.num_layers - n_global
    return cfg.num_layers if cfg.sliding_window else 0


def init_split_cache(cfg: ModelConfig, batch: int, seq_len: int,
                     dtype=jnp.bfloat16) -> Params:
    n_local = num_local_layers(cfg)
    n_global = cfg.num_layers - n_local
    w = cfg.sliding_window
    hd = cfg.resolved_head_dim
    return {
        "local_k": jnp.zeros((n_local, batch, w, cfg.num_kv_heads, hd), dtype),
        "local_v": jnp.zeros((n_local, batch, w, cfg.num_kv_heads, hd), dtype),
        "global_k": jnp.zeros((n_global, batch, seq_len, cfg.num_kv_heads, hd),
                              dtype),
        "global_v": jnp.zeros((n_global, batch, seq_len, cfg.num_kv_heads, hd),
                              dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def split_cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
                     dtype=jnp.bfloat16) -> Params:
    return jax.eval_shape(lambda: init_split_cache(cfg, batch, seq_len, dtype))


def decode_step_split(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                      cache: Params) -> Tuple[jnp.ndarray, Params]:
    """Decode with ring-buffered local layers.  Requires sliding_window > 0.

    Layer heterogeneity (which stack a layer's cache lives in) is static, so
    this path unrolls the layer loop instead of scanning — decode bodies are
    small and L <= ~40 for the SWA archs, and unrolling avoids dragging the
    full-seq global stacks through scan carries.
    """
    h = params["embed"][token]
    pos = cache["pos"]
    w = cfg.sliding_window
    hd = cfg.resolved_head_dim
    import numpy as _np
    if cfg.local_global_ratio:
        idx = _np.arange(cfg.num_layers)
        is_local = (idx % (cfg.local_global_ratio + 1)) != cfg.local_global_ratio
    else:
        is_local = _np.ones(cfg.num_layers, bool)

    lk_stack, lv_stack = cache["local_k"], cache["local_v"]
    gk_stack, gv_stack = cache["global_k"], cache["global_v"]
    li = gi = 0
    for layer in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[layer], params["layers"])
        xn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
        if is_local[layer]:
            q, k, v = L._qkv(lp["attn"], xn, cfg.num_heads, cfg.num_kv_heads,
                             hd)
            p1 = jnp.full((1,), pos, jnp.int32)
            if cfg.rope_theta > 0:
                q = L.apply_rope(q, p1, cfg.rope_theta)
                k = L.apply_rope(k, p1, cfg.rope_theta)
            slot = jnp.mod(pos, w)
            lk = lax.dynamic_update_slice(
                lk_stack[li], k.astype(lk_stack.dtype), (0, slot, 0, 0))
            lv = lax.dynamic_update_slice(
                lv_stack[li], v.astype(lv_stack.dtype), (0, slot, 0, 0))
            valid = _ring_positions(pos, w) >= 0
            out = L._sdpa(q, lk, lv, valid[None, None, :])
            a = out.reshape(h.shape[0], 1, cfg.num_heads * hd) @ \
                lp["attn"]["wo"]
            lk_stack = lk_stack.at[li].set(lk)
            lv_stack = lv_stack.at[li].set(lv)
            li += 1
        else:
            a, gk, gv = L.attention_decode(
                lp["attn"], xn, gk_stack[gi], gv_stack[gi], pos,
                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=hd, rope_theta=cfg.rope_theta)
            gk_stack = gk_stack.at[gi].set(gk)
            gv_stack = gv_stack.at[gi].set(gv)
            gi += 1
        h = h + a
        h = h + L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], h, cfg.norm_eps))

    new_cache = {"local_k": lk_stack, "local_v": lv_stack,
                 "global_k": gk_stack, "global_v": gv_stack, "pos": pos + 1}
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, new_cache


def _prefill_body(cfg: ModelConfig, s: int, b: int, kv_dtype,
                  block_rows=None, start=None, page: int = 0,
                  quant: bool = False):
    """The per-layer prefill scan body shared by :func:`prefill` (contiguous
    cache) and :func:`prefill_paged` (page pool): K/V are rounded to the
    cache dtype *before* the in-pass attention so logits and cache match the
    token-by-token decode path exactly, and long sequences take the
    query-chunked attention path.  Emits (k, v) per layer for the caller to
    store.

    With ``block_rows``/``start`` (prefix sharing) the scan also carries the
    layer's page pool and splices cached-prefix K/V under the in-pass values
    (``layers.substitute_prefix_kv``) — the spliced tensor holds bitwise the
    values a from-scratch prefill would compute, so suffix K/V and
    last-position logits are bitwise identical to the non-sharing path.

    ``quant`` (int8 page pool): the in-pass attention sees K/V FAKE-quantized
    through the per-page int8 grid (``layers.quant_dequant_pages`` — the
    exact values later paged reads dequantize to), while the RAW values are
    emitted for the caller's ``quant_scatter_prefill_pages`` write, which
    recomputes the identical scales — no double rounding.  With prefix
    sharing the scan additionally carries the scale tensors to dequantize
    the spliced cached prefix."""
    prefix = start is not None

    def body(carry, xs):
        if prefix and quant:
            lp, win, pk, pv, sk, sv = xs
        elif prefix:
            lp, win, pk, pv = xs
        else:
            lp, win = xs
        x = carry
        xn = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = L._qkv(lp["attn"], xn, cfg.num_heads, cfg.num_kv_heads,
                         cfg.resolved_head_dim)
        if cfg.rope_theta > 0:
            pos = jnp.arange(s)
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
        if quant:
            k_raw, v_raw = k, v
            k = L.quant_dequant_pages(k, page)
            v = L.quant_dequant_pages(v, page)
            if prefix:
                k = L.substitute_prefix_kv(pk, k, block_rows, start, sk)
                v = L.substitute_prefix_kv(pv, v, block_rows, start, sv)
        else:
            k = k.astype(kv_dtype)
            v = v.astype(kv_dtype)
            if prefix:
                k = L.substitute_prefix_kv(pk, k, block_rows, start)
                v = L.substitute_prefix_kv(pv, v, block_rows, start)
        qc = 512 if (s > 512 and s % 512 == 0) else s
        if s > qc:
            a = L.chunked_attention(q, k, v, q_chunk=qc, causal=True, window=win)
        else:
            mask = L.causal_window_mask(s, s, window=win)
            a = L._sdpa(q, k, v, mask)
        a = a.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim) @ lp["attn"]["wo"]
        x = x + a
        m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + m, ((k_raw, v_raw) if quant else (k, v))

    return body


# ---------------------------------------------------------------------------
# paged KV cache (continuous batching)
# ---------------------------------------------------------------------------
#
# One physical page pool per tier — (L, P, page, K, Dh) — shared by every
# decode slot through an int32 block table (slot, logical page) -> physical
# page.  Physical page 0 is the null/trash page: idle slots and unallocated
# logical pages point there, its contents are garbage by design, and no
# positional mask ever exposes it.  There is NO global ``pos`` scalar — each
# slot carries its own position (slots decode at different depths).


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16) -> Params:
    del num_slots                       # attention state lives in pages only
    shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
             cfg.resolved_head_dim)
    cache = {"kp": jnp.zeros(shape, dtype), "vp": jnp.zeros(shape, dtype)}
    if jnp.dtype(dtype) == jnp.int8:
        # symmetric per-page-per-head scales ride beside the int8 pools in
        # the same donated cache pytree (dequant = int8 * scale)
        sshape = (cfg.num_layers, num_pages, cfg.num_kv_heads)
        cache["ks"] = jnp.zeros(sshape, jnp.float32)
        cache["vs"] = jnp.zeros(sshape, jnp.float32)
    return cache


def prefill_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  lengths: jnp.ndarray, slots: jnp.ndarray,
                  block_rows: jnp.ndarray, cache: Params, *,
                  start: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Params]:
    """Prefill a batch of admitted requests (each padded to the fixed max
    bucket) into their pages in ONE pass.

    tokens: (A, S_max) right-padded; lengths: (A,) int32 true (bucketed)
    prompt lengths — each row's logits are taken at ``lengths[i] - 1`` and
    only keys below ``lengths[i]`` are ever unmasked downstream, so the
    padded tails compute garbage that is never observed.  block_rows:
    (A, n_pages) the admitted slots' block-table rows; padded admission rows
    point at the null page.  The fixed (A, S_max) shape is what keeps the
    scheduler at ONE compiled executable across every prompt bucket, and the
    A-way batching is what amortises admission cost like the drain path does.

    ``start`` (prefix sharing): per-row first UNCACHED position.  Cached
    positions' K/V are read from the aliased pages (``substitute_prefix_kv``)
    and their page writes are redirected to the null page
    (``suffix_write_rows``) — the shared prefix is read-only; only the
    suffix is prefilled.  With ``start=None`` the graph is exactly the
    non-sharing one.

    The layer math is EXACTLY :func:`prefill`'s (shared ``_prefill_body``);
    only the cache write (page scatter vs contiguous) and the logits
    position differ.  Returns (logits (A, V) fp32, cache).
    """
    del slots                           # dense state is fully page-resident
    h = params["embed"][tokens]
    b, s, _ = h.shape
    windows = layer_windows(cfg, s)
    page = cache["kp"].shape[2]
    npg = s // page
    quant = "ks" in cache
    if start is None:
        body = _prefill_body(cfg, s, b, cache["kp"].dtype, page=page,
                             quant=quant)
        h, (ks, vs) = lax.scan(body, h, (params["layers"], windows))
        wrows = block_rows[:, :npg]
    else:
        body = _prefill_body(cfg, s, b, cache["kp"].dtype, block_rows, start,
                             page=page, quant=quant)
        xs = (params["layers"], windows, cache["kp"], cache["vp"])
        if quant:
            xs = xs + (cache["ks"], cache["vs"])
        h, (ks, vs) = lax.scan(body, h, xs)
        wrows = L.suffix_write_rows(block_rows, start, npg, page)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    if quant:
        # per-layer quantize + scatter (vmapped over the leading L axis);
        # scales are recomputed from the same raw values the in-pass
        # fake-quant used, so in-pass and later paged reads agree
        new_k, new_sk = jax.vmap(
            lambda p, sc, kv: L.quant_scatter_prefill_pages(p, sc, kv, wrows)
        )(cache["kp"], cache["ks"], ks)
        new_v, new_sv = jax.vmap(
            lambda p, sc, kv: L.quant_scatter_prefill_pages(p, sc, kv, wrows)
        )(cache["vp"], cache["vs"], vs)
        return logits, {"kp": new_k, "vp": new_v, "ks": new_sk, "vs": new_sv}
    # ks: (L, A, S, K, Dh) -> every layer's pages in one scatter
    shape = ks.shape[:1] + (b, npg, page) + ks.shape[3:]
    new_k = cache["kp"].at[:, wrows].set(ks.reshape(shape), mode="drop")
    new_v = cache["vp"].at[:, wrows].set(vs.reshape(shape), mode="drop")
    return logits, {"kp": new_k, "vp": new_v}


def decode_step_paged(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                      pos: jnp.ndarray, block: jnp.ndarray, cache: Params, *,
                      use_kernel: bool = False,
                      write_block: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Params]:
    """One decode step for ALL slots at per-slot positions.

    token: (B, 1); pos: (B,) int32; block: (B, n_pages) int32; write_block:
    the append-side table with shared (read-only) pages masked to the null
    page — see ``layers.attention_decode_paged``.
    Returns (logits (B, V) fp32, cache)."""
    h = params["embed"][token]
    page = cache["kp"].shape[2]
    s_tot = block.shape[1] * page
    windows = layer_windows(cfg, s_tot)
    quant = "ks" in cache

    def body(carry, xs):
        x = carry
        if quant:
            lp, pk, pv, sk, sv, win = xs
            a, pk, pv, sk, sv = L.attention_decode_paged(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=win, use_kernel=use_kernel, write_block=write_block,
                scale_k=sk, scale_v=sv)
            x = x + a
            m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
            return x + m, (pk, pv, sk, sv)
        lp, pk, pv, win = xs
        a, pk, pv = L.attention_decode_paged(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
            block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=win, use_kernel=use_kernel, write_block=write_block)
        x = x + a
        m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + m, (pk, pv)

    if quant:
        h, (nk, nv, nsk, nsv) = lax.scan(
            body, h, (params["layers"], cache["kp"], cache["vp"],
                      cache["ks"], cache["vs"], windows))
    else:
        h, (nk, nv) = lax.scan(body, h, (params["layers"], cache["kp"],
                                         cache["vp"], windows))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    if quant:
        return logits, {"kp": nk, "vp": nv, "ks": nsk, "vs": nsv}
    return logits, {"kp": nk, "vp": nv}


def forward_chunk_paged(params: Params, cfg: ModelConfig,
                        tokens: jnp.ndarray, pos: jnp.ndarray,
                        block: jnp.ndarray, cache: Params, *,
                        use_kernel: bool = False,
                        write_block: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, Params, dict]:
    """The chunked token lane: C tokens for ALL slots at per-slot start
    positions in ONE multi-token paged pass — the generalisation that
    subsumes both ``prefill_paged`` (chunked prompt ingestion: feed the
    prompt C tokens per tick) and ``decode_step_paged`` (C = 1).

    tokens: (B, C) int32; pos: (B,) int32 per-slot start positions (token i
    of slot b lands at ``pos[b] + i``).  Per-position logits come back for
    every chunk token — the speculative verify pass reads all of them, a
    prefill chunk reads only its last live position.  Attention state is
    entirely positional (rollback = rewind the host position), so the staged
    snapshot dict is empty.  Returns (logits (B, C, V) fp32, cache, staged).
    """
    h = params["embed"][tokens]
    page = cache["kp"].shape[2]
    s_tot = block.shape[1] * page
    windows = layer_windows(cfg, s_tot)
    quant = "ks" in cache

    def body(carry, xs):
        x = carry
        if quant:
            lp, pk, pv, sk, sv, win = xs
            a, pk, pv, sk, sv = L.attention_chunk_paged(
                lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
                block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                window=win, use_kernel=use_kernel, write_block=write_block,
                scale_k=sk, scale_v=sv)
            x = x + a
            m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
            return x + m, (pk, pv, sk, sv)
        lp, pk, pv, win = xs
        a, pk, pv = L.attention_chunk_paged(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), pk, pv,
            block, pos, num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=win, use_kernel=use_kernel, write_block=write_block)
        x = x + a
        m = L.swiglu(lp["mlp"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x + m, (pk, pv)

    if quant:
        h, (nk, nv, nsk, nsv) = lax.scan(
            body, h, (params["layers"], cache["kp"], cache["vp"],
                      cache["ks"], cache["vs"], windows))
    else:
        h, (nk, nv) = lax.scan(body, h, (params["layers"], cache["kp"],
                                         cache["vp"], windows))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    if quant:
        return logits, {"kp": nk, "vp": nv, "ks": nsk, "vs": nsv}, {}
    return logits, {"kp": nk, "vp": nv}, {}


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Params, *, patch_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Params]:
    """Fill the KV cache from a (B, S) prompt in ONE batched pass.

    ``cache`` (from :func:`init_cache`) supplies the buffers; its contents are
    fully overwritten, so callers may donate it across requests.  K/V are
    rounded to the cache dtype *before* the in-pass attention so logits and
    cache match the token-by-token :func:`decode_step` path exactly.

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    h = params["embed"][tokens]
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    windows = layer_windows(cfg, s)
    body = _prefill_body(cfg, s, b, cache["k"].dtype)
    h, (ks, vs) = lax.scan(body, h, (params["layers"], windows))
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, -1, :] @ params["lm_head"]).astype(jnp.float32)
    # write the prompt K/V into the provided buffers; the tail past ``s`` is
    # never read (decode masks positions > pos), so stale values are fine
    new_k = lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0))
    new_v = lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0))
    return logits, {"k": new_k, "v": new_v, "pos": jnp.asarray(s, jnp.int32)}
