"""Uniform model API across all families.

Every family exposes:
  init_params(rng, cfg, dtype)                        -> params pytree
  forward(params, cfg, batch, remat)                  -> logits (B, S, V) fp32
  loss(params, cfg, batch, remat)                     -> (scalar, metrics)
  init_cache(cfg, batch, seq_len)                     -> decode cache pytree
  decode_step(params, cfg, token, cache)              -> (logits (B, V), cache)

``batch`` is a dict: tokens (B, S) int32, labels (B, S) int32, and the
modality-stub inputs where applicable: frames (B, F, D) for encdec,
patches (B, P, D) for vlm.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, ENCDEC, HYBRID, MOE, SSM, VLM,
                                ModelConfig)
from repro.models import hybrid, mamba2, moe, transformer, whisper

Params = Dict[str, Any]


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    if cfg.family in (DENSE, VLM):
        return transformer.init_decoder(rng, cfg, dtype)
    if cfg.family == MOE:
        return moe.init_decoder(rng, cfg, dtype)
    if cfg.family == SSM:
        return mamba2.init_model(rng, cfg, dtype)
    if cfg.family == HYBRID:
        return hybrid.init_model(rng, cfg, dtype)
    if cfg.family == ENCDEC:
        return whisper.init_model(rng, cfg, dtype)
    raise ValueError(cfg.family)


def init_params_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run)."""
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda r: init_params(r, cfg, dtype), rng)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            remat: bool = False, use_kernel: bool = False,
            last_only: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss) — aux_loss is 0 for non-MoE families."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family == DENSE:
        return transformer.forward(params, cfg, batch["tokens"], remat=remat,
                                   last_only=last_only), zero
    if cfg.family == VLM:
        return transformer.forward(params, cfg, batch["tokens"], remat=remat,
                                   last_only=last_only,
                                   patch_embeds=batch["patches"]), zero
    if cfg.family == MOE:
        return moe.forward(params, cfg, batch["tokens"], remat=remat,
                           last_only=last_only)
    if cfg.family == SSM:
        return mamba2.forward(params, cfg, batch["tokens"], remat=remat,
                              use_kernel=use_kernel, last_only=last_only), zero
    if cfg.family == HYBRID:
        return hybrid.forward(params, cfg, batch["tokens"], remat=remat,
                              use_kernel=use_kernel, last_only=last_only), zero
    if cfg.family == ENCDEC:
        return whisper.forward(params, cfg, batch["frames"], batch["tokens"],
                               remat=remat, last_only=last_only), zero
    raise ValueError(cfg.family)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits (B, S, V) fp32, labels (B, S) -> mean NLL."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
         remat: bool = False, use_kernel: bool = False
         ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, cfg, batch, remat=remat, use_kernel=use_kernel)
    labels = batch["labels"]
    if cfg.family == VLM:
        # logits cover [patches | text]; loss only on the text positions
        logits = logits[:, -labels.shape[1]:, :]
    nll = cross_entropy(logits, labels)
    total = nll + cfg.router_aux_coef * aux
    return total, {"nll": nll, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    if cfg.family in (DENSE, VLM):
        return transformer.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == MOE:
        return moe.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == SSM:
        return mamba2.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == HYBRID:
        return hybrid.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == ENCDEC:
        return whisper.init_cache(cfg, batch, seq_len, dtype)
    raise ValueError(cfg.family)


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len, dtype))


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, cache, *,
            use_kernel: bool = False, patch_embeds=None):
    """Batched prompt ingestion: ONE forward-style pass over the whole (B, S)
    prompt that writes the decode cache — replacing O(S) sequential
    ``decode_step`` dispatches.  ``cache`` (from :func:`init_cache`) supplies
    the buffers and is fully overwritten, so callers may donate it.

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    if cfg.family in (DENSE, VLM):
        return transformer.prefill(params, cfg, tokens, cache,
                                   patch_embeds=patch_embeds)
    if cfg.family == MOE:
        return moe.prefill(params, cfg, tokens, cache)
    if cfg.family == SSM:
        return mamba2.prefill(params, cfg, tokens, cache, use_kernel=use_kernel)
    if cfg.family == HYBRID:
        return hybrid.prefill(params, cfg, tokens, cache, use_kernel=use_kernel)
    raise ValueError(f"prefill not supported for family {cfg.family!r}")


# ---------------------------------------------------------------------------
# paged cache API (continuous batching over a shared page pool)
# ---------------------------------------------------------------------------
#
# ``init_paged_cache`` allocates ONE device buffer set per tier: attention
# K/V lives in a (…, num_pages, page_size, K, Dh) physical page pool indexed
# by the scheduler's int32 block table (physical page 0 is the null page);
# recurrent SSM state is per-slot (constant-size — nothing to page).
# ``prefill_paged`` admits ONE right-padded request into a slot at a FIXED
# (1, S_max) shape (logits read at ``length - 1``), and ``decode_step_paged``
# steps ALL slots at per-slot positions — together they are shape-independent
# of the prompt bucket, which is what lets the continuous scheduler serve
# every bucket from a single compiled executable.

PAGED_FAMILIES = (DENSE, VLM, MOE, SSM, HYBRID)


def init_paged_cache(cfg: ModelConfig, num_slots: int, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    if cfg.family in (DENSE, VLM):
        return transformer.init_paged_cache(cfg, num_slots, num_pages,
                                            page_size, dtype)
    if cfg.family == MOE:
        return moe.init_paged_cache(cfg, num_slots, num_pages, page_size,
                                    dtype)
    if cfg.family == SSM:
        return mamba2.init_paged_cache(cfg, num_slots, num_pages, page_size,
                                       dtype)
    if cfg.family == HYBRID:
        return hybrid.init_paged_cache(cfg, num_slots, num_pages, page_size,
                                       dtype)
    raise ValueError(f"paged cache not supported for family {cfg.family!r}")


def prefill_paged(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  lengths: jnp.ndarray, slots: jnp.ndarray,
                  block_rows: jnp.ndarray, cache, *,
                  use_kernel: bool = False, start=None):
    """Admit a BATCH of requests in one pass: tokens (A, S_max) right-padded
    with true lengths (A,), into decode slots ``slots`` (A,) whose
    block-table rows are ``block_rows`` (A, n_pages).  Padded admission rows
    use an out-of-range slot + null-page rows, so their writes drop.

    ``start`` (A,) enables PARTIAL prefill at a page-aligned offset: each
    row's positions < start[i] are served from its aliased (prefix-shared)
    pages — the attention families splice the cached K/V under the in-pass
    values and redirect the prefix page writes to the null page; the
    recurrent families ignore it (their sharing is the whole-prompt
    snapshot/restore path).  Returns (per-row last-prompt-position logits
    (A, V) fp32, cache)."""
    if cfg.family in (DENSE, VLM):
        return transformer.prefill_paged(params, cfg, tokens, lengths, slots,
                                         block_rows, cache, start=start)
    if cfg.family == MOE:
        return moe.prefill_paged(params, cfg, tokens, lengths, slots,
                                 block_rows, cache, start=start)
    if cfg.family == SSM:
        return mamba2.prefill_paged(params, cfg, tokens, lengths, slots,
                                    block_rows, cache, use_kernel=use_kernel,
                                    start=start)
    if cfg.family == HYBRID:
        return hybrid.prefill_paged(params, cfg, tokens, lengths, slots,
                                    block_rows, cache, use_kernel=use_kernel,
                                    start=start)
    raise ValueError(f"prefill_paged not supported for family {cfg.family!r}")


def decode_step_paged(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                      pos: jnp.ndarray, block: jnp.ndarray, cache, *,
                      use_kernel: bool = False, write_block=None):
    """One decode step for ALL slots.  token (B, 1); pos (B,) per-slot
    positions; block (B, n_pages) block table; write_block masks shared
    (read-only) pages out of the append path.  Returns (logits, cache)."""
    if cfg.family in (DENSE, VLM):
        return transformer.decode_step_paged(params, cfg, token, pos, block,
                                             cache, use_kernel=use_kernel,
                                             write_block=write_block)
    if cfg.family == MOE:
        return moe.decode_step_paged(params, cfg, token, pos, block, cache,
                                     use_kernel=use_kernel,
                                     write_block=write_block)
    if cfg.family == SSM:
        return mamba2.decode_step_paged(params, cfg, token, pos, block, cache,
                                        write_block=write_block)
    if cfg.family == HYBRID:
        return hybrid.decode_step_paged(params, cfg, token, pos, block, cache,
                                        use_kernel=use_kernel,
                                        write_block=write_block)
    raise ValueError(
        f"decode_step_paged not supported for family {cfg.family!r}")


# ---------------------------------------------------------------------------
# chunked token lane (unified multi-token paged pass)
# ---------------------------------------------------------------------------
#
# ``forward_chunk_paged`` is the generalisation that subsumes the other two
# paged entry points: a (B, C) block of tokens for ALL slots at per-slot
# start positions, K/V written through the block table, per-position logits
# back.  ``decode_step_paged`` is the C = 1 special case; ``prefill_paged``
# is the everything-at-once special case (kept as the batched admission
# fast path).  The scheduler's chunked-prefill admission feeds long prompts
# through this lane C tokens per tick, and the fused speculative cascade
# uses it as the L tier's draft-verify pass.
#
# Rollback contract: the attention families' chunk state is positional
# (rewinding the host position shadows the rejected tail), so their
# ``staged`` is empty; the recurrent families emit per-step chunk-boundary
# snapshots in ``staged`` and the scheduler commits the accepted boundary
# with ``select_stage`` + ``restore_stage``.


def forward_chunk_paged(params: Params, cfg: ModelConfig,
                        tokens: jnp.ndarray, pos: jnp.ndarray,
                        block: jnp.ndarray, cache, *,
                        use_kernel: bool = False, write_block=None):
    """One multi-token paged pass: tokens (B, C) at per-slot start positions
    ``pos`` (B,) — token i of slot b lands at position ``pos[b] + i``.
    Greedy outputs are token-identical to C sequential ``decode_step_paged``
    calls — bitwise for the recurrent families, whose chunk IS a scan of the
    per-token step (tests/test_chunk_lane.py asserts it per family).
    Returns (logits (B, C, V) fp32, cache, staged)."""
    if cfg.family in (DENSE, VLM):
        return transformer.forward_chunk_paged(params, cfg, tokens, pos,
                                               block, cache,
                                               use_kernel=use_kernel,
                                               write_block=write_block)
    if cfg.family == MOE:
        return moe.forward_chunk_paged(params, cfg, tokens, pos, block,
                                       cache, use_kernel=use_kernel,
                                       write_block=write_block)
    if cfg.family == SSM:
        return mamba2.forward_chunk_paged(params, cfg, tokens, pos, block,
                                          cache, use_kernel=use_kernel,
                                          write_block=write_block)
    if cfg.family == HYBRID:
        return hybrid.forward_chunk_paged(params, cfg, tokens, pos, block,
                                          cache, use_kernel=use_kernel,
                                          write_block=write_block)
    raise ValueError(
        f"forward_chunk_paged not supported for family {cfg.family!r}")


def gather_chunk_slots(cfg: ModelConfig, cache, slots: jnp.ndarray):
    """A W-row view of the cache for the scheduler's chunk-prefill lane:
    the lane runs ``forward_chunk_paged`` over only the W slots actually
    mid-prefill (W << num_slots), not the whole slot table.  Attention state
    lives in the SHARED page pool (routed by the lane's gathered block rows),
    so the attention families pass the cache through; the recurrent families
    gather their per-slot state rows (sentinel rows gather-clamp harmlessly —
    their writes drop on the scatter side)."""
    if cfg.family == SSM:
        return {"state": cache["state"][:, slots],
                "conv": cache["conv"][:, slots]}
    if cfg.family == HYBRID:
        mini = {"state": cache["state"][:, :, slots],
                "conv": cache["conv"][:, :, slots],
                "kp": cache["kp"], "vp": cache["vp"]}
        if "ks" in cache:
            mini["ks"], mini["vs"] = cache["ks"], cache["vs"]
        return mini
    return cache


def scatter_chunk_slots(cfg: ModelConfig, cache, mini, stage_sel,
                        slots: jnp.ndarray):
    """Merge a W-row chunk pass back into the full cache: page pools pass
    through (the lane wrote them in place through its block rows); recurrent
    state scatters the SELECTED boundary snapshot (``select_stage`` over the
    lane's staged outputs — exactly ``chunk_keep`` inputs absorbed) at
    ``slots`` (sentinel == num_slots drops)."""
    if cfg.family == SSM:
        return dict(cache,
                    state=cache["state"].at[:, slots].set(
                        stage_sel["state"], mode="drop"),
                    conv=cache["conv"].at[:, slots].set(
                        stage_sel["conv"], mode="drop"))
    if cfg.family == HYBRID:
        merged = dict(cache, kp=mini["kp"], vp=mini["vp"],
                      state=cache["state"].at[:, :, slots].set(
                          stage_sel["state"], mode="drop"),
                      conv=cache["conv"].at[:, :, slots].set(
                          stage_sel["conv"], mode="drop"))
        if "ks" in mini:
            merged["ks"], merged["vs"] = mini["ks"], mini["vs"]
        return merged
    return mini


def chunk_stage(cfg: ModelConfig, cache):
    """The rollback-able (recurrent) slice of a paged cache — {} for the
    attention families, whose chunk state is positional."""
    if cfg.family == SSM:
        return mamba2.chunk_stage(cfg, cache)
    if cfg.family == HYBRID:
        return hybrid.chunk_stage(cfg, cache)
    return {}


def restore_stage(cfg: ModelConfig, cache, stage, mask: jnp.ndarray):
    """Overwrite slots where ``mask`` (B,) is True with ``stage``'s recurrent
    state (no-op for the attention families)."""
    if cfg.family == SSM:
        return mamba2.restore_stage(cfg, cache, stage, mask)
    if cfg.family == HYBRID:
        return hybrid.restore_stage(cfg, cache, stage, mask)
    return cache


def select_stage(cfg: ModelConfig, staged, keep: jnp.ndarray):
    """Per-slot chunk-boundary snapshot after exactly ``keep`` (B,) inputs
    (staged leaves carry a leading chunk axis; {} passes through)."""
    if cfg.family == SSM:
        return mamba2.select_stage(cfg, staged, keep)
    if cfg.family == HYBRID:
        return hybrid.select_stage(cfg, staged, keep)
    return {}


# ---------------------------------------------------------------------------
# prefix cache (cross-request prompt reuse)
# ---------------------------------------------------------------------------
#
# The pool's prefix index retains prompt pages by content hash; the DEVICE
# side keeps a small row cache per tier holding whatever a full-prompt
# restore needs beyond the pages themselves: the last-prompt-position logits
# (every family — they seed tok0 + the confidence gate without re-running
# the admit lane) and the recurrent state + conv window at the prompt
# boundary (SSM/hybrid).  Rows are host-allocated (LRU) by kv_pool and
# scattered/gathered inside the one tick program — no extra dispatch, no
# extra host sync.


def init_prefix_cache(cfg: ModelConfig, entries: int, dtype=jnp.bfloat16):
    """Device-side prefix-cache rows: (E, V) fp32 last-position logits for
    every family, plus the families' own snapshot extras."""
    base = {"logits": jnp.zeros((entries, cfg.vocab_size), jnp.float32)}
    if cfg.family == SSM:
        base.update(mamba2.init_prefix_cache(cfg, entries, dtype))
    elif cfg.family == HYBRID:
        base.update(hybrid.init_prefix_cache(cfg, entries, dtype))
    elif cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"prefix cache not supported for family {cfg.family!r}")
    return base


def snapshot_save(cfg: ModelConfig, cache, prefix, rows: jnp.ndarray,
                  slots: jnp.ndarray):
    """Scatter admitted slots' post-prefill recurrent state into prefix-cache
    rows (rows (A,), sentinel == entries drops).  No-op for the attention
    families — their prompt state IS the retained pages."""
    if cfg.family == SSM:
        return mamba2.snapshot_save(cfg, cache, prefix, rows, slots)
    if cfg.family == HYBRID:
        return hybrid.snapshot_save(cfg, cache, prefix, rows, slots)
    return prefix


def snapshot_restore(cfg: ModelConfig, cache, prefix, rows: jnp.ndarray,
                     slots: jnp.ndarray):
    """Scatter prefix-cache rows into restored decode slots (slots (A,),
    sentinel == num_slots drops)."""
    if cfg.family == SSM:
        return mamba2.snapshot_restore(cfg, cache, prefix, rows, slots)
    if cfg.family == HYBRID:
        return hybrid.snapshot_restore(cfg, cache, prefix, rows, slots)
    return cache


def cow_pages(cfg: ModelConfig, cache, src: jnp.ndarray, dst: jnp.ndarray, *,
              use_kernel: bool = False):
    """Execute the tick's copy-on-write page duplications: dst pages become
    copies of src pages in every layer's pool.  Pairs are padded with (0, 0)
    — the null page copied onto itself.  SSM caches have no pages."""
    if cfg.family == SSM:
        return cache
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(f"cow_pages not supported for family {cfg.family!r}")
    from repro.models import layers as L
    if use_kernel:
        from repro.kernels import ops as kops
        out = dict(cache, kp=kops.copy_pages(cache["kp"], src, dst),
                   vp=kops.copy_pages(cache["vp"], src, dst))
        if "ks" in cache:
            # scale rows move with their pages: copy_pages is shape/dtype
            # generic, so the same scalar-prefetched kernel relocates the
            # (L|G, P, K) fp32 scale tensors
            out["ks"] = kops.copy_pages(cache["ks"], src, dst)
            out["vs"] = kops.copy_pages(cache["vs"], src, dst)
        return out
    out = dict(cache, kp=L.cow_copy_pages(cache["kp"], src, dst),
               vp=L.cow_copy_pages(cache["vp"], src, dst))
    if "ks" in cache:
        out["ks"] = L.cow_copy_scales(cache["ks"], src, dst)
        out["vs"] = L.cow_copy_scales(cache["vs"], src, dst)
    return out


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray, cache, *,
                use_kernel: bool = False):
    if cfg.family in (DENSE, VLM):
        return transformer.decode_step(params, cfg, token, cache,
                                       use_kernel=use_kernel)
    if cfg.family == MOE:
        return moe.decode_step(params, cfg, token, cache, use_kernel=use_kernel)
    if cfg.family == SSM:
        return mamba2.decode_step(params, cfg, token, cache)
    if cfg.family == HYBRID:
        return hybrid.decode_step(params, cfg, token, cache)
    if cfg.family == ENCDEC:
        return whisper.decode_step(params, cfg, token, cache)
    raise ValueError(cfg.family)
