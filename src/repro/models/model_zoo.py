"""Uniform model API across all families.

Every family exposes:
  init_params(rng, cfg, dtype)                        -> params pytree
  forward(params, cfg, batch, remat)                  -> logits (B, S, V) fp32
  loss(params, cfg, batch, remat)                     -> (scalar, metrics)
  init_cache(cfg, batch, seq_len)                     -> decode cache pytree
  decode_step(params, cfg, token, cache)              -> (logits (B, V), cache)

``batch`` is a dict: tokens (B, S) int32, labels (B, S) int32, and the
modality-stub inputs where applicable: frames (B, F, D) for encdec,
patches (B, P, D) for vlm.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, ENCDEC, HYBRID, MOE, SSM, VLM,
                                ModelConfig)
from repro.models import hybrid, mamba2, moe, transformer, whisper

Params = Dict[str, Any]


def init_params(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    if cfg.family in (DENSE, VLM):
        return transformer.init_decoder(rng, cfg, dtype)
    if cfg.family == MOE:
        return moe.init_decoder(rng, cfg, dtype)
    if cfg.family == SSM:
        return mamba2.init_model(rng, cfg, dtype)
    if cfg.family == HYBRID:
        return hybrid.init_model(rng, cfg, dtype)
    if cfg.family == ENCDEC:
        return whisper.init_model(rng, cfg, dtype)
    raise ValueError(cfg.family)


def init_params_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run)."""
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda r: init_params(r, cfg, dtype), rng)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            remat: bool = False, use_kernel: bool = False,
            last_only: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss) — aux_loss is 0 for non-MoE families."""
    zero = jnp.zeros((), jnp.float32)
    if cfg.family == DENSE:
        return transformer.forward(params, cfg, batch["tokens"], remat=remat,
                                   last_only=last_only), zero
    if cfg.family == VLM:
        return transformer.forward(params, cfg, batch["tokens"], remat=remat,
                                   last_only=last_only,
                                   patch_embeds=batch["patches"]), zero
    if cfg.family == MOE:
        return moe.forward(params, cfg, batch["tokens"], remat=remat,
                           last_only=last_only)
    if cfg.family == SSM:
        return mamba2.forward(params, cfg, batch["tokens"], remat=remat,
                              use_kernel=use_kernel, last_only=last_only), zero
    if cfg.family == HYBRID:
        return hybrid.forward(params, cfg, batch["tokens"], remat=remat,
                              use_kernel=use_kernel, last_only=last_only), zero
    if cfg.family == ENCDEC:
        return whisper.forward(params, cfg, batch["frames"], batch["tokens"],
                               remat=remat, last_only=last_only), zero
    raise ValueError(cfg.family)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits (B, S, V) fp32, labels (B, S) -> mean NLL."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
         remat: bool = False, use_kernel: bool = False
         ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, cfg, batch, remat=remat, use_kernel=use_kernel)
    labels = batch["labels"]
    if cfg.family == VLM:
        # logits cover [patches | text]; loss only on the text positions
        logits = logits[:, -labels.shape[1]:, :]
    nll = cross_entropy(logits, labels)
    total = nll + cfg.router_aux_coef * aux
    return total, {"nll": nll, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    if cfg.family in (DENSE, VLM):
        return transformer.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == MOE:
        return moe.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == SSM:
        return mamba2.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == HYBRID:
        return hybrid.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family == ENCDEC:
        return whisper.init_cache(cfg, batch, seq_len, dtype)
    raise ValueError(cfg.family)


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len, dtype))


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, cache, *,
            use_kernel: bool = False, patch_embeds=None):
    """Batched prompt ingestion: ONE forward-style pass over the whole (B, S)
    prompt that writes the decode cache — replacing O(S) sequential
    ``decode_step`` dispatches.  ``cache`` (from :func:`init_cache`) supplies
    the buffers and is fully overwritten, so callers may donate it.

    Returns (last-token logits (B, V) fp32, filled cache).
    """
    if cfg.family in (DENSE, VLM):
        return transformer.prefill(params, cfg, tokens, cache,
                                   patch_embeds=patch_embeds)
    if cfg.family == MOE:
        return moe.prefill(params, cfg, tokens, cache)
    if cfg.family == SSM:
        return mamba2.prefill(params, cfg, tokens, cache, use_kernel=use_kernel)
    if cfg.family == HYBRID:
        return hybrid.prefill(params, cfg, tokens, cache, use_kernel=use_kernel)
    raise ValueError(f"prefill not supported for family {cfg.family!r}")


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray, cache, *,
                use_kernel: bool = False):
    if cfg.family in (DENSE, VLM):
        return transformer.decode_step(params, cfg, token, cache,
                                       use_kernel=use_kernel)
    if cfg.family == MOE:
        return moe.decode_step(params, cfg, token, cache, use_kernel=use_kernel)
    if cfg.family == SSM:
        return mamba2.decode_step(params, cfg, token, cache)
    if cfg.family == HYBRID:
        return hybrid.decode_step(params, cfg, token, cache)
    if cfg.family == ENCDEC:
        return whisper.decode_step(params, cfg, token, cache)
    raise ValueError(cfg.family)
