"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: the model consumes precomputed frame embeddings
``frames (B, num_audio_frames, d_model)``.  LayerNorm + GELU MLP (whisper
uses pre-LN transformer blocks, learned positional embeddings, no RoPE).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import act

Params = Dict[str, Any]


def _init_enc_layer(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, bias=cfg.qkv_bias,
                                 dtype=dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(rng, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "self_attn": L.attention_init(k1, cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.resolved_head_dim,
                                      bias=cfg.qkv_bias, dtype=dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "cross_attn": L.attention_init(k2, cfg.d_model, cfg.num_heads,
                                       cfg.num_kv_heads, cfg.resolved_head_dim,
                                       bias=cfg.qkv_bias, dtype=dtype),
        "ln3": L.layernorm_init(cfg.d_model, dtype),
        "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_model(rng, cfg: ModelConfig, dtype=jnp.float32,
               max_seq: int = 32_768) -> Params:
    ks = jax.random.split(rng, 6)
    enc_rngs = jax.random.split(ks[0], cfg.encoder_layers)
    dec_rngs = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_pos": (jax.random.normal(ks[2], (cfg.num_audio_frames, cfg.d_model))
                    * 0.02).astype(dtype),
        "enc_layers": jax.vmap(lambda r: _init_enc_layer(r, cfg, dtype))(enc_rngs),
        "enc_norm": L.layernorm_init(cfg.d_model, dtype),
        "embed": L.embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
        "dec_pos": (jax.random.normal(ks[4], (max_seq, cfg.d_model))
                    * 0.02).astype(dtype),
        "dec_layers": jax.vmap(lambda r: _init_dec_layer(r, cfg, dtype))(dec_rngs),
        "dec_norm": L.layernorm_init(cfg.d_model, dtype),
        "lm_head": L.dense_init(ks[5], cfg.d_model, cfg.vocab_size, dtype),
    }


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray,
           remat: bool = False) -> jnp.ndarray:
    """frames: (B, F, D) stubbed frontend embeddings -> encoder states."""
    h = frames + params["enc_pos"][None, : frames.shape[1], :].astype(frames.dtype)

    def body(carry, lp):
        x = act.shard_hidden(carry)
        a = L.attention_forward(lp["attn"], L.layernorm(lp["ln1"], x, cfg.norm_eps),
                                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim, rope_theta=0.0,
                                causal=False)
        x = x + a
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], x, cfg.norm_eps))
        return act.shard_hidden(x + m), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, act.shard_hidden(h), params["enc_layers"])
    return L.layernorm(params["enc_norm"], h, cfg.norm_eps)


def _cross_attend(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
                  enc: jnp.ndarray) -> jnp.ndarray:
    """Cross attention: q from decoder x, k/v from encoder states."""
    b, s, _ = x.shape
    f = enc.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ lp["wq"] + lp.get("bq", 0)).reshape(b, s, cfg.num_heads, hd)
    k = (enc @ lp["wk"] + lp.get("bk", 0)).reshape(b, f, cfg.num_kv_heads, hd)
    v = (enc @ lp["wv"] + lp.get("bv", 0)).reshape(b, f, cfg.num_kv_heads, hd)
    out = L._sdpa(q, k, v, None)
    return out.reshape(b, s, cfg.num_heads * hd) @ lp["wo"]


def forward(params: Params, cfg: ModelConfig, frames: jnp.ndarray,
            tokens: jnp.ndarray, *, remat: bool = False,
            last_only: bool = False) -> jnp.ndarray:
    """Teacher-forced enc-dec forward -> logits (B, S, V)."""
    enc = encode(params, cfg, frames, remat)
    b, s = tokens.shape
    h = params["embed"][tokens] + \
        params["dec_pos"][None, :s, :].astype(params["embed"].dtype)

    def body(carry, lp):
        x = act.shard_hidden(carry)
        a = L.attention_forward(lp["self_attn"],
                                L.layernorm(lp["ln1"], x, cfg.norm_eps),
                                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim, rope_theta=0.0,
                                causal=True)
        x = x + a
        c = _cross_attend(lp["cross_attn"], cfg,
                          L.layernorm(lp["ln2"], x, cfg.norm_eps), enc)
        x = x + c
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], x, cfg.norm_eps))
        return act.shard_hidden(x + m), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, act.shard_hidden(h), params["dec_layers"])
    h = L.layernorm(params["dec_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    return act.shard_logits((h @ params["lm_head"]).astype(jnp.float32))


# ---------------------------------------------------------------------------
# decode: self-attn KV cache + precomputed cross-attn K/V
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    self_shape = (cfg.num_layers, batch, seq_len, cfg.num_kv_heads, hd)
    cross_shape = (cfg.num_layers, batch, cfg.num_audio_frames,
                   cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(self_shape, dtype), "v": jnp.zeros(self_shape, dtype),
        "ck": jnp.zeros(cross_shape, dtype), "cv": jnp.zeros(cross_shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def precompute_cross(params: Params, cfg: ModelConfig, frames: jnp.ndarray,
                     cache: Params) -> Params:
    """Encode once and cache per-layer cross K/V."""
    enc = encode(params, cfg, frames)
    b, f, _ = enc.shape
    hd = cfg.resolved_head_dim

    def per_layer(lp):
        ca = lp["cross_attn"]
        k = (enc @ ca["wk"] + ca.get("bk", 0)).reshape(b, f, cfg.num_kv_heads, hd)
        v = (enc @ ca["wv"] + ca.get("bv", 0)).reshape(b, f, cfg.num_kv_heads, hd)
        return k.astype(cache["ck"].dtype), v.astype(cache["cv"].dtype)

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return dict(cache, ck=ck, cv=cv)


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                cache: Params) -> Tuple[jnp.ndarray, Params]:
    pos = cache["pos"]
    h = params["embed"][token] + \
        lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)[None]
    hd = cfg.resolved_head_dim

    def body(carry, xs):
        x = carry
        lp, ck, cv, xk, xv = xs
        a, ck, cv = L.attention_decode(lp["self_attn"],
                                       L.layernorm(lp["ln1"], x, cfg.norm_eps),
                                       ck, cv, pos,
                                       num_heads=cfg.num_heads,
                                       num_kv=cfg.num_kv_heads, head_dim=hd,
                                       rope_theta=0.0)
        x = x + a
        # cross attention against precomputed K/V (always valid, non-causal)
        xn = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        ca = lp["cross_attn"]
        b = x.shape[0]
        q = (xn @ ca["wq"] + ca.get("bq", 0)).reshape(b, 1, cfg.num_heads, hd)
        c = L._sdpa(q, xk, xv, None)
        x = x + c.reshape(b, 1, cfg.num_heads * hd) @ ca["wo"]
        m = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln3"], x, cfg.norm_eps))
        return x + m, (ck, cv)

    h, (nk, nv) = lax.scan(body, h, (params["dec_layers"], cache["k"], cache["v"],
                                     cache["ck"], cache["cv"]))
    h = L.layernorm(params["dec_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["lm_head"]).astype(jnp.float32)
    return logits, dict(cache, k=nk, v=nv, pos=pos + 1)
