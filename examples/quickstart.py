"""Quickstart: the HI decision module in 30 lines.

Runs the fused hi_gate kernel over S-tier logits, routes complex samples
through the static-capacity router, and prints the paper's cost accounting.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import brute_force_theta
from repro.core.cost import cost_closed_form
from repro.core.router import capacity_for, route
from repro.kernels import ops as kops


def main():
    rng = np.random.default_rng(0)
    n, classes = 1000, 10

    # pretend S-tier logits: half the samples confidently right, half fuzzy
    easy = rng.normal(0, 1, (n // 2, classes)); easy[:, 0] += 6
    hard = rng.normal(0, 1, (n // 2, classes))
    logits = jnp.asarray(np.concatenate([easy, hard]), jnp.float32)
    s_correct = np.concatenate([np.ones(n // 2, bool),
                                rng.random(n // 2) < 0.3])

    # 1) calibrate theta* offline (paper SS4: brute force on validation data)
    conf_np = np.asarray(kops.hi_gate(logits, 0.5)[0])
    theta, cost = brute_force_theta(conf_np, s_correct, beta=0.4)
    print(f"calibrated theta* = {theta:.3f} (min cost {cost:.0f})")

    # 2) fused gate kernel: confidence + prediction + offload decision
    conf, pred, offload = kops.hi_gate(logits, theta)
    print(f"offload fraction = {float(jnp.mean(offload.astype(jnp.float32))):.2%}")

    # 3) static-capacity router (the TPU-native offload link)
    cap = capacity_for(n, 0.6)
    d = route(offload.astype(bool), conf, cap)
    print(f"served remotely: {int(d.served_remote.sum())}/{n} "
          f"(capacity {cap}, dropped {int(d.dropped)})")

    # 4) the paper's cost model
    n_off = int(d.served_remote.sum())
    wrong_local = int((~s_correct & ~np.asarray(d.served_remote)).sum())
    print("total cost:", cost_closed_form(n_off, wrong_local, 0, beta=0.4))


if __name__ == "__main__":
    main()
