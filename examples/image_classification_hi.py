"""Paper §4: HI for CIFAR-10-style image classification — the Table 1 study.

Trains the paper's two tiers on the synthetic CIFAR-10 stand-in:
  S-ML: 5-layer tinyML CNN (paper: 62.58%, 0.45 MB quantised)
  L-ML: deeper CNN standing in for EfficientNet (paper: 95%)
then calibrates theta* by brute force (paper: 0.607), runs the HI cascade
through the fused hi_gate kernel + static-capacity router, and prints the
Table-1 cost comparison (no offload / full offload / HI) for a sweep of beta.

  PYTHONPATH=src python examples/image_classification_hi.py [--fast]
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig
from repro.core import replay
from repro.core.calibrate import brute_force_theta, p_histogram
from repro.core.cascade import classifier_cascade
from repro.core.cost import CostReport
from repro.core.metrics import format_table, hi_report
from repro.data import images
from repro.models import cnn
from repro.training.cnn_trainer import accuracy, predict_logits, train_cnn


def train_tiers(n_train=8000, n_val=2000, n_test=2000, epochs_s=4, epochs_l=5,
                seed=0):
    x_tr, y_tr = images.make_dataset(n_train, seed=seed)
    x_va, y_va = images.make_dataset(n_val, seed=seed + 1)
    x_te, y_te = images.make_dataset(n_test, seed=seed + 2)
    print(f"training S-ML ({cnn.SML_CIFAR.name}) ...")
    ps = train_cnn(cnn.SML_CIFAR, x_tr, y_tr, epochs=epochs_s, verbose=True)
    print(f"training L-ML ({cnn.LML_CIFAR.name}) ...")
    pl = train_cnn(cnn.LML_CIFAR, x_tr, y_tr, epochs=epochs_l, verbose=True)
    return (ps, pl), (x_va, y_va), (x_te, y_te)


def main(fast: bool = False):
    kw = dict(n_train=3000, n_val=1000, n_test=1000, epochs_s=2, epochs_l=2) \
        if fast else {}
    (ps, pl), (x_va, y_va), (x_te, y_te) = train_tiers(**kw)

    s_acc = accuracy(ps, cnn.SML_CIFAR, x_te, y_te)
    l_acc = accuracy(pl, cnn.LML_CIFAR, x_te, y_te)
    import repro.models.cnn as cnn_mod
    print(f"\nS-ML accuracy {s_acc:.2%} (paper 62.58%), "
          f"size {cnn_mod.model_size_mb(ps):.2f} MB int8 (paper 0.45 MB)")
    print(f"L-ML accuracy {l_acc:.2%} (paper 95%)")

    # --- theta* calibration on validation (paper: brute force -> 0.607) ----
    beta = 0.5
    s_logits_va = predict_logits(ps, cnn.SML_CIFAR, x_va)
    conf_va = np.asarray(jnp.max(jnp.asarray(
        np.exp(s_logits_va - s_logits_va.max(-1, keepdims=True)) /
        np.exp(s_logits_va - s_logits_va.max(-1, keepdims=True)).sum(
            -1, keepdims=True)), axis=-1))
    s_ok_va = s_logits_va.argmax(-1) == y_va
    theta, _ = brute_force_theta(conf_va, s_ok_va, beta)
    print(f"calibrated theta* = {theta:.3f} at beta={beta} (paper: 0.607)")
    hist = p_histogram(conf_va, s_ok_va, bins=10)
    print("Fig.6-style p-histogram (correct/incorrect per conf bin):")
    for i in range(10):
        print(f"  p in [{hist['edges'][i]:.1f},{hist['edges'][i+1]:.1f}): "
              f"{hist['correct'][i]:5d} / {hist['incorrect'][i]:5d}")

    # --- HI cascade on the test set -----------------------------------------
    hi = HIConfig(theta=float(theta), beta=beta, capacity_factor=1.0)
    casc = classifier_cascade(
        lambda p, x: cnn.apply_cnn(p, cnn.SML_CIFAR, x),
        lambda p, x: cnn.apply_cnn(p, cnn.LML_CIFAR, x),
        hi, use_kernel=True)
    out = casc.infer_jit()(ps, pl, jnp.asarray(x_te))

    rep_hi = hi_report(out["pred"], out["s_pred"], out["served_remote"],
                       out["offload_mask"], y_te, None, beta)
    n = len(y_te)
    s_pred = np.asarray(out["s_pred"])
    l_pred = predict_logits(pl, cnn.LML_CIFAR, x_te).argmax(-1)
    rep_no = CostReport("no-offload", n, 0, int((s_pred != y_te).sum()), 0, beta)
    rep_full = CostReport("full-offload", n, n, 0,
                          int((l_pred != y_te).sum()), beta)
    print("\n=== Table 1 (synthetic-data reproduction, beta=0.5) ===")
    print(format_table([rep_no, rep_full, rep_hi]))

    print("\n=== Table 1 (paper's published counts, replayed exactly) ===")
    t = replay.table1(beta)
    print(format_table([t["no_offload"], t["full_offload"], t["hi"]]))

    print("\nrelative cost reduction vs full offload (ours vs paper):")
    for b in (0.25, 0.5, 0.75, 0.99):
        t = replay.table1(b)
        ours = (1 - (rep_hi.offloaded * b + rep_hi.misclassified) /
                (n * b + rep_full.misclassified)) * 100
        paper = (1 - t["hi"].cost / t["full_offload"].cost) * 100
        print(f"  beta={b:.2f}: ours {ours:5.1f}%   paper {paper:5.1f}%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(ap.parse_args().fast)
