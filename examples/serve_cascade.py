"""End-to-end driver: serve a small model with batched requests through the
HI cascade (deliverable b).

Builds an S/L tier pair from an assigned architecture (reduced so it runs on
CPU; on a pod the same engine runs the full config via launch/serve.py),
feeds batched requests through the batcher, and reports the paper's
offload/cost accounting plus measured tier latencies.

  PYTHONPATH=src python examples/serve_cascade.py --arch qwen2-1.5b \
      --requests 32 --theta 0.55

``--stream`` serves the same request set through the continuous-batching
scheduler (slot-level admission over the paged KV pool, one compiled shape
across all buckets) instead of drained batches.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.core.baselines import TimingModel
from repro.models import model_zoo
from repro.serving import engine as engine_mod
from repro.serving.batcher import Batcher, Request
from repro.serving.engine import build_engine


def _tier_ms_per_request(tier, batch, bucket, steps, cache_len,
                         iters: int = 3) -> float:
    """Wall ms per request for ONE tier's prefill+decode program.

    The fused cascade is a single device program, so per-tier costs can't be
    split out of ``serve_time`` — measure each tier's generate directly."""
    fn = jax.jit(lambda p, t, c: engine_mod._generate(
        p, tier.cfg, t, c, steps=steps, metric="max_prob", theta=0.5))
    toks = jnp.zeros((batch, bucket), jnp.int32)
    cache = model_zoo.init_cache(tier.cfg, batch, cache_len)
    jax.block_until_ready(fn(tier.params, toks, cache))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(tier.params, toks, cache))
        times.append(time.perf_counter() - t0)
    return min(times) / batch * 1000


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.55)
    ap.add_argument("--capacity-factor", type=float, default=0.5)
    ap.add_argument("--max-new-tokens", type=int, default=6)
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching (paged KV pool) instead of "
                         "drained batches")
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    hi = HIConfig(theta=args.theta, capacity_factor=args.capacity_factor)
    print(f"building HI cascade for {args.arch}: "
          f"S={cfg.s_variant(hi.s_scale).name} L={cfg.name}")
    engine = build_engine(cfg, hi, max_new_tokens=args.max_new_tokens,
                          cache_len=64)

    rng = np.random.default_rng(0)
    requests = [Request(i, rng.integers(
        0, cfg.vocab_size, int(rng.integers(4, 16))).astype(np.int32),
        max_new_tokens=args.max_new_tokens) for i in range(args.requests)]

    t0 = time.time()
    if args.stream:
        results = engine.serve_stream(requests, buckets=(16, 32),
                                      num_slots=args.batch, page_size=16)
        confs = np.asarray([results[r.request_id]["confidence"]
                            for r in requests])
        n_off = sum(results[r.request_id]["offloaded"] for r in requests)
        print(f"stream: conf={np.round(confs, 2)} "
              f"offloaded={n_off}/{len(requests)} "
              f"({int(engine.stats['stream_ticks'])} ticks, "
              f"{int(engine.stats['stream_compiles'])} compiled shape)")
    else:
        batcher = Batcher(batch_size=args.batch, buckets=(16, 32))
        for r in requests:
            batcher.submit(r)
        batches = 0
        while batcher.queue:
            b = batcher.next_batch()
            out = engine.serve(b.tokens)
            batches += 1
            print(f"batch {batches}: conf={np.round(out['confidence'], 2)} "
                  f"offloaded={int(out['offloaded'].sum())}/{len(b.tokens)}")
    dt = time.time() - t0

    s = engine.summary()
    print(f"\nserved {s['requests']} requests in {dt:.1f}s")
    print(f"offload fraction: {s['offload_frac']:.1%}  "
          f"(capacity drops: {s['drop_frac']:.1%})")
    print(f"cascade wall time {s['serve_time']:.2f}s "
          f"({int(s['compiles'])} compiled shapes)")

    # paper Fig-8-style latency accounting with directly measured tier costs
    from repro.core.router import capacity_for
    bucket = max(b for (_, b) in engine._exec) if engine._exec else 16
    cap = capacity_for(args.batch, args.capacity_factor)
    per_s = _tier_ms_per_request(engine.s, args.batch, bucket,
                                 args.max_new_tokens, engine.cache_len)
    per_l = _tier_ms_per_request(engine.l, cap, bucket,
                                 args.max_new_tokens, engine.cache_len)
    tm = TimingModel(t_local_ms=per_s, t_offload_ms=per_l)
    hi_ms = tm.hi_makespan_ms(s["requests"], int(s["offloaded"]))
    full_ms = s["requests"] * per_l
    print(f"measured per-request: S {per_s:.1f}ms, L {per_l:.1f}ms")
    print(f"HI makespan {hi_ms:.0f}ms vs full-offload {full_ms:.0f}ms "
          f"-> {(1 - hi_ms / full_ms):.1%} latency saving")


if __name__ == "__main__":
    main()
