"""Paper §3: HI for rolling-element (REB) fault diagnosis.

S-ML = the paper's moving-average threshold rule (mean |x| of a 4096-sample
window vs 0.07) running on the sensor; L-ML = the 8-layer CNN of [38]
classifying the 10 machine states, deployed at the ES.  Only windows the
threshold flags as NOT-normal are offloaded.

Reproduces, on the CWRU-statistics-matched synthetic dataset:
  * 100% normal-vs-fault separation by the 0.07 threshold (Figs. 4–5)
  * near-total bandwidth savings when machines are mostly normal
  * the CNN resolving the fault states the threshold cannot (Fig. 5)

  PYTHONPATH=src python examples/fault_detection.py
"""

from repro.data import vibration as vib
from repro.models import cnn
from repro.training.cnn_trainer import accuracy, train_cnn


def main():
    # --- train the L-ML fault CNN on (balanced) fault data -----------------
    x_tr, y_tr, _ = vib.make_dataset(windows_per_state=100, seed=0)
    x_te, y_te, means_te = vib.make_dataset(windows_per_state=25, seed=1)
    print(f"training {cnn.FAULT_CNN.name} on {len(x_tr)} windows ...")
    params = train_cnn(cnn.FAULT_CNN, x_tr, y_tr, epochs=20, batch=64,
                       lr=2e-3)
    cnn_acc = accuracy(params, cnn.FAULT_CNN, x_te, y_te)
    print(f"L-ML (CNN) 10-state accuracy: {cnn_acc:.1%} "
          f"(paper's CNN [38]: 99.6%; more data/epochs close the gap — "
          f"this budget is CPU-bound)")

    # --- the S-ML threshold rule (paper: theta = 0.07) ----------------------
    is_fault_pred = vib.threshold_sml(means_te, theta=0.07)
    is_fault_true = y_te != 0
    tp = (is_fault_pred & is_fault_true).sum()
    tn = (~is_fault_pred & ~is_fault_true).sum()
    print(f"threshold S-ML normal-vs-fault accuracy: "
          f"{(tp + tn) / len(y_te):.1%}  (paper: 100%)")

    # --- HI deployment: realistic duty cycle (machines mostly normal) ------
    x_op, y_op, means_op = vib.make_dataset(windows_per_state=40, seed=2,
                                            normal_fraction=0.98)
    offload = vib.threshold_sml(means_op, 0.07)
    frac = offload.mean()
    print(f"\noperational stream: {len(y_op)} windows, "
          f"{(y_op == 0).mean():.1%} normal")
    print(f"HI offloads {offload.sum()}/{len(y_op)} windows ({frac:.2%})")

    full_bw = vib.bandwidth_required(num_machines=100)
    print(f"full-offload bandwidth for 100 machines: {full_bw:.1f} Mbps "
          f"(paper: >= 76.8 Mbps)")
    print(f"HI bandwidth: {full_bw * frac:.2f} Mbps "
          f"-> {(1 - frac):.1%} bandwidth saved")

    # fault windows that do offload get correctly classified by the CNN
    if offload.any():
        acc_off = accuracy(params, cnn.FAULT_CNN,
                           x_op[offload], y_op[offload])
        print(f"CNN accuracy on offloaded windows: {acc_off:.1%}")

    # missed faults (false negatives of the threshold rule)
    missed = (~offload & (y_op != 0)).sum()
    print(f"fault windows missed by the threshold: {missed}")


if __name__ == "__main__":
    main()
