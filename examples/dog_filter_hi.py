"""Paper §5: HI as a binary relevance filter — the dog-breed use case.

S-ML = 0.23 MB binary CNN (dog / not-dog) on the ED; images classified as
dogs (p >= 0.5) are the COMPLEX samples and are offloaded to a (per the
paper, assumed-perfect) dog-breed L-ML at the ES.  Irrelevant images never
leave the device.

Prints the Table-3 comparison: number offloaded, accuracy (= recall of dogs
reaching the L-ML), cost 912*beta + 3521-style formulas — next to the
paper's exact published counts.

  PYTHONPATH=src python examples/dog_filter_hi.py [--fast]
"""
import argparse

import numpy as np

from repro.core import replay
from repro.data import images
from repro.models import cnn
from repro.training.cnn_trainer import predict_logits, train_cnn


def main(fast: bool = False):
    n_tr, n_te, epochs = (3000, 1000, 2) if fast else (8000, 10_000, 4)
    x_tr, y_tr = images.make_dataset(n_tr, seed=0)
    x_te, y_te = images.make_dataset(n_te, seed=7)
    b_te = images.binary_labels(y_te)

    # train recall-oriented: oversample dogs to 50% (with the natural 10%
    # prior a tiny filter collapses to always-negative)
    b_all = images.binary_labels(y_tr)
    rng = np.random.default_rng(0)
    pos, neg = np.flatnonzero(b_all == 1), np.flatnonzero(b_all == 0)
    idx = rng.permutation(np.concatenate(
        [rng.choice(pos, size=len(neg), replace=True), neg]))
    x_bal, b_bal = x_tr[idx], b_all[idx]

    print(f"training S-ML relevance filter ({cnn.SML_BINARY.name}) ...")
    ps = train_cnn(cnn.SML_BINARY, x_bal, b_bal, epochs=epochs, verbose=True)
    print(f"S-ML size {cnn.model_size_mb(ps):.2f} MB int8 (paper: 0.23 MB)")

    # decision rule (paper SS5): offload iff p >= 0.5
    p = 1 / (1 + np.exp(-predict_logits(ps, cnn.SML_BINARY, x_te)[:, 0]))
    offload = p >= 0.5

    dogs = b_te == 1
    tp = int((offload & dogs).sum())          # dogs reaching the L-ML
    fn = int((~offload & dogs).sum())         # missed dogs
    fp = int((offload & ~dogs).sum())         # irrelevant images offloaded
    n_dogs = int(dogs.sum())
    acc = tp / max(n_dogs, 1)                 # paper's accuracy metric

    print(f"\n=== Table 3 (synthetic-data reproduction, N={n_te}, "
          f"{n_dogs} dogs) ===")
    print(f"offloaded: {tp + fp} ({tp} dogs + {fp} false positives)")
    print(f"missed dogs (false negatives): {fn}")
    print(f"accuracy (dogs reaching L-ML): {acc:.1%}")
    print(f"HI cost: {tp}*beta + {fp}")
    print(f"full-offload cost: {n_dogs}*beta + {n_te - n_dogs}")
    for beta in (0.1, 0.5, 0.9):
        hi_c = tp * beta + fp
        full_c = n_dogs * beta + (n_te - n_dogs)
        print(f"  beta={beta}: cost reduction {(1 - hi_c / full_c):.1%}")

    print("\n=== Table 3 (paper's published counts, replayed exactly) ===")
    d = replay.DogReplay()
    print(f"offloaded: {d.n_offloaded} ({d.offloaded_dogs} dogs + "
          f"{d.false_positives} false positives); accuracy {d.accuracy:.1%}")
    print(f"HI cost: {d.offloaded_dogs}*beta + {d.false_positives}")
    for beta in (0.1, 0.5, 0.9):
        print(f"  beta={beta}: cost reduction {d.cost_reduction(beta):.1f}% "
              f"(paper range: 50-60%)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(ap.parse_args().fast)
