"""Continuous-batching scheduler: drain-equivalence on mixed-length traffic,
one compiled executable across buckets, the per-tick single-sync guarantee,
and per-request temperature / max-new-tokens / EOS semantics."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving import engine as engine_mod
from repro.serving.batcher import Request
from repro.serving.engine import build_engine

STEPS = 3


def _mixed_requests(cfg, lens, rng, steps=STEPS, temperature=0.0):
    return [Request(i, rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=steps, temperature=temperature)
            for i, L in enumerate(lens)]


def _drain_by_bucket(eng, reqs, temps=False):
    """Reference: serve uniform-bucket batches through the drain path."""
    out = {}
    for L in sorted({len(r.prompt) for r in reqs}):
        sub = [r for r in reqs if len(r.prompt) == L]
        toks = np.stack([r.prompt for r in sub])
        seeds = np.asarray([r.request_id for r in sub], np.int32)
        res = eng.serve(toks, seeds=seeds)
        for j, r in enumerate(sub):
            out[r.request_id] = {k: res[k][j] for k in res}
    return out


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m"])
def test_stream_matches_drain_on_mixed_lengths(arch):
    """serve_stream must produce token-identical greedy outputs (S tokens,
    merged tokens, confidence, offload set) to the drain path on mixed-length
    traffic — while compiling exactly ONE executable across all buckets."""
    cfg = ARCHS[arch].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=1.0)   # no capacity drops
    rng = np.random.default_rng(7)
    reqs = _mixed_requests(cfg, [8, 16, 8, 16, 8], rng)

    eng_d = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    drain = _drain_by_bucket(eng_d, reqs)
    eng_s = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    stream = eng_s.serve_stream(reqs, buckets=(8, 16), num_slots=3,
                                page_size=8)

    assert set(stream) == {r.request_id for r in reqs}
    for rid, rec in stream.items():
        np.testing.assert_array_equal(rec["tokens"], drain[rid]["tokens"])
        np.testing.assert_array_equal(rec["s_tokens"], drain[rid]["s_tokens"])
        assert rec["offloaded"] == bool(drain[rid]["offloaded"])
        np.testing.assert_allclose(rec["confidence"],
                                   drain[rid]["confidence"], atol=1e-5)
    # the paged pool removed the bucket from every device shape
    assert eng_s.stats["stream_compiles"] == 1
    # the drain path needed one executable per bucket
    assert eng_d.stats["compiles"] == 2


def test_stream_single_sync_per_tick(monkeypatch):
    """Each scheduler tick performs exactly ONE device->host sync, through
    the engine's ``_host_fetch`` — no hidden fetches in admission,
    escalation, or completion handling."""
    calls = []
    real = engine_mod._host_fetch
    monkeypatch.setattr(engine_mod, "_host_fetch",
                        lambda tree: (calls.append(1), real(tree))[1])
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=STEPS, cache_len=32)
    reqs = _mixed_requests(cfg, [8, 16, 8], np.random.default_rng(0))
    eng.serve_stream(reqs, buckets=(8, 16), num_slots=2, page_size=8)
    assert len(calls) == eng.stats["stream_ticks"] > 0


def test_stream_temperature_matches_drain():
    """Per-request seeded sampling: temp > 0 continuations are reproducible
    across the two schedulers (keys depend only on request id + token index,
    not slot / tick / batch row)."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)   # S-only: compare S tokens
    rng = np.random.default_rng(5)
    reqs = _mixed_requests(cfg, [8, 8, 16], rng, temperature=0.7)

    eng_d = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32,
                         temperature=0.7)
    drain = _drain_by_bucket(eng_d, reqs)
    eng_s = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    stream = eng_s.serve_stream(reqs, buckets=(8, 16), num_slots=2,
                                page_size=8)
    for rid, rec in stream.items():
        np.testing.assert_array_equal(rec["tokens"], drain[rid]["tokens"])
    # and the sampled path actually differs from greedy
    eng_g = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    greedy = _drain_by_bucket(eng_g, reqs)
    assert any(not np.array_equal(greedy[r]["tokens"], drain[r]["tokens"])
               for r in drain)


def test_stream_per_request_max_new_and_eos():
    """Unlike the drain path's engine-wide step count, the scheduler honours
    per-request max_new_tokens and stops early on EOS, freeing the slot."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)
    rng = np.random.default_rng(9)
    r_short = Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                      max_new_tokens=2)
    r_long = Request(1, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                     max_new_tokens=6)
    eng = build_engine(cfg, hi, max_new_tokens=6, cache_len=32)
    out = eng.serve_stream([r_short, r_long], buckets=(8,), num_slots=2,
                           page_size=8)
    assert len(out[0]["tokens"]) == 2
    assert len(out[1]["tokens"]) == 6

    # EOS: run greedy once, then replay with eos_id = the first token
    first = int(out[1]["tokens"][0])
    r_eos = Request(1, r_long.prompt, max_new_tokens=6, eos_id=first)
    eng2 = build_engine(cfg, hi, max_new_tokens=6, cache_len=32)
    out2 = eng2.serve_stream([r_eos], buckets=(8,), num_slots=2, page_size=8)
    assert len(out2[1]["tokens"]) == 1
    assert int(out2[1]["tokens"][0]) == first


def test_stream_slot_count_smaller_than_traffic():
    """More requests than slots: admission must recycle slots (the
    continuous part) and still serve everyone exactly once."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=1.1, capacity_factor=1.0)   # everything escalates
    rng = np.random.default_rng(4)
    reqs = _mixed_requests(cfg, [8] * 5, rng)
    eng = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    out = eng.serve_stream(reqs, buckets=(8,), num_slots=2, page_size=8)
    assert len(out) == 5
    assert all(rec["offloaded"] and rec["served_remote"]
               for rec in out.values())
    assert eng.stats["offloaded"] == 5
