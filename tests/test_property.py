"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import calibrate
from repro.core.cost import per_sample_cost, total_cost
from repro.core.router import route, scatter_merge
from repro.models import moe as moe_mod

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
@given(st.integers(2, 200), st.floats(0.0, 0.999), st.integers(0, 2 ** 31 - 1))
def test_cost_bounds_and_monotonicity(n, beta, seed):
    rng = np.random.default_rng(seed)
    off = rng.random(n) < 0.5
    s_ok = rng.random(n) < 0.7
    l_ok = rng.random(n) < 0.95
    c = np.asarray(per_sample_cost(jnp.asarray(off), jnp.asarray(s_ok),
                                   jnp.asarray(l_ok), beta))
    # per-sample cost in [0, 1 + beta]
    assert (c >= -1e-6).all() and (c <= 1.0 + beta + 1e-6).all()
    # total cost is monotone nondecreasing in beta (same decisions)
    t1 = float(total_cost(jnp.asarray(off), jnp.asarray(s_ok),
                          jnp.asarray(l_ok), beta))
    t2 = float(total_cost(jnp.asarray(off), jnp.asarray(s_ok),
                          jnp.asarray(l_ok), min(beta + 0.1, 0.999)))
    assert t2 >= t1 - 1e-6


@given(st.integers(5, 300), st.floats(0.01, 0.99), st.integers(0, 2 ** 31 - 1))
def test_brute_force_theta_never_beaten(n, beta, seed):
    """theta* from the sweep must beat every random threshold."""
    rng = np.random.default_rng(seed)
    conf = rng.random(n)
    s_ok = rng.random(n) < conf        # calibrated-ish
    th, c = calibrate.brute_force_theta(conf, s_ok, beta)
    for t in rng.random(16):
        naive = np.sum(np.where(conf < t, beta, 1.0 - s_ok))
        assert c <= naive + 1e-9


# ---------------------------------------------------------------------------
# router invariants
# ---------------------------------------------------------------------------
@given(st.integers(2, 128), st.integers(1, 128), st.integers(0, 2 ** 31 - 1))
def test_route_invariants(n, cap_raw, seed):
    cap = min(cap_raw, n)
    rng = np.random.default_rng(seed)
    conf = jnp.asarray(rng.random(n).astype(np.float32))
    mask = conf < 0.5
    d = route(mask, conf, cap)
    served = np.asarray(d.served_remote)
    maskn = np.asarray(mask)
    # served is a subset of the policy mask
    assert not (served & ~maskn).any()
    # capacity respected
    assert served.sum() <= cap
    # conservation: served + dropped = wanted
    assert served.sum() + int(d.dropped) == int(maskn.sum())
    # indices are unique
    idx = np.asarray(d.indices)
    assert len(set(idx.tolist())) == len(idx)


@given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
def test_scatter_merge_identity_off_served(n, seed):
    rng = np.random.default_rng(seed)
    conf = jnp.asarray(rng.random(n).astype(np.float32))
    mask = conf < 0.4
    cap = max(1, n // 2)
    d = route(mask, conf, cap)
    s_out = jnp.asarray(rng.integers(0, 100, n))
    l_out = jnp.asarray(rng.integers(100, 200, cap))
    merged = np.asarray(scatter_merge(s_out, l_out, d))
    served = np.asarray(d.served_remote)
    # non-served positions keep the S output
    np.testing.assert_array_equal(merged[~served], np.asarray(s_out)[~served])
    # served positions hold an L output
    assert (merged[served] >= 100).all()


# ---------------------------------------------------------------------------
# MoE dispatch conservation
# ---------------------------------------------------------------------------
@given(st.integers(2, 6), st.integers(1, 2), st.integers(0, 2 ** 31 - 1))
def test_moe_capacity_math(e, k, seed):
    k = min(k, e)
    t = 32
    cap = moe_mod.moe_capacity(t, e, k, 1.0)
    assert cap * e >= t * k          # full capacity covers all assignments


# ---------------------------------------------------------------------------
# SSD semantics under random shapes
# ---------------------------------------------------------------------------
@given(st.integers(1, 2), st.sampled_from([16, 24, 40]), st.integers(1, 3),
       st.sampled_from([4, 8]), st.sampled_from([4, 8]),
       st.integers(0, 2 ** 31 - 1))
def test_ssd_chunked_equals_recurrence(b, l, h, p, n, seed):
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, l, h)), jnp.float32) * 0.5
    A = -jnp.asarray(rng.random(h), jnp.float32) - 0.3
    B = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    y_c, _ = ref.ssd_ref(x, dt, A, B, C, chunk=16)
    y_n = ref.ssd_naive_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# confidence metric ranges
# ---------------------------------------------------------------------------
@given(st.integers(1, 32), st.integers(2, 50), st.integers(0, 2 ** 31 - 1))
def test_confidence_ranges(n, c, seed):
    from repro.core.confidence import confidence
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(n, c)) * 5, jnp.float32)
    for metric in ("max_prob", "margin", "entropy"):
        v = np.asarray(confidence(logits, metric))
        assert (v >= -1e-5).all() and (v <= 1.0 + 1e-5).all()
    # max_prob lower bound: 1/C
    mp = np.asarray(confidence(logits, "max_prob"))
    assert (mp >= 1.0 / c - 1e-6).all()


# ---------------------------------------------------------------------------
# quantized pool: allocator invariants with scale rows attached
# ---------------------------------------------------------------------------
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "admit",
                                           "truncate"]),
                          st.integers(0, 3),
                          st.sampled_from([8, 12, 16, 20])),
                min_size=1, max_size=25),
       st.integers(0, 5))
def test_int8_pool_invariants_under_random_ops(ops, nprompts):
    """Random alloc / free / prefix-admission (aliasing, COW tail pages,
    eviction) / truncate sequences on an int8 pool keep every refcount
    invariant PLUS the scale-row accounting (`check_invariants` asserts the
    fp32 scale tensors stay one-row-per-physical-page beside the int8
    pools).  Hashed prompts repeat, so admissions alias retained pages and
    non-page-aligned buckets schedule COW copies."""
    from repro.configs.registry import ARCHS
    from repro.serving.kv_pool import KVPool

    cfg = ARCHS["qwen2-1.5b"].reduced()
    pool = KVPool(cfg, num_slots=4, max_context=32, page_size=8,
                  dtype=jnp.int8, prefix_entries=2)
    pool.check_invariants()
    for tick, (op, slot, bucket) in enumerate(ops):
        try:
            if op == "alloc":
                pool.alloc(slot, bucket + 4, tick=tick)
            elif op == "free":
                pool.free(slot)
            elif op == "admit":
                # small prompt-identity space -> repeats hit the index;
                # 12/20 buckets have partial tail pages -> COW on restore
                pid = (slot + bucket) % max(nprompts + 1, 1)
                hashes = [bytes([pid, i]) for i in range(4)]
                full = bytes([pid, 0xFF, bucket])
                pool.admit_prefix(slot, bucket + 4, bucket, hashes, full,
                                  tick)
            elif op == "truncate":
                pool.truncate(slot, bucket)
        except ValueError:
            pass          # exhaustion / double-free / shared-page rewind
        pool.check_invariants()
    for slot in list(pool.held_slots):
        pool.free(slot)
    pool.check_invariants()
