"""Prefix-sharing page pool, end to end: greedy serve_stream outputs are
token-identical with sharing ON vs OFF per decoder family (while actually
hitting the cache), repeated escalations skip L-tier prefill, pool-exhaustion
backpressure admits via retry without leaking pages, and the L-queue latency
drop policy (arXiv:2112.11413) keeps the S answer for expired escalations."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.batcher import Request
from repro.serving.engine import build_engine

STEPS = 3

# one reduced config per decoder family: dense (partial-hit + COW capable),
# moe (batch-coupled routing), ssm + hybrid (whole-prompt snapshot/restore)
FAMS = ["qwen2-1.5b", "deepseek-moe-16b", "mamba2-370m", "zamba2-2.7b"]


def _repeated_prefix_traffic(cfg, seed=3):
    """Shared 8-token system prefix + repeats: p1 lands in the 12-bucket
    (partial tail page -> copy-on-write on restore), p2/p3 in the 16-bucket
    (page-aligned).  Repeats exercise full restores; p2 vs p3 share only the
    prefix pages (partial hit, attention families)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def mk(n):
        return np.concatenate(
            [base, rng.integers(0, cfg.vocab_size, n).astype(np.int32)])

    p1, p2, p3 = mk(4), mk(8), mk(8)
    order = [p1, p2, p3, p1, p2, p3, p1, p2]
    return [Request(i, p, max_new_tokens=STEPS) for i, p in enumerate(order)]


def _assert_stream_equal(on, off):
    assert set(on) == set(off)
    for rid in off:
        np.testing.assert_array_equal(on[rid]["tokens"], off[rid]["tokens"])
        np.testing.assert_array_equal(on[rid]["s_tokens"],
                                      off[rid]["s_tokens"])
        assert on[rid]["offloaded"] == off[rid]["offloaded"]
        assert on[rid]["served_remote"] == off[rid]["served_remote"]
        np.testing.assert_allclose(on[rid]["confidence"],
                                   off[rid]["confidence"], atol=1e-6)


@pytest.mark.parametrize("arch", FAMS)
def test_sharing_equivalence_per_family(arch):
    """Greedy outputs must be bitwise token-identical with prefix sharing on
    vs off on mixed-bucket repeated-prefix traffic — while the cache actually
    hits (full restores for every family; partial hits + COW for the dense
    family via the non-page-aligned 12 bucket) and both engines keep ONE
    compiled stream executable."""
    cfg = ARCHS[arch].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=1.0)
    reqs = _repeated_prefix_traffic(cfg)
    kw = dict(buckets=(12, 16), num_slots=2, page_size=8)

    eng_on = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    on = eng_on.serve_stream(reqs, prefix_sharing=True, **kw)
    eng_off = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    off = eng_off.serve_stream(reqs, prefix_sharing=False, **kw)

    _assert_stream_equal(on, off)
    sched = eng_on._stream[1]
    stats = sched.prefix_stats
    assert stats["full_hits"] > 0                  # repeats restored
    assert stats["tokens_saved"] > 0
    assert eng_on.stats["prefill_tokens_saved"] == stats["tokens_saved"]
    if arch == "qwen2-1.5b":
        assert stats["hits"] > stats["full_hits"]  # partial hits too
        assert stats["cow_copies"] > 0             # 12-bucket tail page
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()
    assert eng_on.stats["stream_compiles"] == 1
    assert eng_off.stats["stream_compiles"] == 1


def test_warm_cache_replay_stays_equivalent():
    """A second serve_stream call reuses the scheduler AND its prefix index:
    every repeated prompt full-restores (S and L tier), outputs stay
    identical to a sharing-off engine, and invariants hold after drain."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=1.1, capacity_factor=1.0)   # everything escalates
    reqs = _repeated_prefix_traffic(cfg)
    kw = dict(buckets=(12, 16), num_slots=2, page_size=8)
    eng_on = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    eng_off = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    eng_on.serve_stream(reqs, prefix_sharing=True, **kw)
    sched = eng_on._stream[1]
    hits0 = sched.prefix_stats["full_hits"]
    l_saved0 = sched.lrt.pool.stats["tokens_saved"]
    on = eng_on.serve_stream(reqs, prefix_sharing=True, **kw)
    off = eng_off.serve_stream(reqs, prefix_sharing=False, **kw)
    _assert_stream_equal(on, off)
    # warm replay: every admission on BOTH tiers is a full restore, so the
    # repeated escalations skipped L-tier prefill compute entirely
    assert sched.prefix_stats["full_hits"] >= hits0 + len(reqs)
    assert sched.lrt.pool.stats["tokens_saved"] > l_saved0
    assert eng_on.stats["stream_compiles"] == 1     # replay never recompiles
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()


def test_pool_exhaustion_backpressure_retries():
    """Traffic sized to exhaust the page pool mid-run: admission must retry
    (requeue at the head) instead of crashing, serve every request exactly
    once, and leak no pages (invariants after drain)."""
    from repro.serving.batcher import AdmissionQueue
    from repro.serving.scheduler import ContinuousScheduler

    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)
    eng = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    # 3 slots but pages for ~1.5 full-context sequences: slots outnumber
    # pages, so admission hits pool exhaustion while slots are still free
    sched = ContinuousScheduler(
        eng.s, eng.l, hi, max_prompt_len=16, max_new_tokens=STEPS,
        num_slots=3, l_slots=2, page_size=8, decode_block=2,
        prefix_sharing=True, num_pages=6)
    queue = AdmissionQueue(buckets=(16,), page_size=8)
    rng = np.random.default_rng(5)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=STEPS) for i in range(6)]
    for r in reqs:
        queue.submit(r)
    results = sched.run(queue)
    assert set(results) == set(range(6))
    assert all(len(r["tokens"]) == STEPS for r in results.values())
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()
    # after drain every slot's pages are back (only index retention remains)
    assert sched.srt.busy == 0 and sched.lrt.busy == 0


def test_latency_budget_drop_policy():
    """arXiv:2112.11413: an escalation past its latency budget is dropped
    from the L queue — the S-tier answer stands, the record is flagged, and
    stats['dropped'] counts it; unbudgeted requests still escalate."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=1.1, capacity_factor=1.0)   # everything escalates
    rng = np.random.default_rng(9)
    expired = Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                      max_new_tokens=STEPS, latency_budget=0.0)
    patient = Request(1, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                      max_new_tokens=STEPS, latency_budget=None)
    eng = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    out = eng.serve_stream([expired, patient], buckets=(8,), num_slots=2,
                           page_size=8)
    assert out[0]["dropped"] and not out[0]["served_remote"]
    assert out[0]["offloaded"]                      # it WANTED to escalate
    np.testing.assert_array_equal(out[0]["tokens"], out[0]["s_tokens"])
    assert not out[1]["dropped"] and out[1]["served_remote"]
    assert eng.stats["dropped"] == 1

    # the S answer must be exactly what an unbudgeted run produces on S
    eng2 = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    ref = eng2.serve_stream([Request(0, expired.prompt,
                                     max_new_tokens=STEPS)],
                            buckets=(8,), num_slots=2, page_size=8)
    np.testing.assert_array_equal(out[0]["tokens"], ref[0]["s_tokens"])


def test_same_tick_row_recycling_keeps_restore_intact():
    """Regression: with a single prefix-cache row, a tick that BOTH restores
    from the row and (via same-tick LRU eviction) recycles it for a new
    admission's save must restore the PRE-SAVE state — the recurrent
    families read the snapshot before this tick's save scatter lands."""
    cfg = ARCHS["mamba2-370m"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    kw = dict(buckets=(16,), num_slots=2, page_size=8, prefix_entries=1)
    outs = {}
    for sharing in (True, False):
        eng = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
        eng.serve_stream([Request(0, p1, max_new_tokens=STEPS)],
                         prefix_sharing=sharing, **kw)
        # one tick admits BOTH: rid 1 restores row 0, rid 2 evicts + reuses it
        outs[sharing] = eng.serve_stream(
            [Request(1, p1, max_new_tokens=STEPS),
             Request(2, p2, max_new_tokens=STEPS)],
            prefix_sharing=sharing, **kw)
    _assert_stream_equal(outs[True], outs[False])


def test_cow_kernel_matches_jnp_path():
    """The Pallas page-copy kernel (scalar-prefetched source map) must match
    the jnp scatter for dense and hybrid pool layouts, including padded
    (0, 0) no-op pairs."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    from repro.models import layers as L

    rng = np.random.default_rng(0)
    for shape in [(2, 6, 4, 2, 3), (3, 5, 8, 1, 4)]:
        pool = jnp.asarray(rng.normal(size=shape), jnp.float32)
        src = jnp.asarray([4, 2, 0], jnp.int32)
        dst = jnp.asarray([1, 3, 0], jnp.int32)
        out_k = np.asarray(kops.copy_pages(pool, src, dst))
        out_j = np.asarray(L.cow_copy_pages(pool, src, dst))
        np.testing.assert_array_equal(out_k, out_j)
        np.testing.assert_array_equal(out_k[:, 1], np.asarray(pool[:, 4]))
        np.testing.assert_array_equal(out_k[:, 3], np.asarray(pool[:, 2]))
        np.testing.assert_array_equal(out_k[:, 0], np.asarray(pool[:, 0]))
