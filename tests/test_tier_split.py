"""Tier-split deployment: each tier lowers+compiles on its own pod
(subprocess: needs 512 forced host devices)."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.configs.registry import ARCHS
    from repro.configs.base import SHAPES
    from repro.launch.tier_split import lower_tier_split
    r = lower_tier_split(ARCHS["qwen2-1.5b"], SHAPES["decode_32k"],
                         capacity_factor=0.5)
    assert r.s_compile["chips"] == 256 and r.l_compile["chips"] == 256
    assert r.s_compile["peak_gb_per_device"] < r.l_compile["peak_gb_per_device"]
    assert 0 < r.beta_bytes_per_step < 1e9
    print("TIER_SPLIT_OK", r.beta_bytes_per_step)
""")


def test_tier_split_lowers_both_pods():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert "TIER_SPLIT_OK" in out.stdout, out.stdout + out.stderr
