"""Decision-quality observability invariants (PR 9).

* the streaming reliability bins / ECE match a ``core/calibrate.p_histogram``
  NumPy oracle on the same decision stream (unit stream incl. bin-edge
  values, AND the ground-truthed records of a real speculative run);
* audit-enabled greedy output is token-identical to disabled, with
  ``stream_compiles == 1`` and ONE ``_host_fetch`` per tick — in BOTH
  ``kv_dtype`` modes (the audit rides the existing sync);
* per-traffic-class offload rates agree with the result records'
  ``offloaded`` flags (``Request.tclass`` threading);
* the speculative verify lane feeds per-position ground truth and the
  empirical-regret counters reconcile with the record stream;
* the SLO watchdog emits breaches as telemetry instant events, rendered in
  the Chrome trace, and ``hi_audit_*`` families (with ``# HELP``) appear in
  ``prometheus_text`` — whose histogram overflow bucket must NOT report a
  finite ``le`` edge (satellite fix).
"""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.core.calibrate import p_histogram
from repro.serving import engine as engine_mod
from repro.serving import trace_export
from repro.serving.audit import (GateAudit, ReliabilityBins, SLOThresholds,
                                 SLOWatchdog)
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.flight_recorder import FlightRecorder
from repro.serving.telemetry import Telemetry

STEPS = 3
KW = dict(buckets=(8, 16), num_slots=3, l_slots=2, page_size=8)

_STATE = {}


def _requests(n=7, tclass=False):
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(n):
        ln = int(rng.integers(4, 16))
        reqs.append(Request(i, rng.integers(0, 500, ln).astype(np.int32),
                            max_new_tokens=STEPS,
                            tclass=("interactive", "batch")[i % 2]
                            if tclass else ""))
    return reqs


def _eng(kv_dtype="bf16"):
    if kv_dtype not in _STATE:
        cfg = ARCHS["qwen2-1.5b"].reduced()
        _STATE[kv_dtype] = build_engine(
            cfg, HIConfig(theta=0.6, capacity_factor=1.0),
            max_new_tokens=STEPS, cache_len=32)
    return _STATE[kv_dtype]


# ---------------------------------------------------------------------------
# streaming bins vs the p_histogram NumPy oracle
# ---------------------------------------------------------------------------

def test_reliability_bins_match_p_histogram_oracle():
    rng = np.random.default_rng(0)
    conf = rng.random(500)
    # include every edge case the bin rule must get right: exact bin edges,
    # 0.0, and 1.0 (np.histogram closes the last bin)
    conf = np.concatenate([conf, np.linspace(0.0, 1.0, 21), [0.0, 1.0]])
    ok = rng.random(conf.size) < conf          # roughly calibrated stream
    bins = ReliabilityBins(bins=20)
    for c, o in zip(conf, ok):
        bins.record(float(c), bool(o))
    oracle = p_histogram(conf, ok.astype(np.float32), bins=20)
    np.testing.assert_array_equal(bins.edges, oracle["edges"])
    np.testing.assert_array_equal(bins.correct, oracle["correct"])
    np.testing.assert_array_equal(bins.incorrect, oracle["incorrect"])
    # ECE against a direct NumPy evaluation of the definition
    n_b = bins.correct + bins.incorrect
    idx = np.clip(np.searchsorted(bins.edges, conf, side="right") - 1,
                  0, 19)
    conf_sum = np.bincount(idx, weights=conf, minlength=20)
    live = n_b > 0
    ece = np.sum(n_b[live] / conf.size
                 * np.abs(bins.correct[live] / n_b[live]
                          - conf_sum[live] / n_b[live]))
    assert bins.ece() == pytest.approx(float(ece))
    assert bins.count == conf.size


def test_spec_run_bins_match_oracle_on_recorded_stream():
    """The verify lane's ground-truthed records, replayed through the
    oracle, must reproduce the audit's streaming bins exactly."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.9, capacity_factor=1.0),
                       max_new_tokens=6, cache_len=48)
    aud = GateAudit(bins=20)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=6) for i in range(4)]
    eng.serve_stream(reqs, buckets=(8,), num_slots=2, page_size=8,
                     decode_block=4, speculative=True, audit=aud)
    truthed = [r for r in aud.records if r.ok is not None]
    assert truthed, "the verify lane must produce ground truth every tick"
    assert {r.kind for r in truthed} == {"draft"}
    conf = np.array([r.conf for r in truthed])
    ok = np.array([r.ok for r in truthed], np.float32)
    oracle = p_histogram(conf, ok, bins=20)
    np.testing.assert_array_equal(aud.overall.correct, oracle["correct"])
    np.testing.assert_array_equal(aud.overall.incorrect, oracle["incorrect"])
    assert aud.outcomes == len(truthed)
    # regret counters reconcile with the raw stream
    wasted = sum(1 for r in truthed if r.offload and r.ok)
    missed = sum(1 for r in truthed if not r.offload and not r.ok)
    assert aud.wasted_offload == wasted and aud.missed_local == missed
    assert aud.regret_cost == pytest.approx(
        wasted * aud.beta + missed * (1 - aud.beta))


# ---------------------------------------------------------------------------
# audit on == audit off, one sync per tick, both kv_dtype modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_audit_token_identical_and_single_sync(kv_dtype, monkeypatch):
    eng = _eng(kv_dtype)
    base = eng.serve_stream(_requests(), validate=True, kv_dtype=kv_dtype,
                            **KW)
    syncs = {"n": 0}
    real = engine_mod._host_fetch

    def counting(x):
        syncs["n"] += 1
        return real(x)

    monkeypatch.setattr(engine_mod, "_host_fetch", counting)
    aud = GateAudit()
    ticks0 = eng.stats["stream_ticks"]
    on = eng.serve_stream(_requests(), validate=True, kv_dtype=kv_dtype,
                          audit=aud, **KW)
    assert syncs["n"] == eng.stats["stream_ticks"] - ticks0, \
        "the audit must ride the tick's ONE existing host fetch"
    assert eng.stats["stream_compiles"] == 1
    assert set(base) == set(on)
    for rid in base:
        np.testing.assert_array_equal(base[rid]["tokens"], on[rid]["tokens"])
        assert base[rid]["status"] == on[rid]["status"]
    assert aud.decisions > 0
    # plain mode: every completed escalation yields one agreement sample
    remote = sum(1 for r in on.values() if r["served_remote"])
    l_agree = [r for r in aud.records if r.kind == "l_agree"]
    assert len(l_agree) == remote == aud.outcomes


# ---------------------------------------------------------------------------
# traffic classes
# ---------------------------------------------------------------------------

def test_per_tclass_offload_rates_match_records():
    eng = _eng()
    aud = GateAudit()
    res = eng.serve_stream(_requests(tclass=True), validate=True, audit=aud,
                           **KW)
    reqs = _requests(tclass=True)
    by_class = {}
    for r in reqs:
        by_class.setdefault(r.tclass, []).append(res[r.request_id])
    assert set(aud.classes) == set(by_class)
    for t, recs in by_class.items():
        off = sum(1 for r in recs if r["offloaded"])
        assert aud.classes[t].requests == len(recs)
        assert aud.classes[t].offloaded == off
        assert aud.offload_rate(t) == pytest.approx(off / len(recs))
    total_off = sum(1 for r in res.values() if r["offloaded"])
    assert aud.offload_rate() == pytest.approx(total_off / len(res))
    assert aud.ece("no-such-class") == 0.0


# ---------------------------------------------------------------------------
# watchdog + exporters
# ---------------------------------------------------------------------------

def test_watchdog_breaches_reach_trace_and_recorder(tmp_path):
    eng = _eng()
    tel = Telemetry()
    aud = GateAudit()
    wd = SLOWatchdog(SLOThresholds(queue_depth=0, offload_rate_max=0.0,
                                   min_requests=1))
    fr = FlightRecorder(capacity=8)
    res = eng.serve_stream(_requests(), telemetry=tel, audit=aud,
                           watchdog=wd, flight_recorder=fr, validate=True,
                           **KW)
    assert any(r["offloaded"] for r in res.values()), \
        "need offloads for the drift threshold to trip"
    kinds = {b["kind"] for b in wd.breaches}
    assert "offload_rate" in kinds
    names = {n for _, n, _ in tel.events}
    assert any(n.startswith("slo_breach:offload_rate") for n in names)
    # breaches render as Chrome instant events on the scheduler track, and
    # audit aggregates become counter tracks via the tick gauges
    doc = trace_export.chrome_trace(tel)
    ev = doc["traceEvents"]
    assert any(e["ph"] == "i" and e.get("cat") == "slo" for e in ev)
    assert any(e["ph"] == "C" and e["name"] == "audit_ece" for e in ev)
    assert min(e["ts"] for e in ev if "ts" in e) >= 0.0
    # every breach froze a dump; snapshots carry the audit aggregates
    assert fr.dumps and fr.last_dump["reason"].startswith("slo_breach:")
    assert all("audit_ece" in s["gauges"] for s in fr.last_dump["ring"])
    assert all("serve_time" not in s["counters"]
               for s in fr.last_dump["ring"])


def test_prometheus_audit_families_and_overflow_bucket():
    eng = _eng()
    tel = Telemetry()
    aud = GateAudit()
    eng.serve_stream(_requests(tclass=True), telemetry=tel, audit=aud, **KW)
    txt = tel.prometheus_text()
    for key in ("# HELP hi_requests_total", "# HELP hi_gauge",
                "# HELP hi_audit_ece", "hi_audit_decisions_total",
                "hi_audit_outcomes_total",
                'hi_audit_regret_total{kind="wasted_offload"}',
                'hi_audit_ece{tclass="interactive"}',
                'hi_audit_offload_rate{tclass="batch"}',
                "hi_audit_theta_margin_count"):
        assert key in txt, f"missing Prometheus key: {key}"
    assert "hi_audit_reliability_total" in txt
    # satellite fix: the unbounded overflow bucket must fold into +Inf —
    # no finite ``le`` edge may exceed the last BOUNDED bucket's edge
    h = tel.hists["ttft"]
    h.record(1e6)                               # lands in the overflow bucket
    txt = tel.prometheus_text()
    finite_les = [float(line.split('le="')[1].split('"')[0])
                  for line in txt.splitlines()
                  if line.startswith("hi_ttft_seconds_bucket")
                  and "+Inf" not in line]
    assert finite_les, "bounded buckets must still be emitted"
    assert max(finite_les) <= h.upper_edge(h.n_buckets - 2)
    inf_line = [ln for ln in txt.splitlines()
                if ln.startswith('hi_ttft_seconds_bucket{le="+Inf"}')]
    assert inf_line and int(inf_line[0].split()[-1]) == h.count


def test_label_escaping():
    eng = _eng()
    tel = Telemetry()
    aud = GateAudit()
    reqs = _requests(3)
    for r in reqs:
        r.tclass = 'we"ird\nclass\\x'
    eng.serve_stream(reqs, telemetry=tel, audit=aud, **KW)
    txt = tel.prometheus_text()
    assert 'tclass="we\\"ird\\nclass\\\\x"' in txt
    assert 'we"ird\nclass' not in txt
