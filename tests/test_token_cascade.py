"""Block-granularity token-level HI (serving/token_cascade.py)."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.token_cascade import build_token_cascade


def _pure_greedy(tc, params, cfg, prompt, steps):
    from repro.serving.token_cascade import _feed_tokens, _draft_block
    import jax.numpy as jnp
    from repro.models import model_zoo
    cache = model_zoo.init_cache(cfg, prompt.shape[0], tc.cache_len)
    cache, logits = _feed_tokens(params, cfg, cache, jnp.asarray(prompt))
    toks, _, _, _ = _draft_block(params, cfg, cache, logits, steps,
                                 "max_prob")
    return np.asarray(toks)


@pytest.fixture(scope="module")
def setup():
    cfg = ARCHS["gemma3-1b"].reduced()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    return cfg, prompt


def test_never_escalate_equals_pure_s(setup):
    cfg, prompt = setup
    tc = build_token_cascade(cfg, HIConfig(theta=0.0), block=3, cache_len=32)
    out = tc.generate(prompt, num_blocks=2)
    assert out["escalated"] == 0
    ref = _pure_greedy(tc, tc.s_params, tc.s_cfg, prompt, 6)
    np.testing.assert_array_equal(out["tokens"], ref)


def test_always_escalate_equals_pure_l(setup):
    cfg, prompt = setup
    tc = build_token_cascade(cfg, HIConfig(theta=1.1), block=3, cache_len=32)
    out = tc.generate(prompt, num_blocks=2)
    assert out["escalated"] == 2
    assert out["escalation_frac"] == 1.0
    ref = _pure_greedy(tc, tc.l_params, tc.l_cfg, prompt, 6)
    np.testing.assert_array_equal(out["tokens"], ref)


def test_intermediate_theta_counts(setup):
    cfg, prompt = setup
    tc = build_token_cascade(cfg, HIConfig(theta=0.5), block=3, cache_len=32)
    out = tc.generate(prompt, num_blocks=3)
    assert out["tokens"].shape == (2, 9)
    assert 0 <= out["escalated"] <= 3
