"""Unit tests for the HI core, pinned to the paper's published numbers."""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HIConfig
from repro.core import calibrate, replay
from repro.core.baselines import (TimingModel, full_offload, oma, omd,
                                  partition_per_sample_ms, tinyml)
from repro.core.cascade import classifier_cascade
from repro.core.cost import CostReport, cost_closed_form, relative_cost_reduction
from repro.core.policy import (BinaryRelevancePolicy, OnlineThresholdPolicy,
                               ThresholdPolicy)
from repro.core.router import route, scatter_merge


# ---------------------------------------------------------------------------
# paper-number replay (Table 1, Table 3, Fig. 8)
# ---------------------------------------------------------------------------
def test_table1_exact():
    t = replay.table1(beta=0.5)
    hi = t["hi"]
    assert hi.offloaded == 3550
    assert hi.misclassified == 1648
    assert abs(hi.accuracy - 0.8352) < 1e-12
    assert hi.cost == 3550 * 0.5 + 1648
    assert t["full_offload"].cost == 10_000 * 0.5 + 500
    assert t["no_offload"].cost == 3742


def test_table1_cost_reduction_range():
    """Paper: 14–49% relative reduction vs full offload (beta in ~[0.25, 1])."""
    lo = replay.table1_cost_reduction(0.25)
    hi = replay.table1_cost_reduction(0.999)
    assert 13.0 < lo < 20.0
    assert 45.0 < hi < 52.0


def test_table3_dog_filter():
    d = replay.DogReplay()
    assert d.n_offloaded == 4433
    assert abs(d.accuracy - 0.912) < 1e-12
    assert d.cost_hi(0.5) == 912 * 0.5 + 3521
    # paper: 50-60% cost reduction across beta
    for beta in (0.01, 0.5, 0.99):
        assert 50.0 < d.cost_reduction(beta) < 61.0


def test_fig8_headline_numbers():
    f = replay.fig8_hi_vs_full_offload(0.5)
    assert abs(f["latency_reduction_pct"] - 63.15) < 0.2   # paper: 63.15%
    assert abs(f["offload_reduction_pct"] - 64.45) < 0.2   # paper: 64.45%
    assert abs(f["hi_accuracy_pct"] - 83.52) < 1e-9


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def test_cost_closed_form_matches_report():
    r = CostReport("x", 100, 30, 5, 2, beta=0.4)
    assert r.cost == cost_closed_form(30, 5, 2, 0.4)
    assert r.accuracy == 1 - 7 / 100


def test_relative_cost_reduction():
    assert relative_cost_reduction(50, 100) == 50.0


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------
def test_brute_force_theta_optimal():
    rng = np.random.default_rng(1)
    conf = rng.random(1000)
    s_ok = rng.random(1000) < conf
    th, c = calibrate.brute_force_theta(conf, s_ok, beta=0.3)
    grid = np.linspace(0, 1, 1001)
    naive = min(np.sum(np.where(conf < t, 0.3, 1.0 - s_ok)) for t in grid)
    assert c <= naive + 1e-9


def test_theta_extremes():
    conf = np.array([0.1, 0.9])
    # S-ML always wrong -> offload everything: theta* ~ 1
    th, _ = calibrate.brute_force_theta(conf, np.array([False, False]), beta=0.1)
    assert th > 0.9
    # S-ML always right & beta high -> keep everything: theta* = 0
    th, _ = calibrate.brute_force_theta(conf, np.array([True, True]), beta=0.9)
    assert th <= 0.1 + 1e-9


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
def test_threshold_policy_rule():
    p = ThresholdPolicy(theta=0.6)
    conf = jnp.asarray([0.59, 0.6, 0.61])
    np.testing.assert_array_equal(np.asarray(p.offload(conf)),
                                  [True, False, False])


def test_binary_relevance_policy_rule():
    p = BinaryRelevancePolicy(theta=0.5)
    conf = jnp.asarray([0.49, 0.5, 0.9])
    np.testing.assert_array_equal(np.asarray(p.offload(conf)),
                                  [False, True, True])


def test_online_policy_converges_toward_optimum():
    """With S-ML always right and beta small-but-nonzero the best threshold is
    low; with S-ML always wrong it is high."""
    rng = np.random.default_rng(0)
    conf = rng.random(800)
    pol = OnlineThresholdPolicy(beta=0.2, grid=64, eta_lr=0.3)
    pol.update(conf, np.ones_like(conf, bool))     # always right
    assert pol.theta < 0.25
    pol2 = OnlineThresholdPolicy(beta=0.2, grid=64, eta_lr=0.3)
    pol2.update(conf, np.zeros_like(conf, bool))   # always wrong
    assert pol2.theta > 0.75


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
def test_route_respects_capacity_and_priority():
    conf = jnp.asarray([0.1, 0.9, 0.2, 0.8, 0.05])
    mask = conf < 0.5            # 3 want offload
    d = route(mask, conf, capacity=2)
    assert int(d.valid.sum()) == 2
    assert int(d.dropped) == 1
    # the two LOWEST-confidence offloads are served
    served_idx = set(np.asarray(d.indices)[np.asarray(d.valid)])
    assert served_idx == {0, 4}


def test_scatter_merge_only_replaces_served():
    conf = jnp.asarray([0.1, 0.9, 0.2])
    mask = conf < 0.5
    d = route(mask, conf, capacity=2)
    s_out = jnp.asarray([10, 20, 30])
    l_out = jnp.asarray([111, 333])[jnp.argsort(d.indices[d.valid])] \
        if False else jnp.asarray([1, 2])
    merged = scatter_merge(s_out, l_out, d)
    m = np.asarray(merged)
    assert m[1] == 20                       # not offloaded -> untouched
    assert set(m[[0, 2]]) == {1, 2}          # offloaded -> L outputs


def test_cascade_full_and_never_offload_limits():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    Ws = jnp.asarray(rng.normal(size=(8, 10)), jnp.float32)
    Wl = jnp.asarray(rng.normal(size=(8, 10)) * 10, jnp.float32)
    apply_fn = lambda p, xx: xx @ p
    # theta=0 -> never offload; predictions == S predictions
    c0 = classifier_cascade(apply_fn, apply_fn, HIConfig(theta=0.0,
                                                         capacity_factor=1.0))
    out0 = c0.infer(Ws, Wl, x)
    np.testing.assert_array_equal(np.asarray(out0["pred"]),
                                  np.asarray(out0["s_pred"]))
    assert int(out0["n_offloaded"]) == 0
    # theta=1+ -> offload all (capacity 1.0): predictions == L predictions
    c1 = classifier_cascade(apply_fn, apply_fn, HIConfig(theta=1.1,
                                                         capacity_factor=1.0))
    out1 = c1.infer(Ws, Wl, x)
    l_pred = np.argmax(np.asarray(x @ Wl), -1)
    np.testing.assert_array_equal(np.asarray(out1["pred"]), l_pred)
    assert int(out1["n_offloaded"]) == 32


# ---------------------------------------------------------------------------
# baselines + timing model (Appendix tables)
# ---------------------------------------------------------------------------
def test_partitioning_always_worse_than_full_offload():
    """Appendix: every split point is dominated by full offload (74.34 ms)."""
    for layer in range(1, 8):
        assert partition_per_sample_ms(layer) > partition_per_sample_ms(0)
    # Table 6 row check: split at layer 1 in [618.1, 651.83] ms
    assert 600 < partition_per_sample_ms(1) < 660


def test_omd_balances_makespan():
    tm = TimingModel()
    s_ok = np.ones(1000, bool)
    l_ok = np.ones(1000, bool)
    r = omd(s_ok, l_ok, tm)
    k = r.n - r.n_offloaded
    assert abs(k * tm.t_local_ms - r.n_offloaded * tm.t_offload_ms) \
        <= max(tm.t_local_ms, tm.t_offload_ms) * 2


def test_oma_worst_case_is_worst():
    rng = np.random.default_rng(5)
    s_ok = rng.random(500) < 0.6
    l_ok = rng.random(500) < 0.95
    tm = TimingModel()
    budget = tm.hi_makespan_ms(500, 150)
    r_rand = oma(s_ok, l_ok, budget, tm)
    r_worst = oma(s_ok, l_ok, budget, tm, worst_case=True)
    assert r_worst.accuracy <= r_rand.accuracy + 0.02


def test_tinyml_fastest_full_offload_most_accurate():
    rng = np.random.default_rng(6)
    s_ok = rng.random(500) < 0.6
    l_ok = rng.random(500) < 0.95
    tm = TimingModel()
    t = tinyml(s_ok, tm)
    f = full_offload(l_ok, tm)
    assert t.makespan_ms < f.makespan_ms
    assert f.accuracy > t.accuracy
