"""Data pipelines: CWRU-like vibration stats (paper Figs 4-5) and the
CIFAR-10 stand-in's S/L-relevant structure."""
import numpy as np
import pytest

from repro.data import images, tokens, vibration as vib


# ---------------------------------------------------------------------------
# vibration (§3)
# ---------------------------------------------------------------------------
def test_threshold_separates_normal_from_faults():
    """Paper: windowed mean < 0.07 <=> normal, 100% separation."""
    _, labels, means = vib.make_dataset(windows_per_state=30, seed=0)
    pred = vib.threshold_sml(means, 0.07)
    assert (pred == (labels != 0)).all()


def test_inner_outer_not_threshold_separable():
    """Paper Fig 5: at large widths inner/outer lace overlap in mean |x| —
    only the CNN can tell them apart."""
    rng = np.random.default_rng(0)
    m_inner = vib.windowed_means(vib.gen_series("inner_036", 30, rng))
    m_outer = vib.windowed_means(vib.gen_series("outer_036", 30, rng))
    lo = max(m_inner.min(), m_outer.min())
    hi = min(m_inner.max(), m_outer.max())
    assert hi > lo          # overlapping ranges -> no separating threshold


def test_bandwidth_math():
    """Paper: 100 machines x 2 REB x 48kHz x 2B = 153.6 Mbps >= 76.8."""
    assert vib.bandwidth_required(100, rebs_per_machine=2) == pytest.approx(153.6)
    assert vib.bandwidth_required(100, rebs_per_machine=1) == pytest.approx(76.8)


def test_windows_to_images_shape():
    rng = np.random.default_rng(1)
    s = vib.gen_series("ball_018", 5, rng)
    imgs = vib.windows_to_images(s)
    assert imgs.shape == (5, 64, 64, 1)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0


def test_normal_fraction_oversampling():
    _, labels, _ = vib.make_dataset(10, seed=2, normal_fraction=0.9)
    assert (labels == 0).mean() > 0.8


# ---------------------------------------------------------------------------
# images (§4-5)
# ---------------------------------------------------------------------------
def test_image_dataset_shapes_and_balance():
    x, y = images.make_dataset(500, seed=0)
    assert x.shape == (500, 32, 32, 3) and x.dtype == np.float32
    assert set(np.unique(y)) <= set(range(10))
    # roughly balanced
    counts = np.bincount(y, minlength=10)
    assert counts.min() > 20


def test_tint_carries_class_signal():
    """A tint-only linear readout must beat chance by a wide margin (this is
    what the S-ML learns)."""
    x, y = images.make_dataset(2000, seed=1, patch_amp=0.0)
    mean_rg = x.mean(axis=(1, 2))[:, :2]          # (n, 2) colour means
    ang = np.arctan2(mean_rg[:, 1], mean_rg[:, 0])
    pred = np.round(ang / (2 * np.pi / 10)).astype(int) % 10
    acc = (pred == y).mean()
    assert 0.45 < acc < 0.75    # tint Bayes ~62%


def test_binary_labels():
    _, y = images.make_dataset(200, seed=2)
    b = images.binary_labels(y)
    assert ((b == 1) == (y == images.DOG_CLASS)).all()


# ---------------------------------------------------------------------------
# tokens
# ---------------------------------------------------------------------------
def test_lm_batches_shapes():
    for batch in tokens.lm_batches(vocab=97, batch=4, seq=33, steps=2):
        assert batch["tokens"].shape == (4, 33)
        assert batch["labels"].shape == (4, 33)
        assert batch["tokens"].max() < 97
