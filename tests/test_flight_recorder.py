"""Flight-recorder invariants (PR 9).

* the snapshot ring stays bounded under long runs (capacity = maxlen);
* a seeded ``FaultSchedule`` outage produces a breaker-open dump that is
  BYTE-identical across two fresh engines (determinism: snapshots exclude
  wall clock) and contains the breaker-open tick;
* the dump-on-invariant-failure path fires and re-raises;
* the stall path dumps before its RuntimeError;
* unit behavior: capacity validation, ``path`` persistence, canonical JSON.
"""
import json

import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.faults import FaultSchedule, RetryPolicy
from repro.serving.flight_recorder import FlightRecorder

STEPS = 3
KW = dict(buckets=(8,), num_slots=2, page_size=8)


def _reqs(cfg, n):
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=STEPS) for i in range(n)]


def _outage_engine():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    # theta 1.1 > any confidence: every request wants escalation, so the
    # outage window reliably trips the breaker
    return cfg, build_engine(cfg, HIConfig(theta=1.1, capacity_factor=1.0),
                             max_new_tokens=STEPS, cache_len=32)


def _outage_run(fr):
    cfg, eng = _outage_engine()
    eng.serve_stream(
        _reqs(cfg, 8), validate=True,
        faults=FaultSchedule(seed=5, outages=((1, 4),)),
        retry=RetryPolicy(ack_timeout_ticks=1, max_retries=1,
                          breaker_threshold=2, breaker_cooldown_ticks=2),
        flight_recorder=fr, **KW)
    return eng


# ---------------------------------------------------------------------------
# unit behavior
# ---------------------------------------------------------------------------

def test_ring_bounded_and_capacity_validated():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)
    fr = FlightRecorder(capacity=4)
    for i in range(100):
        fr.record({"tick": i})
    assert len(fr.ring) == 4
    assert [s["tick"] for s in fr.ring] == [96, 97, 98, 99]
    dump = fr.trigger("test", 99)
    assert len(dump["ring"]) == 4 and dump["seq"] == 0
    assert fr.last_dump is dump


def test_path_persistence_and_canonical_json(tmp_path):
    p = tmp_path / "dump.json"
    fr = FlightRecorder(capacity=2, path=str(p))
    fr.record({"tick": 0, "b": 1.0, "a": 2})
    d1 = fr.trigger("first", 0)
    fr.record({"tick": 1})
    d2 = fr.trigger("second", 1, {"why": "because"})
    # last trigger wins the file; both dumps are kept in memory
    assert json.loads(p.read_text())["reason"] == "second"
    assert [d["seq"] for d in fr.dumps] == [0, 1]
    assert d2["detail"] == {"why": "because"}
    # canonical serialization: equal content -> equal bytes
    assert FlightRecorder.dump_json(d1) == \
        FlightRecorder.dump_json(json.loads(FlightRecorder.dump_json(d1)))


# ---------------------------------------------------------------------------
# ring bounded on a real run
# ---------------------------------------------------------------------------

def test_ring_bounded_under_long_run():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=STEPS, cache_len=32)
    fr = FlightRecorder(capacity=4)
    eng.serve_stream(_reqs(cfg, 10), validate=True, flight_recorder=fr,
                     **KW)
    ticks = eng.stats["stream_ticks"]
    assert ticks > 4, "the run must outlive the ring"
    assert len(fr.ring) == 4
    assert [s["tick"] for s in fr.ring] == \
        list(range(ticks - 4, ticks)), "the ring keeps the LAST 4 ticks"
    assert not fr.dumps, "a healthy run triggers nothing"


# ---------------------------------------------------------------------------
# breaker-open dump: deterministic and carries the open tick
# ---------------------------------------------------------------------------

def test_breaker_open_dump_deterministic_across_runs():
    fr1, fr2 = FlightRecorder(capacity=8), FlightRecorder(capacity=8)
    eng1 = _outage_run(fr1)
    _outage_run(fr2)
    assert eng1.stats["breaker_opens"] >= 1
    opens = [d for d in fr1.dumps if d["reason"] == "breaker_open"]
    assert opens, "the outage must produce a breaker-open dump"
    dump = opens[0]
    # the dump names the tick the breaker opened on, and the frozen ring
    # actually covers it (snapshot gauges flip to breaker_state == OPEN)
    assert dump["detail"]["opens"] == 1
    assert dump["detail"]["opened_tick"] >= 0
    assert any(s["gauges"].get("breaker_state") == 1.0
               for s in dump["ring"]), "ring must show the OPEN transition"
    assert all("serve_time" not in s["counters"] for s in dump["ring"])
    # byte-identical across two fresh engines on the same seeded schedule
    j1 = [FlightRecorder.dump_json(d) for d in fr1.dumps]
    j2 = [FlightRecorder.dump_json(d) for d in fr2.dumps]
    assert j1 == j2


# ---------------------------------------------------------------------------
# invariant-failure and stall postmortems
# ---------------------------------------------------------------------------

def test_invariant_failure_dumps_and_reraises(monkeypatch):
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=STEPS, cache_len=32)
    fr = FlightRecorder(capacity=4)
    # prime the scheduler, then poison check_invariants on a later run
    eng.serve_stream(_reqs(cfg, 2), validate=True, flight_recorder=fr, **KW)
    sched = eng._stream[1]

    def boom():
        raise AssertionError("injected invariant violation")

    monkeypatch.setattr(sched.srt.pool, "check_invariants", boom)
    with pytest.raises(AssertionError, match="injected invariant"):
        eng.serve_stream(_reqs(cfg, 2), validate=True, flight_recorder=fr,
                         **KW)
    assert fr.last_dump["reason"] == "invariant_failure"
    assert "injected invariant" in fr.last_dump["detail"]["error"]


def test_stall_dumps_before_runtime_error(monkeypatch):
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=1.1, capacity_factor=1.0),
                       max_new_tokens=STEPS, cache_len=32)
    fr = FlightRecorder(capacity=4)
    # prime the scheduler, then force the idle-tick bound to zero: a delayed
    # escalation's in-transit timer ticks become a "stall" immediately
    eng.serve_stream(_reqs(cfg, 1), flight_recorder=fr, **KW)
    sched = eng._stream[1]
    monkeypatch.setattr(sched, "_stall_limit", lambda: 0)
    with pytest.raises(RuntimeError, match="stalled"):
        eng.serve_stream(_reqs(cfg, 1),
                         faults=FaultSchedule(seed=1, delay_ticks=6),
                         flight_recorder=fr, **KW)
    assert fr.last_dump["reason"] == "stall"
    assert fr.last_dump["detail"]["idle_ticks"] > 0
    assert fr.last_dump["detail"]["in_flight"] >= 1
