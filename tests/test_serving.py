"""Serving path: batcher, HI engine end-to-end on a reduced arch."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.batcher import Batcher, Request
from repro.serving.engine import build_engine


def test_batcher_padding_and_buckets():
    b = Batcher(batch_size=4, buckets=(8, 16), pad_id=0)
    for i, L in enumerate([3, 9, 5]):
        b.submit(Request(i, np.arange(1, L + 1, dtype=np.int32)))
    batch = b.next_batch()
    assert batch.tokens.shape == (4, 16)          # bucket 16 (max len 9)
    assert (batch.request_ids >= 0).sum() == 3    # one padding slot
    assert batch.lengths[0] == 3
    assert (batch.tokens[0, 3:] == 0).all()


def test_pad_to_bucket_overflow_raises():
    """Regression: prompts longer than the largest bucket used to be silently
    clamped, and the pack loop then truncated the prompt (served corrupted
    requests).  Now every entry point raises instead."""
    from repro.serving.batcher import AdmissionQueue, pad_to_bucket
    assert pad_to_bucket(8, (8, 16)) == 8
    assert pad_to_bucket(9, (8, 16)) == 16
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        pad_to_bucket(17, (8, 16))
    b = Batcher(batch_size=2, buckets=(8, 16))
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        b.submit(Request(0, np.ones(17, np.int32)))
    q = AdmissionQueue(buckets=(8, 16))
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        q.submit(Request(0, np.ones(17, np.int32)))


def test_admission_queue_bucketizes_like_batcher():
    from repro.serving.batcher import AdmissionQueue
    q = AdmissionQueue(buckets=(8, 16))
    q.submit(Request(0, np.arange(1, 6, dtype=np.int32)))
    q.submit(Request(1, np.arange(1, 13, dtype=np.int32)))
    a = q.pop()
    assert a.bucket == 8 and a.tokens.shape == (8,)
    assert (a.tokens[:5] == np.arange(1, 6)).all() and (a.tokens[5:] == 0).all()
    b = q.pop()
    assert b.bucket == 16
    assert q.pop() is None
    q.push_front(b)
    assert q.pop().request.request_id == 1


def test_batcher_queue_drain():
    b = Batcher(batch_size=2, buckets=(8,))
    for i in range(5):
        b.submit(Request(i, np.ones(4, np.int32)))
    seen = 0
    while b.queue:
        seen += int((b.next_batch().request_ids >= 0).sum())
    assert seen == 5


@pytest.mark.parametrize("theta,expect", [(0.0, "none"), (1.1, "all")])
def test_engine_offload_extremes(theta, expect):
    cfg = ARCHS["gemma3-1b"].reduced()
    hi = HIConfig(theta=theta, capacity_factor=1.0)
    eng = build_engine(cfg, hi, max_new_tokens=4, cache_len=32)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (4, 8)).astype(np.int32)
    out = eng.serve(toks)
    if expect == "none":
        assert out["offloaded"].sum() == 0
        np.testing.assert_array_equal(out["tokens"], out["s_tokens"])
    else:
        assert out["offloaded"].sum() == 4
        assert out["served_remote"].sum() == 4


def test_engine_capacity_drops_counted():
    cfg = ARCHS["gemma3-1b"].reduced()
    hi = HIConfig(theta=1.1, capacity_factor=0.5)   # all want offload, half fit
    eng = build_engine(cfg, hi, max_new_tokens=2, cache_len=32)
    toks = np.random.default_rng(1).integers(0, cfg.vocab_size,
                                             (4, 8)).astype(np.int32)
    out = eng.serve(toks)
    assert out["served_remote"].sum() == 2
    assert eng.summary()["dropped"] == 2
    s = eng.summary()
    assert s["offload_frac"] == 1.0


def test_engine_output_shapes_and_stats():
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=0.5)
    eng = build_engine(cfg, hi, max_new_tokens=3, cache_len=32)
    toks = np.random.default_rng(2).integers(0, cfg.vocab_size,
                                             (4, 8)).astype(np.int32)
    out = eng.serve(toks)
    assert out["tokens"].shape == (4, 3)
    assert out["confidence"].shape == (4,)
    assert 0 <= eng.summary()["offload_frac"] <= 1


def test_engine_online_policy_adapts():
    """Paper ref [27]: online theta tuning from L-tier feedback.  With a
    random-init S-tier (never agreeing with L), offloading must look
    worthwhile, so theta rises toward 1 as batches stream."""
    from repro.core.policy import OnlineThresholdPolicy
    from repro.serving.engine import build_engine
    cfg = ARCHS["gemma3-1b"].reduced()
    pol = OnlineThresholdPolicy(beta=0.1, grid=32, eta_lr=0.5)
    hi = HIConfig(theta=0.5, capacity_factor=1.0)
    eng = build_engine(cfg, hi, max_new_tokens=2, cache_len=32)
    eng.online_policy = pol
    rng = np.random.default_rng(3)
    thetas = [pol.theta]
    for _ in range(3):
        toks = rng.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)
        eng.serve(toks)
        thetas.append(pol.theta)
    # the policy moved (it observed disagreement feedback)
    assert thetas[-1] != thetas[0] or len(pol.history) > 0
