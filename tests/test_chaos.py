"""Chaos property: under ANY seeded FaultSchedule, serving degrades but
never corrupts.

One engine (ONE compiled tick executable) is hammered with randomly drawn
fault schedules — loss, delay, jitter, L-tier outages, latency spikes — over
mixed Poisson-style traffic, with per-tick ``KVPool.check_invariants``
enabled.  Invariants checked per example:

* every submitted request terminates with EXACTLY ONE record whose
  ``status`` is a member of ``faults.STATUSES``;
* the S-tier answer is sacred: ``s_tokens`` are token-identical to the
  fault-free run for every served request, and requests that never wanted
  escalation (``offloaded`` False) return fault-free-identical ``tokens``;
* degraded requests answer with their S tokens (never a truncated L reply);
* zero page leaks: both pools pass invariants and hold no slots after the
  drain, so schedules that abort mid-flight L work release every page;
* ``stream_compiles`` stays 1 — fault handling is host-side only and can
  never change a compiled shape.

The property runs twice over: a FIXED seeded sweep (always on, so tier-1
CI exercises it without extra deps) and a hypothesis ``@given`` search when
hypothesis is installed (same body, wider schedule space).
"""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.faults import STATUSES, FaultSchedule, RetryPolicy

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("chaos", max_examples=6, deadline=None)
    settings.load_profile("chaos")
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

STEPS = 3
KW = dict(buckets=(8, 16), num_slots=3, l_slots=2, page_size=8)

_STATE = {}                      # engine + fault-free reference, built once


def _requests():
    """Mixed traffic: two buckets, Poisson-ish lengths, a zero-budget
    straggler (always drops if it tries to escalate)."""
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(7):
        n = int(rng.integers(4, 16))
        budget = 0.0 if i == 5 else None
        reqs.append(Request(i, rng.integers(0, 500, n).astype(np.int32),
                            max_new_tokens=STEPS, latency_budget=budget))
    return reqs


def _state():
    if not _STATE:
        cfg = ARCHS["qwen2-1.5b"].reduced()
        # theta 0.6: a real S/L split — some requests escalate, some don't
        eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                           max_new_tokens=STEPS, cache_len=32)
        ref = eng.serve_stream(_requests(), validate=True, **KW)
        _STATE.update(eng=eng, ref=ref)
    return _STATE["eng"], _STATE["ref"]


def _check(seed, loss, delay, jitter, out_start, out_len, spike_start,
           spike_len):
    eng, ref = _state()
    faults = FaultSchedule(
        seed=seed, loss_prob=loss, delay_ticks=delay, delay_jitter=jitter,
        outages=((out_start, out_start + out_len),) if out_len else (),
        spikes=((spike_start, spike_start + spike_len),) if spike_len else ())
    retry = RetryPolicy(ack_timeout_ticks=2, max_retries=2,
                        backoff_cap_ticks=4, breaker_threshold=2,
                        breaker_cooldown_ticks=4)
    reqs = _requests()
    out = eng.serve_stream(reqs, validate=True, faults=faults, retry=retry,
                           **KW)

    # exactly one terminal record per request, with a valid status
    assert set(out) == {r.request_id for r in reqs}
    for rid, rec in out.items():
        assert rec["status"] in STATUSES
        assert rec["status"] != "rejected"      # pool is adequate here
        np.testing.assert_array_equal(rec["s_tokens"], ref[rid]["s_tokens"])
        if not rec["offloaded"]:
            # never wanted escalation: faults must be invisible
            assert rec["status"] == "ok" and not rec["served_remote"]
            np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])
        elif rec["status"] == "ok" and rec["served_remote"]:
            np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])
        else:
            # degraded_local / dropped: the S answer stands, never truncated
            assert rec["status"] in ("degraded_local", "dropped")
            np.testing.assert_array_equal(rec["tokens"], rec["s_tokens"])

    # zero leaks after the drain (validate=True already checked every tick)
    sched = eng._stream[1]
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()
    assert sched.srt.pool.held_slots == []
    assert sched.lrt.pool.held_slots == []
    # host-side faults can never grow the compiled-shape set
    assert eng.stats["stream_compiles"] == 1


# fixed sweep: loss-only, delay-only, outage, spike, everything at once
SWEEP = [
    (1, 1.0, 0, 0, 0, 0, 0, 0),
    (2, 0.0, 2, 2, 0, 0, 0, 0),
    (3, 0.0, 0, 0, 1, 6, 0, 0),
    (4, 0.0, 0, 0, 0, 0, 2, 5),
    (5, 0.25, 1, 2, 2, 4, 7, 3),
]


@pytest.mark.parametrize("params", SWEEP, ids=lambda p: f"seed{p[0]}")
def test_chaos_never_corrupts_seeded(params):
    _check(*params)


def test_telemetry_under_active_faults():
    """PR-7 satellite: with telemetry ON under an active FaultSchedule the
    compiled-shape set stays at one, every terminating request still gets a
    complete span tree whose terminal status matches its record, and the
    fault path's escalation spans carry the retry attempts."""
    from repro.serving.telemetry import Telemetry

    eng, _ = _state()
    tel = Telemetry()
    faults = FaultSchedule(seed=5, loss_prob=0.25, delay_ticks=1,
                           delay_jitter=2, outages=((2, 6),),
                           spikes=((7, 10),))
    retry = RetryPolicy(ack_timeout_ticks=2, max_retries=2,
                        backoff_cap_ticks=4, breaker_threshold=2,
                        breaker_cooldown_ticks=4)
    out = eng.serve_stream(_requests(), validate=True, faults=faults,
                           retry=retry, telemetry=tel, **KW)
    assert eng.stats["stream_compiles"] == 1
    assert set(tel.traces) == set(out)
    for rid, rec in out.items():
        tr = tel.traces[rid]
        assert tr.complete and tr.status == rec["status"]
        kinds = [s.kind for s in tr.spans]
        assert kinds.count("terminal") == 1
        if rec["escalation_retries"]:
            # one escalate_attempt span per transport attempt
            assert kinds.count("escalate_attempt") >= \
                rec["escalation_retries"]
    # the gauge stream saw the breaker state change if anything degraded
    assert len(tel.ticks) > 0
    assert all(t.t1 >= t.t0 for t in tel.ticks)


if HAVE_HYPOTHESIS:
    @given(
        seed=st.integers(0, 2**16),
        loss=st.sampled_from([0.0, 0.25, 1.0]),
        delay=st.integers(0, 2),
        jitter=st.integers(0, 2),
        out_start=st.integers(0, 10),
        out_len=st.integers(0, 8),
        spike_start=st.integers(0, 10),
        spike_len=st.integers(0, 6),
    )
    @settings(max_examples=6)   # each example replays the full stream
    def test_chaos_never_corrupts_hypothesis(seed, loss, delay, jitter,
                                             out_start, out_len, spike_start,
                                             spike_len):
        _check(seed, loss, delay, jitter, out_start, out_len, spike_start,
               spike_len)
