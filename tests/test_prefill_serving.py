"""Device-resident serving hot path: batched prefill equivalence per family,
router merge semantics for dropped escalations, and the engine's single
post-cascade host-sync guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.core import router
from repro.models import model_zoo as zoo
from repro.serving import engine as engine_mod
from repro.serving.engine import build_engine

B, S, CACHE, STEPS = 4, 12, 32, 6

# one reduced config per decoder family (dense incl. local/global SWA, ssm,
# hybrid, moe)
FAMS = ["qwen2-1.5b", "gemma3-1b", "mamba2-370m", "zamba2-2.7b",
        "deepseek-moe-16b"]


# ---------------------------------------------------------------------------
# batched prefill == token-by-token scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMS)
def test_prefill_generations_match_legacy_scan(arch, key):
    """Greedy generations from the batched prefill must be identical to the
    legacy per-token scan prefill — same tokens, same mean confidence rule."""
    cfg = ARCHS[arch].reduced()
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    old_toks, old_conf = jax.jit(
        lambda p, t: engine_mod._decode_loop(p, cfg, t, CACHE, STEPS,
                                             "max_prob"))(params, tokens)
    new_toks, new_conf, cache = jax.jit(
        lambda p, t, c: engine_mod._generate(p, cfg, t, c, steps=STEPS,
                                             metric="max_prob", theta=0.5)
    )(params, tokens, zoo.init_cache(cfg, B, CACHE))

    np.testing.assert_array_equal(np.asarray(new_toks), np.asarray(old_toks))
    assert int(cache["pos"]) == S + STEPS
    # confidences feed the offload rule; tiny numeric drift is acceptable for
    # the recurrent families (chunked SSD vs per-token recurrence)
    np.testing.assert_allclose(np.asarray(new_conf), np.asarray(old_conf),
                               rtol=1e-4, atol=1e-4)


def test_prefill_cache_continues_decode(key):
    """The prefill-written cache is a valid decode cache: continuing from it
    equals continuing from a stepwise-filled one (dense, fp32 cache)."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits_bulk, cache_bulk = zoo.prefill(
        params, cfg, tokens, zoo.init_cache(cfg, B, CACHE, dtype=jnp.float32))
    cache_step = zoo.init_cache(cfg, B, CACHE, dtype=jnp.float32)
    logits_step = None
    for t in range(S):
        logits_step, cache_step = zoo.decode_step(params, cfg,
                                                  tokens[:, t:t + 1],
                                                  cache_step)
    np.testing.assert_allclose(np.asarray(logits_bulk),
                               np.asarray(logits_step), rtol=2e-3, atol=2e-3)
    nxt = jnp.argmax(logits_bulk, -1)[:, None].astype(jnp.int32)
    l1, _ = zoo.decode_step(params, cfg, nxt, cache_bulk)
    l2, _ = zoo.decode_step(params, cfg, nxt, cache_step)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# router: dropped escalations keep the S-tier result
# ---------------------------------------------------------------------------

def test_scatter_merge_preserves_dropped_escalations():
    """Requests that want offload but exceed capacity (dropped) must be
    served with the S-tier output, untouched by the merge."""
    n, cap = 8, 3
    conf = jnp.asarray(np.linspace(0.1, 0.8, n), jnp.float32)
    mask = jnp.ones((n,), bool)                 # everyone wants offload
    d = router.route(mask, conf, cap)
    s_out = jnp.arange(n * 2, dtype=jnp.int32).reshape(n, 2)
    l_out = 100 + jnp.arange(cap * 2, dtype=jnp.int32).reshape(cap, 2)
    merged = np.asarray(router.scatter_merge(s_out, l_out, d))
    served = np.asarray(d.served_remote)
    assert served.sum() == cap and int(d.dropped) == n - cap
    # dropped (and never-offloaded) positions are bit-identical to S-tier
    np.testing.assert_array_equal(merged[~served], np.asarray(s_out)[~served])
    # served positions carry L-tier rows
    assert (merged[served] >= 100).all()


def test_router_agreement_on_device():
    s_out = jnp.asarray([[1, 2], [3, 4], [5, 6], [7, 8]], jnp.int32)
    conf = jnp.asarray([0.1, 0.9, 0.2, 0.8], jnp.float32)
    d = router.route(conf < 0.5, conf, 2)       # gathers rows 0 and 2
    l_out = s_out[d.indices].at[1].add(1)       # slot 1 disagrees
    agree = np.asarray(router.agreement(s_out, l_out, d))
    np.testing.assert_array_equal(agree, [True, False])


# ---------------------------------------------------------------------------
# engine: single post-cascade host sync + executable cache
# ---------------------------------------------------------------------------

def test_engine_single_host_sync_and_no_retrace(monkeypatch):
    """`serve` must perform NO host transfer between the S-tier and L-tier
    forwards: the only device→host sync is the one post-cascade
    ``_host_fetch``.  Re-serving the same (batch, bucket) shape must reuse
    the compiled executable."""
    calls = []
    real = engine_mod._host_fetch
    monkeypatch.setattr(engine_mod, "_host_fetch",
                        lambda tree: (calls.append(1), real(tree))[1])
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=0.5),
                       max_new_tokens=3, cache_len=32)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (4, 8)).astype(np.int32)
    out = eng.serve(toks)
    assert len(calls) == 1          # exactly one sync point per serve()
    assert all(isinstance(v, np.ndarray) for v in out.values())
    eng.serve(toks)
    assert len(calls) == 2
    assert eng.stats["compiles"] == 1           # same shape -> no retrace
    eng.serve(np.pad(toks, ((0, 0), (0, 8))))   # new bucket -> one compile
    assert eng.stats["compiles"] == 2
    eng.serve(toks)                              # back to the first bucket
    assert eng.stats["compiles"] == 2


def test_engine_matches_legacy_serve(key):
    """End-to-end: the device-resident cascade and the legacy path agree on
    generations, confidence, and offload accounting."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=0.5)
    toks = np.random.default_rng(5).integers(0, cfg.vocab_size,
                                             (4, 8)).astype(np.int32)
    eng_new = build_engine(cfg, hi, max_new_tokens=4, cache_len=32)
    eng_old = build_engine(cfg, hi, max_new_tokens=4, cache_len=32)
    new = eng_new.serve(toks)
    old = eng_old.serve_legacy(toks)
    np.testing.assert_array_equal(new["tokens"], old["tokens"])
    np.testing.assert_array_equal(new["offloaded"], old["offloaded"])
    np.testing.assert_allclose(new["confidence"], old["confidence"],
                               rtol=1e-5, atol=1e-5)
