"""The unified chunked token lane: ``model_zoo.forward_chunk_paged`` must be
bitwise the PR-2 per-token paged path per family (greedy outputs), the
chunked-prefill admission must be token-identical to whole-prompt admission,
and ``KVPool.truncate`` must stay refcount-safe under rollback sequences.

The fixed-parameter tests run everywhere; the hypothesis sections widen the
same properties to arbitrary chunk sizes C and draft lengths k in CI."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.models import model_zoo as zoo
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.kv_pool import KVPool

FAMS = ["qwen2-1.5b", "gemma3-1b", "deepseek-moe-16b", "mamba2-370m",
        "zamba2-2.7b"]
PAGE = 8


def _paged_setup(cfg, slots=2, npg=6, prompt_len=16, seed=0):
    """A paged cache with ``slots`` prompts prefilled; returns everything a
    chunk pass needs."""
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    cache = zoo.init_paged_cache(cfg, slots, slots * npg + 1, PAGE)
    block = jnp.asarray(
        np.arange(1, slots * npg + 1, dtype=np.int32).reshape(slots, npg))
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (slots, prompt_len)),
                       jnp.int32)
    lens = jnp.full((slots,), prompt_len, jnp.int32)
    _, cache = zoo.prefill_paged(params, cfg, toks, lens,
                                 jnp.arange(slots, dtype=jnp.int32), block,
                                 cache)
    pos = jnp.full((slots,), prompt_len, jnp.int32)
    return params, cache, block, pos, rng


def _chunk_vs_steps(cfg, c, seed=0, use_kernel=False):
    """Core property: one C-token chunk pass == C sequential decode steps —
    same greedy tokens, same cache continuation."""
    params, cache, block, pos, rng = _paged_setup(cfg, seed=seed)
    chunk = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, c)), jnp.int32)

    cache_ref = cache
    ref = []
    for i in range(c):
        lg, cache_ref = zoo.decode_step_paged(params, cfg, chunk[:, i:i + 1],
                                              pos + i, block, cache_ref,
                                              use_kernel=use_kernel)
        ref.append(lg)
    ref = jnp.stack(ref, axis=1)                       # (B, C, V)

    out, cache_c, staged = jax.jit(
        lambda p, t, q, b, ca: zoo.forward_chunk_paged(
            p, cfg, t, q, b, ca, use_kernel=use_kernel))(
        params, chunk, pos, block, cache)

    np.testing.assert_array_equal(np.asarray(jnp.argmax(out, -1)),
                                  np.asarray(jnp.argmax(ref, -1)))
    # continuing from the chunk-written cache equals the stepped cache
    nxt = jnp.argmax(out[:, -1], -1).astype(jnp.int32)[:, None]
    l1, _ = zoo.decode_step_paged(params, cfg, nxt, pos + c, block, cache_c,
                                  use_kernel=use_kernel)
    l2, _ = zoo.decode_step_paged(params, cfg, nxt, pos + c, block,
                                  cache_ref, use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(l1, -1)),
                                  np.asarray(jnp.argmax(l2, -1)))
    return out, ref, staged


@pytest.mark.parametrize("arch", FAMS)
def test_chunk_pass_matches_per_token_path(arch):
    """forward_chunk_paged == C x decode_step_paged, greedy-bitwise, every
    family (the attention families exactly — maxerr 0 on this reference;
    MoE up to routing-drop determinism, absent at the decode capacity)."""
    cfg = ARCHS[arch].reduced()
    out, ref, staged = _chunk_vs_steps(cfg, c=4)
    if cfg.family in ("ssm", "hybrid"):
        # the recurrent chunk IS a scan of the per-token step: bitwise
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        # attention families: one (B, C, D) matmul vs C (B, 1, D) matmuls —
        # identical math, low-order gemm-tiling bits may differ; MoE adds
        # batch-coupled routing (dispatch order over B*C vs B*1 tokens)
        tol = 5e-3 if cfg.family == "moe" else 1e-4
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)
    if cfg.family in ("ssm", "hybrid"):
        # recurrent families stage a per-step boundary snapshot per token
        assert all(a.shape[0] == 4 for a in jax.tree.leaves(staged))
    else:
        assert staged == {}


def test_chunk_kernel_matches_jnp_path():
    """The multi-token paged Pallas kernel agrees with the jnp gather path
    (same greedy tokens; interpret-mode numerics)."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params, cache, block, pos, rng = _paged_setup(cfg)
    chunk = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 3)), jnp.int32)
    out_j, _, _ = zoo.forward_chunk_paged(params, cfg, chunk, pos, block,
                                          cache)
    out_k, _, _ = zoo.forward_chunk_paged(params, cfg, chunk, pos, block,
                                          cache, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(out_k, -1)),
                                  np.asarray(jnp.argmax(out_j, -1)))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               rtol=2e-2, atol=2e-2)


def test_chunk_staged_rollback_restores_boundary():
    """select_stage(staged, keep) must equal the state after exactly ``keep``
    sequential steps (the rollback contract for the recurrent families)."""
    cfg = ARCHS["mamba2-370m"].reduced()
    params, cache, block, pos, rng = _paged_setup(cfg)
    c, keep = 4, 2
    chunk = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, c)), jnp.int32)
    _, cache_c, staged = zoo.forward_chunk_paged(params, cfg, chunk, pos,
                                                 block, cache)
    sel = zoo.select_stage(cfg, staged, jnp.full((2,), keep, jnp.int32))
    rolled = zoo.restore_stage(cfg, cache_c, sel, jnp.ones((2,), bool))
    cache_ref = cache
    for i in range(keep):
        _, cache_ref = zoo.decode_step_paged(params, cfg, chunk[:, i:i + 1],
                                             pos + i, block, cache_ref)
    np.testing.assert_array_equal(np.asarray(rolled["state"]),
                                  np.asarray(cache_ref["state"]))
    np.testing.assert_array_equal(np.asarray(rolled["conv"]),
                                  np.asarray(cache_ref["conv"]))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m"])
@pytest.mark.parametrize("sharing", [False, True])
def test_chunked_prefill_admission_token_identical(arch, sharing):
    """serve_stream with chunk_prefill on == off, token for token, per
    request — long prompts just arrive C tokens per tick."""
    cfg = ARCHS[arch].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=1.0)
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=4)
            for i, n in enumerate([8, 24, 16, 24])]
    kw = dict(buckets=(8, 16, 24), num_slots=3, page_size=8,
              prefix_sharing=sharing)
    eng_a = build_engine(cfg, hi, max_new_tokens=4, cache_len=48)
    base = eng_a.serve_stream(reqs, **kw)
    eng_b = build_engine(cfg, hi, max_new_tokens=4, cache_len=48)
    chunked = eng_b.serve_stream(reqs, **kw, chunk_prefill=True, chunk_size=8)
    for rid in base:
        np.testing.assert_array_equal(base[rid]["tokens"],
                                      chunked[rid]["tokens"])
        assert chunked[rid]["ttft"] >= 0.0
    assert eng_b.stats["stream_compiles"] == 1


def test_truncate_guards_shared_pages():
    """truncate raises on rewinds that could reach a page another slot
    aliases, passes on exclusively-held decode regions, and never perturbs
    refcount conservation."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8,
                  prefix_entries=2)
    toks = np.arange(16, dtype=np.int32)
    from repro.serving.batcher import prompt_hashes
    hashes, full = prompt_hashes(toks, 8)
    p0 = pool.admit_prefix(0, 32, 16, hashes, full, tick=0)
    assert p0 is not None and p0.start == 0
    pool.truncate(0, 17)                       # decode region: exclusive
    pool.check_invariants()
    # second slot aliases the first prompt's pages (next tick)
    p1 = pool.admit_prefix(1, 32, 16, hashes, full, tick=1)
    assert p1 is not None and p1.start > 0
    with pytest.raises(ValueError, match="shared page"):
        pool.truncate(1, 0)                    # rewind into the shared prefix
    pool.truncate(1, 17)                       # its own decode region: fine
    pool.check_invariants()
    with pytest.raises(ValueError):
        pool.truncate(1, -1)


def test_retract_undoes_rolled_back_registrations():
    """A rolled-back paired admission must not leave prefix-index entries
    pointing at never-prefilled pages — retract drops the admission's own
    same-tick registrations (and ONLY those: a co-admitted identical prompt's
    entries survive)."""
    from repro.serving.batcher import prompt_hashes
    cfg = ARCHS["qwen2-1.5b"].reduced()
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8,
                  prefix_entries=2)
    toks = np.arange(16, dtype=np.int32)
    hashes, full = prompt_hashes(toks, 8)
    plan = pool.admit_prefix(0, 32, 16, hashes, full, tick=0)
    assert plan is not None and plan.save_row >= 0
    pool.retract(0, hashes, full, tick=0)
    pool.free(0)
    pool.check_invariants()
    # the retracted entries are gone: a next-tick identical prompt MISSES
    plan2 = pool.admit_prefix(0, 32, 16, hashes, full, tick=1)
    assert plan2 is not None and plan2.start == 0 and not plan2.is_restore
    # ... but retracting with a DIFFERENT slot leaves the new owner's
    # registrations alone
    pool.retract(1, hashes, full, tick=1)
    fe_hit, pages = pool.lookup(hashes, full, 16, tick=2)
    assert fe_hit is not None or pages
    pool.check_invariants()


# ---------------------------------------------------------------------------
# hypothesis: arbitrary chunk sizes / draft lengths / rollback sequences
# (guarded so the fixed-parameter tests above still run without hypothesis)
# ---------------------------------------------------------------------------
def _spec_vs_oracle(cfg, k, chunk, seed, max_new=6):
    from repro.serving.token_cascade import TokenCascade
    hi = HIConfig(theta=0.5, capacity_factor=1.0)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = build_engine(cfg, hi, max_new_tokens=max_new, cache_len=48)
    out = eng.serve_stream(
        [Request(0, prompt, max_new_tokens=max_new)], buckets=(8,),
        num_slots=1, page_size=8, decode_block=k,
        speculative=True, chunk_prefill=chunk > 0, chunk_size=max(chunk, 1))
    tc = TokenCascade(s_cfg=eng.s.cfg, l_cfg=eng.l.cfg,
                      s_params=eng.s.params, l_params=eng.l.params,
                      hi=hi, block=k, cache_len=48)
    ref = tc.generate_speculative(prompt[None, :], max_new)
    np.testing.assert_array_equal(out[0]["tokens"], ref["tokens"][0])
    assert out[0]["rounds"] == ref["rounds"]


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    settings.register_profile("chunk", max_examples=8, deadline=None)
    settings.load_profile("chunk")

    @given(st.integers(1, 6), st.integers(0, 2 ** 16))
    def test_chunk_lane_equiv_arbitrary_c(c, seed):
        """For arbitrary chunk sizes C the chunk lane's greedy outputs match
        the per-token path's (dense reference; the per-family sweep is the
        parametrized test above — _chunk_vs_steps itself asserts the greedy
        tokens and the cache continuation)."""
        cfg = ARCHS["qwen2-1.5b"].reduced()
        out, ref, _ = _chunk_vs_steps(cfg, c=c, seed=seed)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=4)   # each example compiles a tick executable
    @given(st.integers(1, 5), st.integers(1, 6), st.integers(0, 2 ** 16))
    def test_spec_lane_equiv_arbitrary_k(k, c, seed):
        """Draft length k (decode_block) and chunk size C are free knobs:
        the fused speculative lane's greedy outputs must match the host
        oracle for any combination (small model, one request)."""
        cfg = ARCHS["qwen2-1.5b"].reduced()
        _spec_vs_oracle(cfg, k=k, chunk=c, seed=seed)

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 40)),
                    min_size=1, max_size=24),
           st.integers(0, 2 ** 16))
    def test_pool_invariants_under_truncate_rollback(ops, seed):
        """check_invariants holds through arbitrary alloc /
        truncate(rollback) / free sequences (truncate either passes or
        raises cleanly — never corrupts the allocator)."""
        cfg = ARCHS["qwen2-1.5b"].reduced()
        pool = KVPool(cfg, num_slots=4, max_context=32, page_size=8)
        rng = np.random.default_rng(seed)
        held = set()
        for slot, arg in ops:
            kind = rng.integers(0, 3)
            try:
                if kind == 0 and slot not in held:
                    pool.alloc(slot, 8 + (arg % 25))
                    held.add(slot)
                elif kind == 1 and slot in held:
                    pool.truncate(slot, arg)
                elif kind == 2 and slot in held:
                    pool.free(slot)
                    held.discard(slot)
            except ValueError:
                pass             # rejection is fine; corruption is not
            pool.check_invariants()
