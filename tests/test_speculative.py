"""Fused speculative S→L cascade: the in-tick draft-verify lane must match
the host-driven ``token_cascade.generate_speculative`` oracle block for
block, stay greedy-only (temperature raises), keep the one-program / single-
sync discipline, and degrade to pure-S greedy when the gate never fires."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving import engine as engine_mod
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.token_cascade import TokenCascade

MAX_NEW = 6
K = 3


def _engine_and_oracle(arch, theta, block=K, max_new=MAX_NEW):
    cfg = ARCHS[arch].reduced()
    hi = HIConfig(theta=theta, capacity_factor=1.0)
    eng = build_engine(cfg, hi, max_new_tokens=max_new, cache_len=48)
    tc = TokenCascade(s_cfg=eng.s.cfg, l_cfg=eng.l.cfg,
                      s_params=eng.s.params, l_params=eng.l.params,
                      hi=hi, block=block, cache_len=48)
    return cfg, eng, tc


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m"])
def test_fused_cascade_matches_host_oracle(arch):
    """Same traffic through the fused in-tick cascade and the legacy-style
    host-driven loop: identical accepted/escalated BLOCK decisions and
    identical emitted tokens, per request."""
    cfg, eng, tc = _engine_and_oracle(arch, theta=0.5)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]
    out = eng.serve_stream(
        [Request(i, p, max_new_tokens=MAX_NEW)
         for i, p in enumerate(prompts)],
        buckets=(8,), num_slots=2, page_size=8, decode_block=K,
        speculative=True)
    for i, p in enumerate(prompts):
        ref = tc.generate_speculative(p[None, :], MAX_NEW)
        np.testing.assert_array_equal(out[i]["tokens"], ref["tokens"][0])
        assert out[i]["rounds"] == ref["rounds"]
        assert out[i]["escalated_blocks"] == ref["escalated"]
    # the lane stays ONE compiled executable with speculation fused in
    assert eng.stats["stream_compiles"] == 1


def test_fused_cascade_single_sync_per_tick(monkeypatch):
    """Draft + verify + rollback all live inside the tick's one program:
    still exactly one host fetch per tick."""
    calls = []
    real = engine_mod._host_fetch
    monkeypatch.setattr(engine_mod, "_host_fetch",
                        lambda tree: (calls.append(1), real(tree))[1])
    cfg, eng, _ = _engine_and_oracle("qwen2-1.5b", theta=0.5)
    rng = np.random.default_rng(0)
    eng.serve_stream([Request(0, rng.integers(0, cfg.vocab_size, 8)
                              .astype(np.int32), max_new_tokens=MAX_NEW)],
                     buckets=(8,), num_slots=1, page_size=8, decode_block=K,
                     speculative=True)
    sched = eng._stream[1]
    assert len(calls) == sched.stats["ticks"] > 0
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()


def test_speculative_never_escalates_equals_greedy_stream():
    """theta = 0: the gate never fires, every draft block is accepted — the
    fused cascade must emit exactly the plain scheduler's S-tier greedy
    tokens (the chunking/speculation-off bitwise guarantee)."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)
    rng = np.random.default_rng(5)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, n in enumerate([8, 16, 8])]
    eng_p = build_engine(cfg, hi, max_new_tokens=MAX_NEW, cache_len=48)
    plain = eng_p.serve_stream(reqs, buckets=(8, 16), num_slots=2,
                               page_size=8)
    eng_s = build_engine(cfg, hi, max_new_tokens=MAX_NEW, cache_len=48)
    spec = eng_s.serve_stream(reqs, buckets=(8, 16), num_slots=2,
                              page_size=8, decode_block=K, speculative=True)
    for rid in plain:
        np.testing.assert_array_equal(plain[rid]["s_tokens"],
                                      spec[rid]["tokens"])
        assert spec[rid]["escalated_blocks"] == 0
        assert not spec[rid]["offloaded"]
    sched = eng_s._stream[1]
    assert sched.stats["accepted"] == sched.stats["drafted"] > 0


def test_speculative_with_chunked_prefill_matches_plain_speculative():
    """Both tentpole features on at once: chunked prompt ingestion must not
    change a single speculative token."""
    cfg, eng_a, _ = _engine_and_oracle("qwen2-1.5b", theta=0.5)
    rng = np.random.default_rng(2)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, n in enumerate([24, 8, 16])]
    base = eng_a.serve_stream(reqs, buckets=(8, 16, 24), num_slots=2,
                              page_size=8, decode_block=K, speculative=True)
    _, eng_b, _ = _engine_and_oracle("qwen2-1.5b", theta=0.5)
    both = eng_b.serve_stream(reqs, buckets=(8, 16, 24), num_slots=2,
                              page_size=8, decode_block=K, speculative=True,
                              chunk_prefill=True, chunk_size=8)
    for rid in base:
        np.testing.assert_array_equal(base[rid]["tokens"],
                                      both[rid]["tokens"])
        assert base[rid]["rounds"] == both[rid]["rounds"]
    assert eng_b.stats["stream_compiles"] == 1


def test_speculative_temperature_raises():
    """Speculative acceptance is greedy-only: any sampling temperature —
    per-request or engine-wide — raises a clear NotImplementedError."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.5, capacity_factor=1.0)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    eng = build_engine(cfg, hi, max_new_tokens=4, cache_len=48)
    with pytest.raises(NotImplementedError, match="greedy-only"):
        eng.serve_stream([Request(0, prompt, temperature=0.7)],
                         buckets=(8,), speculative=True)

    eng_t = build_engine(cfg, hi, max_new_tokens=4, cache_len=48,
                         temperature=0.8)
    with pytest.raises(NotImplementedError, match="greedy-only"):
        eng_t.serve_stream([Request(0, prompt)], buckets=(8,),
                           speculative=True)

    # greedy requests through a greedy engine still serve fine
    out = eng.serve_stream([Request(0, prompt, max_new_tokens=4)],
                           buckets=(8,), num_slots=1, page_size=8,
                           decode_block=2, speculative=True)
    assert len(out[0]["tokens"]) == 4
