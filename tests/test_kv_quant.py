"""Quantized paged KV pool (int8 per-page-per-head scales): per-family
int8-vs-bf16 decode fidelity bounds, fused-dequant kernel vs jnp-path
agreement, scale rows moving with pages through copy_pages / COW, pool
gauges + scalar-prefetch bound hardening, and the serving-layer contract
(one compiled stream executable, one host sync per tick) in BOTH dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.models import model_zoo as zoo
from repro.serving import engine as engine_mod
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.kv_pool import KVPool

FAMS = ["qwen2-1.5b", "gemma3-1b", "deepseek-moe-16b", "mamba2-370m",
        "zamba2-2.7b"]
PAGE = 8


def _quant_pair(cfg, slots=2, npg=6, prompt_len=16, seed=0):
    """Prefill the same prompts into a bf16 and an int8 paged cache."""
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (slots, prompt_len)),
                       jnp.int32)
    lens = jnp.full((slots,), prompt_len, jnp.int32)
    block = jnp.asarray(
        np.arange(1, slots * npg + 1, dtype=np.int32).reshape(slots, npg))
    out = {}
    for dt in (jnp.bfloat16, jnp.int8):
        cache = zoo.init_paged_cache(cfg, slots, slots * npg + 1, PAGE, dt)
        lg, cache = zoo.prefill_paged(params, cfg, toks, lens,
                                      jnp.arange(slots, dtype=jnp.int32),
                                      block, cache)
        out[str(jnp.dtype(dt))] = (lg, cache)
    pos = jnp.full((slots,), prompt_len, jnp.int32)
    return params, out, block, pos


@pytest.mark.parametrize("arch", FAMS)
def test_int8_decode_fidelity_per_family(arch):
    """int8 pools track the bf16 pools within tolerance: bounded logit
    error and high TEACHER-FORCED greedy top-1 agreement (both paths fed
    the bf16 argmax each step, isolating per-decision fidelity from
    compounding divergence).  The pure-SSM family has no pages to quantize,
    so it must stay EXACT."""
    cfg = ARCHS[arch].reduced()
    params, out, block, pos = _quant_pair(cfg)
    (lg_b, cache_b), (lg_q, cache_q) = out["bfloat16"], out["int8"]
    slots, steps = lg_b.shape[0], 8
    max_err = float(jnp.max(jnp.abs(lg_b - lg_q)))
    match = int(jnp.sum(jnp.argmax(lg_b, -1) == jnp.argmax(lg_q, -1)))
    total = slots
    tok = jnp.argmax(lg_b, -1)[:, None].astype(jnp.int32)
    for i in range(steps):
        lg_b, cache_b = zoo.decode_step_paged(params, cfg, tok, pos + i,
                                              block, cache_b)
        lg_q, cache_q = zoo.decode_step_paged(params, cfg, tok, pos + i,
                                              block, cache_q)
        max_err = max(max_err, float(jnp.max(jnp.abs(lg_b - lg_q))))
        match += int(jnp.sum(jnp.argmax(lg_b, -1) == jnp.argmax(lg_q, -1)))
        total += slots
        tok = jnp.argmax(lg_b, -1)[:, None].astype(jnp.int32)
    if arch == "mamba2-370m":        # no KV pages -> int8 mode is a no-op
        assert match == total and max_err == 0.0
    else:
        assert match / total >= 0.8, f"{arch}: agreement {match}/{total}"
        assert max_err <= 0.3, f"{arch}: logit error {max_err}"


def test_int8_fused_dequant_kernel_matches_jnp():
    """The Pallas kernels with the fused dequant (scale operand riding the
    block-table index_map) agree with the jnp dequant-gather path, single
    token and chunked."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params, out, block, pos = _quant_pair(cfg)
    _, cache = out["int8"]
    tok = jnp.asarray([[7], [11]], jnp.int32)
    lg_j, _ = zoo.decode_step_paged(params, cfg, tok, pos, block, cache)
    lg_k, _ = zoo.decode_step_paged(params, cfg, tok, pos, block, cache,
                                    use_kernel=True)
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_j),
                               rtol=2e-2, atol=2e-2)
    chunk = jnp.asarray([[7, 3, 5], [11, 2, 9]], jnp.int32)
    cj, _, _ = zoo.forward_chunk_paged(params, cfg, chunk, pos, block, cache)
    ck, _, _ = zoo.forward_chunk_paged(params, cfg, chunk, pos, block, cache,
                                       use_kernel=True)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cj),
                               rtol=2e-2, atol=2e-2)


def test_int8_sharing_and_chunking_equivalence_tolerance():
    """Under int8 the bitwise sharing/chunking invariants become
    tolerance-based: one chunk pass tracks sequential decode steps on the
    same quantized pool (identical writes -> identical pool bytes; logits
    match within interpret-mode tolerance)."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params, out, block, pos = _quant_pair(cfg)
    _, cache = out["int8"]
    c = 3
    rng = np.random.default_rng(1)
    chunk = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, c)), jnp.int32)
    cache_ref, ref = cache, []
    for i in range(c):
        lg, cache_ref = zoo.decode_step_paged(params, cfg, chunk[:, i:i + 1],
                                              pos + i, block, cache_ref)
        ref.append(lg)
    ref = jnp.stack(ref, axis=1)
    out_c, cache_c, _ = zoo.forward_chunk_paged(params, cfg, chunk, pos,
                                                block, cache)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    # pool proximity, not byte equality: deeper-layer K/V of later chunk
    # tokens depend on whether earlier chunk tokens were read back
    # quantized (step loop) or in-pass (chunk), and the step loop
    # additionally requantizes already-rounded levels when a later token
    # raises the page scale — so scales agree to tolerance and the
    # quantized levels within a couple of grid steps
    for k in ("ks", "vs"):
        np.testing.assert_allclose(np.asarray(cache_c[k]),
                                   np.asarray(cache_ref[k]),
                                   rtol=5e-3, atol=5e-5)
    for k in ("kp", "vp"):
        d = np.abs(np.asarray(cache_c[k], np.int32) -
                   np.asarray(cache_ref[k], np.int32))
        assert d.max() <= 2, f"{k}: quantized bytes diverge by {d.max()}"


def test_copy_pages_int8_pool_with_scales():
    """copy_pages derives out_shape/dtype from its pool argument: an int8
    pool copies as int8, and the (L, P, K) scale tensor goes through the
    SAME kernel so a COW'd page carries its scale row."""
    from repro.kernels import ops as kops
    from repro.models import layers as L

    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.integers(-127, 128, (2, 6, 4, 2, 3)), jnp.int8)
    scale = jnp.asarray(rng.random((2, 6, 2)), jnp.float32)
    src = jnp.asarray([4, 2, 0], jnp.int32)
    dst = jnp.asarray([1, 3, 0], jnp.int32)
    out = np.asarray(kops.copy_pages(pool, src, dst))
    assert out.dtype == np.int8
    np.testing.assert_array_equal(out, np.asarray(L.cow_copy_pages(pool, src,
                                                                   dst)))
    np.testing.assert_array_equal(out[:, 1], np.asarray(pool[:, 4]))
    out_s = np.asarray(kops.copy_pages(scale, src, dst))
    assert out_s.dtype == np.float32
    np.testing.assert_array_equal(out_s,
                                  np.asarray(L.cow_copy_scales(scale, src,
                                                               dst)))
    np.testing.assert_array_equal(out_s[:, 1], np.asarray(scale[:, 4]))
    np.testing.assert_array_equal(out_s[:, 0], np.asarray(scale[:, 0]))


def test_pool_gauges_report_quant_footprint():
    """kv_bytes_total / bytes_per_slot / kv_bits gauges: the int8 pool
    (pages + scale rows) fits in <= 0.55x the bf16 bytes at the same
    slot/page config."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    kw = dict(num_slots=4, max_context=32, page_size=8)
    g16 = KVPool(cfg, dtype=jnp.bfloat16, **kw).gauges()
    g8 = KVPool(cfg, dtype=jnp.int8, **kw).gauges()
    assert g16["kv_bits"] == 16 and g8["kv_bits"] == 8
    assert g16["kv_bytes_total"] == g16["bytes_per_slot"] * 4
    assert g8["kv_bytes_total"] <= 0.55 * g16["kv_bytes_total"]
    for g in (g16, g8):        # numeric-only contract (telemetry counters)
        assert all(isinstance(v, int) for v in g.values())


def test_block_table_wider_than_prefetch_bound_raises():
    """A page_size/max_context pair implying a block-table row wider than
    the kernels' scalar-prefetch block must fail loudly at pool
    construction, not read garbage in the kernel."""
    from repro.kernels.decode_attention import MAX_PREFETCH_PAGES

    cfg = ARCHS["qwen2-1.5b"].reduced()
    too_wide = 8 * (MAX_PREFETCH_PAGES + 1)
    with pytest.raises(ValueError, match="MAX_PREFETCH_PAGES"):
        KVPool(cfg, num_slots=1, max_context=too_wide, page_size=8,
               num_pages=4)


@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_stream_one_compile_one_sync_both_dtypes(monkeypatch, kv_dtype):
    """The quantized pool changes bytes, not structure: serve_stream keeps
    ONE compiled executable across buckets and exactly one device->host
    sync per tick in either kv_dtype, with pool invariants (including
    scale-row accounting) checked after every tick."""
    calls = []
    real = engine_mod._host_fetch
    monkeypatch.setattr(engine_mod, "_host_fetch",
                        lambda tree: (calls.append(1), real(tree))[1])
    cfg = ARCHS["qwen2-1.5b"].reduced()
    eng = build_engine(cfg, HIConfig(theta=0.6, capacity_factor=1.0),
                       max_new_tokens=3, cache_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=3) for i, L in enumerate([8, 16, 8])]
    eng.serve_stream(reqs, buckets=(8, 16), num_slots=2, page_size=8,
                     kv_dtype=kv_dtype, validate=True)
    assert eng.stats["stream_compiles"] == 1
    assert len(calls) == eng.stats["stream_ticks"] > 0
    pool = eng._stream[1].srt.pool
    assert pool.kv_dtype == ("int8" if kv_dtype == "int8" else "bfloat16")
    assert ("ks" in pool.buffers) == (kv_dtype == "int8")


def test_int8_prefix_sharing_cow_moves_scale_rows():
    """End to end through the serving stack: repeated-prefix traffic with a
    non-page-aligned bucket forces full restores + COW tail-page copies on
    an int8 pool; invariants (scale accounting included) hold every tick
    and restored continuations match the unshared engine's tokens."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.6, capacity_factor=1.0)
    rng = np.random.default_rng(3)
    base = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    def mk(n):
        return np.concatenate(
            [base, rng.integers(0, cfg.vocab_size, n).astype(np.int32)])

    p1, p2 = mk(4), mk(8)
    reqs = [Request(i, p, max_new_tokens=3)
            for i, p in enumerate([p1, p2, p1, p2, p1])]
    kw = dict(buckets=(12, 16), num_slots=2, page_size=8, kv_dtype="int8",
              validate=True)
    eng_on = build_engine(cfg, hi, max_new_tokens=3, cache_len=32)
    on = eng_on.serve_stream(reqs, prefix_sharing=True, **kw)
    eng_off = build_engine(cfg, hi, max_new_tokens=3, cache_len=32)
    off = eng_off.serve_stream(reqs, prefix_sharing=False, **kw)
    stats = eng_on._stream[1].prefix_stats
    assert stats["full_hits"] > 0 and stats["cow_copies"] > 0
    # same pool dtype both sides -> identical quantized pages for identical
    # traffic: restored/aliased continuations stay token-identical
    for rid in off:
        np.testing.assert_array_equal(on[rid]["tokens"], off[rid]["tokens"])
    eng_on._stream[1].srt.pool.check_invariants()
    eng_on._stream[1].lrt.pool.check_invariants()
