"""Training substrate: grad accumulation equivalence, optimizer behaviour,
checkpoint roundtrip, loss goes down."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS
from repro.models import model_zoo as zoo
from repro.optim import adamw
from repro.training import trainer


def test_grad_accum_equivalence(key):
    """grads(accum=4) must equal grads(accum=1) on the same global batch."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = zoo.init_params(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
    }
    g1, _ = trainer.grads_and_metrics(
        params, cfg, TrainConfig(grad_accum=1, remat=False), batch)
    g4, _ = trainer.grads_and_metrics(
        params, cfg, TrainConfig(grad_accum=4, remat=False), batch)
    flat1, flat4 = jax.tree.leaves(g1), jax.tree.leaves(g4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_remat_equivalence(key):
    cfg = ARCHS["granite-3-2b"].reduced()
    params = zoo.init_params(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    }
    g_no, _ = trainer.grads_and_metrics(
        params, cfg, TrainConfig(grad_accum=1, remat=False), batch)
    g_rm, _ = trainer.grads_and_metrics(
        params, cfg, TrainConfig(grad_accum=1, remat=True), batch)
    for a, b in zip(jax.tree.leaves(g_no), jax.tree.leaves(g_rm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_adamw_minimises_quadratic():
    tcfg = TrainConfig(lr=0.1, warmup_steps=1, total_steps=100,
                       weight_decay=0.0, bf16_state=False)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params, tcfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(params, grads, state, tcfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_lr_schedule_shape():
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(tcfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9       # warmup rises
    assert lrs[-1] < lrs[15]                     # cosine decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9          # floor at 10%


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}           # norm 5
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_loss_decreases_end_to_end(key):
    from repro.launch.train import run
    losses = run("gemma3-1b", steps=15, batch=4, seq=32, lr=2e-3)
    assert losses[-1] < losses[0] * 0.8


def test_checkpoint_roundtrip(tmp_path, key):
    cfg = ARCHS["mamba2-370m"].reduced()
    params = zoo.init_params(key, cfg)
    d = str(tmp_path / "ckpt")
    ckpt_io.save(d, params, step=7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored, step = ckpt_io.restore(d, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path, key):
    cfg = ARCHS["mamba2-370m"].reduced()
    params = zoo.init_params(key, cfg)
    d = str(tmp_path / "ckpt2")
    ckpt_io.save(d, params, step=1)
    bad = jax.tree.map(lambda x: jnp.zeros(x.shape + (1,), x.dtype), params)
    with pytest.raises(ValueError):
        ckpt_io.restore(d, bad)
