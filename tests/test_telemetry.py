"""Serving telemetry invariants (PR 7).

* spans are emitted for EXACTLY the requests that terminate, with the
  terminal status matching the result record (including the zero-budget
  dropper and admission rejection);
* telemetry-enabled greedy output is token-identical to disabled (the
  collector is pure host-side bookkeeping);
* one ``_host_fetch`` sync per tick and ``stream_compiles == 1`` hold with
  telemetry ON;
* ``stats['serve_time']`` is single-entry (one ``finally``), surviving a
  mid-run exception;
* engine and scheduler counter views can NEVER diverge (the engine reads
  the scheduler's typed counters live instead of copy-and-zeroing);
* histogram bucketing/quantiles, the Prometheus snapshot, the StatsView
  dict API, and the Chrome trace structure.
"""
import json
import math

import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving import engine as engine_mod
from repro.serving import trace_export
from repro.serving.batcher import AdmissionQueue, Request
from repro.serving.engine import build_engine
from repro.serving.faults import STATUSES, FaultSchedule, RetryPolicy
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.telemetry import (Histogram, SchedCounters, StatsView,
                                     Telemetry)

STEPS = 3
KW = dict(buckets=(8, 16), num_slots=3, l_slots=2, page_size=8)

_STATE = {}


def _requests(n=7, dropper=True):
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(n):
        ln = int(rng.integers(4, 16))
        budget = 0.0 if (dropper and i == 5) else None
        reqs.append(Request(i, rng.integers(0, 500, ln).astype(np.int32),
                            max_new_tokens=STEPS, latency_budget=budget))
    return reqs


def _eng():
    if not _STATE:
        cfg = ARCHS["qwen2-1.5b"].reduced()
        _STATE["eng"] = build_engine(
            cfg, HIConfig(theta=0.6, capacity_factor=1.0),
            max_new_tokens=STEPS, cache_len=32)
    return _STATE["eng"]


# ---------------------------------------------------------------------------
# span completeness + terminal-status agreement
# ---------------------------------------------------------------------------

def test_spans_for_exactly_the_terminating_requests():
    eng = _eng()
    tel = Telemetry()
    res = eng.serve_stream(_requests(), telemetry=tel, validate=True, **KW)
    assert set(tel.traces) == set(res), \
        "span trees must exist for exactly the requests that terminated"
    for rid, rec in res.items():
        tr = tel.traces[rid]
        assert tr.complete
        assert tr.status == rec["status"] and tr.status in STATUSES
        kinds = [s.kind for s in tr.spans]
        assert kinds[0] == "queued" and kinds[-1] == "terminal"
        assert kinds.count("terminal") == 1, "exactly one terminal marker"
        assert "admitted" in kinds
        if rec["status"] == "ok" and rec["served_remote"]:
            assert "escalate_attempt" in kinds and "l_verify" in kinds
        # every span closed: no NaN end times survive termination
        assert all(math.isfinite(s.t1) for s in tr.spans)
    # structured records mirror the traces
    recs = {r["request_id"]: r for r in tel.request_records()}
    assert set(recs) == set(res)
    assert all(recs[r]["status"] == res[r]["status"] for r in res)


def test_enabled_output_token_identical_to_disabled():
    eng = _eng()
    base = eng.serve_stream(_requests(), validate=True, **KW)
    on = eng.serve_stream(_requests(), telemetry=Telemetry(),
                          validate=True, **KW)
    assert set(base) == set(on)
    for rid in base:
        np.testing.assert_array_equal(base[rid]["tokens"], on[rid]["tokens"])
        assert base[rid]["status"] == on[rid]["status"]
    assert eng.stats["stream_compiles"] == 1


def test_one_host_sync_per_tick_with_telemetry_on(monkeypatch):
    eng = _eng()
    tel = Telemetry()
    syncs = {"n": 0}
    real = engine_mod._host_fetch

    def counting(x):
        syncs["n"] += 1
        return real(x)

    monkeypatch.setattr(engine_mod, "_host_fetch", counting)
    ticks0 = eng.stats["stream_ticks"]
    eng.serve_stream(_requests(dropper=False), telemetry=tel, **KW)
    assert syncs["n"] == eng.stats["stream_ticks"] - ticks0 == len(tel.ticks)
    assert eng.stats["stream_compiles"] == 1


# ---------------------------------------------------------------------------
# satellite: serve_time single-entry
# ---------------------------------------------------------------------------

def test_serve_time_single_entry_on_exception(monkeypatch):
    """The old code added serve_time on each return path; the ``finally``
    must record it exactly once INCLUDING when the run dies mid-loop."""
    eng = _eng()
    cfg = ARCHS["qwen2-1.5b"].reduced()
    sched = ContinuousScheduler(
        eng.s, eng.l, HIConfig(theta=0.0, capacity_factor=1.0),
        max_prompt_len=16, max_new_tokens=STEPS, num_slots=2, l_slots=1,
        page_size=8, decode_block=2, prefix_sharing=False)
    rng = np.random.default_rng(3)
    queue = AdmissionQueue(buckets=(8, 16))
    queue.submit(Request(0, rng.integers(0, cfg.vocab_size, 8)
                         .astype(np.int32), max_new_tokens=STEPS))
    assert sched.stats["serve_time"] == 0.0

    def boom(theta_j):
        raise RuntimeError("injected dispatch failure")

    monkeypatch.setattr(sched, "_dispatch", boom)
    with pytest.raises(RuntimeError, match="injected"):
        sched.run(queue)
    t_fail = sched.stats["serve_time"]
    assert t_fail > 0.0, "the finally block must book the failed run's time"

    # a successful run books exactly one more increment
    monkeypatch.undo()
    queue2 = AdmissionQueue(buckets=(8, 16))
    queue2.submit(Request(1, rng.integers(0, cfg.vocab_size, 8)
                          .astype(np.int32), max_new_tokens=STEPS))
    sched.run(queue2)
    assert sched.stats["serve_time"] > t_fail


# ---------------------------------------------------------------------------
# satellite: engine/scheduler counter views never diverge
# ---------------------------------------------------------------------------

def test_engine_view_never_diverges_from_scheduler():
    eng = _eng()
    eng.serve_stream(_requests(), validate=True, **KW)
    sched = eng._stream[1]
    base0 = {k: eng.stats[k] for k in eng.stats}
    eng.serve_stream(_requests(), validate=True,
                     faults=FaultSchedule(seed=7, loss_prob=1.0),
                     retry=RetryPolicy(ack_timeout_ticks=2, max_retries=1,
                                       backoff_cap_ticks=2,
                                       breaker_threshold=2,
                                       breaker_cooldown_ticks=4), **KW)
    assert eng._stream[1] is sched, "scheduler must be reused (same config)"
    # mirrored keys: engine total == base before this run + scheduler delta
    # is implied by construction; what must hold OBSERVABLY is that engine
    # totals move in lock-step with the scheduler's counters
    for k in ("requests", "offloaded", "dropped", "degraded_local",
              "rejected", "breaker_open_ticks", "breaker_opens",
              "esc_retries", "esc_lost"):
        assert eng.stats[k] - base0[k] >= 0
    # the live identity: engine total minus retired base == scheduler live
    for ek, sk in (("requests", "requests"), ("stream_ticks", "ticks"),
                   ("degraded_local", "degraded_local"),
                   ("esc_retries", "esc_retries"),
                   ("esc_lost", "esc_lost")):
        assert eng.stats[ek] == getattr(eng.counters, ek) + sched.stats[sk]
    # a 100%-loss run with max_retries=1 must degrade every escalation, and
    # both views agree on the count
    assert sched.stats["degraded_local"] > 0
    assert eng.stats["degraded_local"] == \
        eng.counters.degraded_local + sched.counters.degraded_local
    # writes through the view stay arithmetically exact under a live mirror
    before = eng.stats["requests"]
    eng.stats["requests"] += 5
    assert eng.stats["requests"] == before + 5
    eng.stats["requests"] -= 5


# ---------------------------------------------------------------------------
# primitives: StatsView, Histogram, Prometheus snapshot, Chrome trace
# ---------------------------------------------------------------------------

def test_stats_view_dict_api():
    c = SchedCounters()
    v = StatsView(c)
    v["ticks"] += 3
    assert c.ticks == 3 and v["ticks"] == 3
    assert "ticks" in v and len(v) == len(dict(v))
    assert dict(**v)["ticks"] == 3            # ** unpacking (summary())
    with pytest.raises(KeyError):
        v["not_a_counter"]
    with pytest.raises(KeyError):
        v["not_a_counter"] = 1
    with pytest.raises(TypeError):
        del v["ticks"]


def test_histogram_buckets_and_quantiles():
    h = Histogram(lo=1e-3, hi=10.0)
    for v in (0.0005, 0.002, 0.002, 0.004, 0.008, 5.0):
        h.record(v)
    h.record(float("nan"))                     # ignored
    s = h.summary()
    assert s["count"] == 6
    assert s["min"] == pytest.approx(0.0005) and s["max"] == pytest.approx(5.0)
    assert s["mean"] == pytest.approx(sum((0.0005, 0.002, 0.002, 0.004,
                                           0.008, 5.0)) / 6)
    # p50 lands in the [2ms, 4ms) bucket; p99 in the overflow-side bucket
    assert 0.001 <= s["p50"] <= 0.004
    assert s["p99"] <= 5.0 and s["p99"] >= 1.0
    # monotone quantiles
    assert s["p50"] <= s["p95"] <= s["p99"]
    empty = Histogram()
    assert empty.summary() == {"count": 0}
    assert math.isnan(empty.quantile(0.5))


def test_prometheus_snapshot_keys():
    eng = _eng()
    tel = Telemetry()
    eng.serve_stream(_requests(), telemetry=tel, **KW)
    txt = tel.prometheus_text()
    for key in ("hi_requests_total", "hi_degraded_local_total",
                "hi_ticks_total",
                'hi_tick_phase_seconds_total{phase="dispatch"}',
                'hi_tick_phase_seconds_total{phase="host_fetch"}',
                'hi_gauge{name="free_pages",tier="S"}',
                'hi_gauge{name="breaker_state"}',
                "hi_ttft_seconds_count", "hi_ttft_seconds_sum",
                'hi_ttft_seconds_bucket{le="+Inf"}',
                "hi_tpot_seconds_count", "hi_queue_wait_ticks_count"):
        assert key in txt, f"missing Prometheus key: {key}"


def test_chrome_trace_structure(tmp_path):
    eng = _eng()
    tel = Telemetry()
    res = eng.serve_stream(_requests(), telemetry=tel, validate=True, **KW)
    path = tmp_path / "trace.json"
    doc = trace_export.write_chrome_trace(tel, str(path))
    loaded = json.loads(path.read_text())
    assert loaded["traceEvents"], "trace must not be empty"
    ev = doc["traceEvents"]
    # one complete span tree per request: a terminal instant per request,
    # status matching the result record
    terminals = {e["args"]["request_id"]: e for e in ev
                 if e["ph"] == "i" and e["name"].startswith("terminal:")}
    assert set(terminals) == set(res)
    for rid, rec in res.items():
        assert terminals[rid]["name"] == f"terminal:{rec['status']}"
    # tick-phase slices on the scheduler track
    phases = {e["name"] for e in ev if e.get("pid") == 0 and e["ph"] == "X"}
    assert {"build_operands", "dispatch", "host_fetch"} <= phases
    # escalations drawn as S->L flows: starts pair with finishes by id
    starts = {e["id"] for e in ev if e["ph"] == "s"}
    finishes = {e["id"] for e in ev if e["ph"] == "f"}
    served_remote = {r for r, rec in res.items() if rec["served_remote"]}
    assert served_remote <= starts, "every served escalation has a flow start"
    assert served_remote <= finishes, "and a flow finish on the L track"
    # counter (gauge) events exist
    assert any(e["ph"] == "C" for e in ev)
    # timestamps are relative: nothing starts before 0
    assert min(e["ts"] for e in ev if "ts" in e) >= 0.0
