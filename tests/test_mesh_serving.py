"""Mesh-sharded tier-split serving (scheduler ``mesh=`` / engine
``serve_stream(mesh=...)``).

The contract under test, per invariant:

* a (1, 1) DEBUG mesh is semantics-free — greedy outputs are
  token-identical (bitwise at kv_dtype="bf16") to the single-device path,
  in both kv_dtype modes, with prefix sharing and chunked prefill on;
* ``stream_compiles`` stays 1 and the tick keeps exactly ONE host fetch
  with every mesh feature on (the staging buffer, the shard_map'd S tier,
  the GSPMD-sharded L tier add operands and lanes, never syncs);
* every per-replica KV-pool shard passes ``check_invariants`` (and holds
  no slots) after an escalation-heavy faulted run — the transfer staging
  path leaks nothing;
* data=2: two S replicas, each owning a disjoint slot slice + its own pool
  shard, still reproduce the single-device tokens (subprocess with 8
  forced host devices — the established tests/test_tier_split.py pattern).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.launch.mesh import make_serving_mesh
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving import engine as engine_mod
from repro.serving.faults import FaultSchedule, RetryPolicy

STEPS = 4
KW = dict(buckets=(8,), num_slots=2, page_size=8)


def _reqs(cfg, n):
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=STEPS) for i in range(n)]


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["qwen2-1.5b"].reduced()


@pytest.fixture(scope="module")
def ref(cfg):
    """Single-device reference records per kv_dtype (theta 0.5 mixes local
    finishes with escalations, so the staging path is load-bearing)."""
    out = {}
    for kv in ("bf16", "int8"):
        e = build_engine(cfg, HIConfig(theta=0.5, capacity_factor=1.0),
                         max_new_tokens=STEPS, cache_len=32)
        out[kv] = e.serve_stream(_reqs(cfg, 6), validate=True,
                                 kv_dtype=kv, **KW)
    return out


@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_debug_mesh_token_identity(cfg, ref, kv):
    """(1, 1) mesh: the shard_map'd S tier, the sharded L tier, and the
    double-buffered escalation staging produce BITWISE the single-device
    greedy tokens, statuses, and offload decisions — in both KV modes."""
    e = build_engine(cfg, HIConfig(theta=0.5, capacity_factor=1.0),
                     max_new_tokens=STEPS, cache_len=32)
    out = e.serve_stream(_reqs(cfg, 6), validate=True, kv_dtype=kv,
                         mesh=make_serving_mesh(1, 1), **KW)
    assert out.keys() == ref[kv].keys()
    for rid, a in ref[kv].items():
        b = out[rid]
        assert np.array_equal(a["tokens"], b["tokens"]), rid
        assert a["status"] == b["status"]
        assert a["offloaded"] == b["offloaded"]
    assert e.stats["stream_compiles"] == 1


def test_mesh_one_fetch_per_tick_all_features(cfg, monkeypatch):
    """With prefix sharing + chunked prefill + the mesh staging path all on,
    the tick discipline holds: ONE compile, exactly ONE host fetch per tick."""
    calls = {"n": 0}
    real = engine_mod._host_fetch

    def counting(tree):
        calls["n"] += 1
        return real(tree)

    monkeypatch.setattr(engine_mod, "_host_fetch", counting)
    e = build_engine(cfg, HIConfig(theta=0.5, capacity_factor=1.0),
                     max_new_tokens=STEPS, cache_len=32)
    e.serve_stream(_reqs(cfg, 6), validate=True, prefix_sharing=True,
                   chunk_prefill=True, chunk_size=4,
                   mesh=make_serving_mesh(1, 1), **KW)
    assert e.stats["stream_compiles"] == 1
    assert calls["n"] == e.stats["stream_ticks"] > 0


def test_mesh_pool_shards_clean_after_faulted_escalations(cfg):
    """Escalation-heavy faulted traffic (theta > 1: everything wants L;
    losses + an outage exercise retry/breaker/degrade): afterwards every
    replica pool shard and the L pool pass check_invariants with no held
    slots, and every record terminates with a legal status."""
    fs = FaultSchedule(seed=7, loss_prob=0.3, delay_ticks=1, delay_jitter=2,
                       outages=((4, 8),))
    rp = RetryPolicy(max_retries=2, backoff_base_ticks=1, backoff_cap_ticks=4,
                     breaker_threshold=3, breaker_cooldown_ticks=4)
    e = build_engine(cfg, HIConfig(theta=1.1, capacity_factor=1.0),
                     max_new_tokens=STEPS, cache_len=32)
    out = e.serve_stream(_reqs(cfg, 8), faults=fs, retry=rp, validate=True,
                         mesh=make_serving_mesh(1, 1), **KW)
    assert len(out) == 8
    assert all(r["status"] in ("ok", "degraded_local", "dropped", "rejected")
               for r in out.values())
    sched = e._stream[1]
    for rt in (*sched.srts, sched.lrt):
        rt.pool.check_invariants()
        assert all(r is None for r in rt.slot_req)
    assert e.stats["stream_compiles"] == 1


def test_mesh_rejects_bad_configs(cfg):
    """Guard rails: a mesh without the serving axes, and speculative +
    mesh, fail loudly at construction."""
    from repro.serving.scheduler import ContinuousScheduler
    e = build_engine(cfg, HIConfig(theta=0.5, capacity_factor=1.0),
                     max_new_tokens=STEPS, cache_len=32)
    with pytest.raises(NotImplementedError, match="speculative"):
        ContinuousScheduler(e.s, e.l, e.hi, max_prompt_len=8,
                            max_new_tokens=STEPS, num_slots=2, page_size=8,
                            speculative=True, mesh=make_serving_mesh(1, 1))


_DATA2_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert len(jax.devices()) == 8
    from repro.configs.base import HIConfig
    from repro.configs.registry import ARCHS
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.batcher import Request
    from repro.serving.engine import build_engine

    STEPS = 4
    KW = dict(buckets=(8,), num_slots=2, page_size=8)
    cfg = ARCHS["qwen2-1.5b"].reduced()
    def reqs(n):
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=STEPS)
                for i in range(n)]
    hi = HIConfig(theta=0.5, capacity_factor=1.0)
    e1 = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    ref = e1.serve_stream(reqs(8), validate=True, **KW)
    # data=2 (replica-sliced S slots), then the full (2, 2) mesh with the
    # L tier's params + KV pages sharded over model
    for shape in ((2, 1), (2, 2)):
        e2 = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
        out = e2.serve_stream(reqs(8), validate=True,
                              mesh=make_serving_mesh(*shape), **KW)
        for rid in ref:
            assert np.array_equal(ref[rid]["tokens"], out[rid]["tokens"]), \\
                (shape, rid)
            assert ref[rid]["status"] == out[rid]["status"]
        assert e2.stats["stream_compiles"] == 1
        sched = e2._stream[1]
        assert len(sched.srts) == shape[0]
        for rt in (*sched.srts, sched.lrt):
            rt.pool.check_invariants()
    print("MESH_DATA2_OK")
""")


def test_data2_replica_equivalence_subprocess():
    """data=2 / (2, 2) meshes on a forced 8-device host reproduce the
    single-device tokens with one compile and clean per-shard pools."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DATA2_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert "MESH_DATA2_OK" in out.stdout, out.stdout + out.stderr
