"""Fault-tolerant escalation across the S→L serving path: deterministic
seeded injection (serving/faults.py), retry with capped backoff, the
fail-local circuit breaker (closed → open → half-open), bounded admission
rejection, the arXiv:2112.11413 drop policy's resource accounting, and
leak-free degradation — all host-side, with the ONE compiled tick
executable untouched."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.batcher import Request
from repro.serving.engine import build_engine
from repro.serving.faults import CircuitBreaker, FaultSchedule, RetryPolicy

STEPS = 3
KW = dict(buckets=(8,), num_slots=2, page_size=8)


def _reqs(cfg, n, **kw):
    rng = np.random.default_rng(0)
    return [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=STEPS, **kw) for i in range(n)]


@pytest.fixture(scope="module")
def eng():
    """One engine (ONE compiled tick executable) shared by every fault
    scenario below — fault schedules are per-run operand state, so reuse
    across wildly different schedules is itself part of the test."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    # theta 1.1 > any confidence: every request wants escalation, which
    # maximises the faulted path's exposure
    return cfg, build_engine(cfg, HIConfig(theta=1.1, capacity_factor=1.0),
                             max_new_tokens=STEPS, cache_len=32)


@pytest.fixture(scope="module")
def ref(eng):
    """Fault-free reference outputs on the shared traffic."""
    cfg, e = eng
    # 8 covers every test below (the _reqs stream is a deterministic prefix:
    # the first n requests are identical for any n)
    return e.serve_stream(_reqs(cfg, 8), validate=True, **KW)


# ---------------------------------------------------------------------------
# faults.py units
# ---------------------------------------------------------------------------
def test_fault_schedule_deterministic():
    """Every transit decision is a pure function of (seed, rid, attempt):
    replaying a schedule — in any call order — yields identical faults."""
    fs = FaultSchedule(seed=7, loss_prob=0.4, delay_ticks=1, delay_jitter=3)
    draws = [(rid, att, fs.transit(rid, att))
             for rid in range(20) for att in range(3)]
    for rid, att, d in reversed(draws):          # different call order
        assert fs.transit(rid, att) == d
    assert any(d is None for _, _, d in draws)   # losses occur
    kept = [d for _, _, d in draws if d is not None]
    assert kept and all(1 <= d <= 4 for d in kept)
    # a different seed gives a different fault sequence
    fs2 = FaultSchedule(seed=8, loss_prob=0.4, delay_ticks=1, delay_jitter=3)
    assert [fs2.transit(r, a) for r, a, _ in draws] != [d for _, _, d in draws]
    # window queries
    fs3 = FaultSchedule(outages=((2, 5),), spikes=((7, 9),))
    assert not fs3.in_outage(1) and fs3.in_outage(2) and fs3.in_outage(4)
    assert not fs3.in_outage(5)                  # [a, b) half-open
    assert fs3.l_paused(8) and not fs3.l_paused(6)


def test_circuit_breaker_state_machine():
    """closed → open on CONSECUTIVE failures, cooldown → half-open, probe
    failure re-opens, probe success closes and resets the failure count."""
    pol = RetryPolicy(breaker_threshold=3, breaker_cooldown_ticks=5)
    brk = CircuitBreaker(pol)
    brk.record_failure(0)
    brk.record_success()                         # success resets the streak
    brk.record_failure(1)
    brk.record_failure(1)
    assert brk.state_at(2) == CircuitBreaker.CLOSED
    brk.record_failure(2)                        # 3rd consecutive: opens
    assert brk.state == CircuitBreaker.OPEN and brk.opens == 1
    assert brk.state_at(6) == CircuitBreaker.OPEN
    assert brk.state_at(7) == CircuitBreaker.HALF_OPEN
    brk.record_failure(7)                        # probe fails: re-opens
    assert brk.state == CircuitBreaker.OPEN and brk.opens == 2
    assert brk.state_at(12) == CircuitBreaker.HALF_OPEN
    brk.record_success()                         # probe succeeds: closes
    assert brk.state == CircuitBreaker.CLOSED and brk.failures == 0


def test_speculative_mode_rejects_active_faults():
    """Fault injection models the S→L escalation QUEUE; the fused
    speculative cascade has none, so an active schedule is refused."""
    from repro.serving.scheduler import ContinuousScheduler
    sched = ContinuousScheduler.__new__(ContinuousScheduler)
    sched.speculative = True
    with pytest.raises(ValueError, match="speculative"):
        ContinuousScheduler.set_faults(sched, FaultSchedule(loss_prob=0.5))


# ---------------------------------------------------------------------------
# scheduler resilience, end to end
# ---------------------------------------------------------------------------
def test_lost_escalations_degrade_local(eng, ref):
    """Total escalation loss: retries exhaust, every request degrades to its
    S-tier answer (token-identical to the fault-free S run), pages don't
    leak, and the executable never recompiles."""
    cfg, e = eng
    deg0 = e.stats["degraded_local"]
    out = e.serve_stream(
        _reqs(cfg, 6), validate=True,
        faults=FaultSchedule(seed=3, loss_prob=1.0),
        retry=RetryPolicy(ack_timeout_ticks=1, max_retries=1,
                          breaker_threshold=100),   # isolate the retry path
        **KW)
    assert set(out) == set(range(6))
    for rid, rec in out.items():
        assert rec["status"] == "degraded_local"
        assert rec["offloaded"] and not rec["served_remote"]
        np.testing.assert_array_equal(rec["tokens"], rec["s_tokens"])
        np.testing.assert_array_equal(rec["tokens"], ref[rid]["s_tokens"])
        assert rec["escalation_retries"] == 1
    assert e.stats["degraded_local"] - deg0 == 6
    assert e.stats["esc_lost"] >= 6
    sched = e._stream[1]
    assert sched.srt.pool.held_slots == [] and sched.lrt.pool.held_slots == []
    assert e.stats["stream_compiles"] == 1


def test_outage_opens_breaker_then_recovers(eng, ref):
    """An L outage window aborts in-flight L work (leak-free), consecutive
    failures open the breaker into fail-local mode, and after the window +
    cooldown the half-open probe re-admits escalations — later requests are
    served remote again, with outputs identical to the fault-free run."""
    cfg, e = eng
    opens0 = e.stats["breaker_opens"]
    open_ticks0 = e.stats["breaker_open_ticks"]
    out = e.serve_stream(
        _reqs(cfg, 8), validate=True,
        faults=FaultSchedule(seed=5, outages=((1, 4),)),
        retry=RetryPolicy(ack_timeout_ticks=1, max_retries=1,
                          breaker_threshold=2, breaker_cooldown_ticks=2),
        **KW)
    statuses = {rid: rec["status"] for rid, rec in out.items()}
    assert set(statuses.values()) <= {"ok", "degraded_local"}
    assert "degraded_local" in statuses.values()      # outage casualties
    assert "ok" in statuses.values()                  # post-outage recovery
    for rid, rec in out.items():
        np.testing.assert_array_equal(rec["s_tokens"], ref[rid]["s_tokens"])
        if rec["status"] == "ok":
            assert rec["served_remote"]
            np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])
        else:
            np.testing.assert_array_equal(rec["tokens"], rec["s_tokens"])
    assert e.stats["breaker_opens"] > opens0
    assert e.stats["breaker_open_ticks"] > open_ticks0
    sched = e._stream[1]
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()
    assert sched.srt.pool.held_slots == [] and sched.lrt.pool.held_slots == []
    assert e.stats["stream_compiles"] == 1


def test_pure_delay_keeps_outputs_identical(eng, ref):
    """Delivery delay alone (no loss, no windows) only stretches the queue
    wait: every escalation still lands on L and outputs are token-identical
    to the fault-free run."""
    cfg, e = eng
    out = e.serve_stream(
        _reqs(cfg, 6), validate=True,
        faults=FaultSchedule(seed=11, delay_ticks=2, delay_jitter=2),
        retry=RetryPolicy(ack_timeout_ticks=8), **KW)
    for rid, rec in out.items():
        assert rec["status"] == "ok" and rec["served_remote"]
        assert rec["queue_wait_ticks"] >= 2
        np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])
    assert e.stats["stream_compiles"] == 1


def test_drop_expired_releases_every_l_resource(eng):
    """Satellite check for the arXiv:2112.11413 drop path: a queued
    escalation reserves NOTHING on the L side at S-finish time (lookup and
    page claim both happen at L admission), so repeated drops must leave the
    L pool byte-for-byte free and both pools' invariants intact."""
    cfg, e = eng
    sched = e._stream[1] if e._stream else None
    for _ in range(2):                        # repeated drops, warm index
        out = e.serve_stream(_reqs(cfg, 6, latency_budget=0.0),
                             validate=True, **KW)
        sched = e._stream[1]
        for rec in out.values():
            assert rec["status"] == "dropped" and rec["dropped"]
            assert rec["offloaded"] and not rec["served_remote"]
        sched.srt.pool.check_invariants()
        sched.lrt.pool.check_invariants()
        # the L tier never admitted anything: no slots held, and every
        # non-null page is free or index-retained from EARLIER (ok) runs
        assert sched.lrt.pool.held_slots == []
    assert e.stats["stream_compiles"] == 1


def test_admission_rejection_is_bounded():
    """Satellite regression: a prompt whose page demand can NEVER be
    satisfied used to spin forever at the queue head (scheduler.py
    ``queue.appendleft``); it must now fail with ``status='rejected'`` and a
    clear warning after ``admit_retry_limit`` fruitless ticks, while
    satisfiable traffic behind it is still served."""
    from repro.serving.batcher import AdmissionQueue
    from repro.serving.scheduler import ContinuousScheduler

    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)    # S-only
    eng = build_engine(cfg, hi, max_new_tokens=STEPS, cache_len=32)
    # 2 usable pages (num_pages=3 incl. the null page): a 16-bucket prompt
    # needs 3 pages of context and can never be admitted; an 8-bucket one
    # needs 2 and fits
    sched = ContinuousScheduler(
        eng.s, eng.l, hi, max_prompt_len=16, max_new_tokens=STEPS,
        num_slots=2, l_slots=1, page_size=8, decode_block=2,
        prefix_sharing=False, num_pages=3)
    sched.set_faults(policy=RetryPolicy(admit_retry_limit=4))
    rng = np.random.default_rng(2)
    queue = AdmissionQueue(buckets=(8, 16))
    queue.submit(Request(0, rng.integers(0, cfg.vocab_size, 16)
                         .astype(np.int32), max_new_tokens=STEPS))
    queue.submit(Request(1, rng.integers(0, cfg.vocab_size, 8)
                         .astype(np.int32), max_new_tokens=STEPS))
    with pytest.warns(RuntimeWarning, match="rejected"):
        results = sched.run(queue)
    assert set(results) == {0, 1}
    assert results[0]["status"] == "rejected"
    assert len(results[0]["tokens"]) == 0
    assert results[1]["status"] == "ok"
    assert len(results[1]["tokens"]) == STEPS
    assert sched.stats["rejected"] == 1
    sched.srt.pool.check_invariants()
    sched.lrt.pool.check_invariants()
    assert sched.srt.pool.held_slots == [] and sched.lrt.pool.held_slots == []


def test_spike_window_delays_but_serves(eng, ref):
    """A latency-spike window pauses L admission without failing anything:
    escalations wait it out in the queue and are then served remote with
    fault-free-identical outputs; the wait is visible in the records."""
    cfg, e = eng
    out = e.serve_stream(
        _reqs(cfg, 6), validate=True,
        faults=FaultSchedule(seed=13, spikes=((0, 8),)), **KW)
    for rid, rec in out.items():
        assert rec["status"] == "ok" and rec["served_remote"]
        np.testing.assert_array_equal(rec["tokens"], ref[rid]["tokens"])
    assert any(rec["queue_wait_ticks"] >= 3 for rec in out.values())
    assert e.stats["stream_compiles"] == 1
