"""Model-level correctness: decode-with-cache must equal full forward
(teacher forcing) for every family — the strongest serving-path invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import model_zoo as zoo
from repro.models import transformer, whisper

B, S = 2, 12
CACHE = 16

DECODER_FAMS = ["granite-3-2b", "mamba2-370m", "deepseek-moe-16b",
                "zamba2-2.7b", "gemma3-1b"]


def _stepwise_logits(params, cfg, tokens, cache):
    logits = None
    for t in range(tokens.shape[1]):
        logits, cache = zoo.decode_step(params, cfg, tokens[:, t:t + 1], cache)
    return logits


@pytest.mark.parametrize("arch", DECODER_FAMS)
def test_decode_matches_forward(arch, key):
    cfg = ARCHS[arch].reduced()
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "moe":
        # teacher-forcing equivalence needs drop-free dispatch: capacity
        # factor E/k guarantees no expert overflows in either path
        from repro.models import moe
        nodrop = cfg.num_experts / cfg.experts_per_token
        full_logits, _ = moe.forward(params, cfg, tokens,
                                     capacity_factor=nodrop)
    else:
        full_logits, _ = zoo.forward(params, cfg, {"tokens": tokens})
    cache = zoo.init_cache(cfg, B, CACHE, dtype=jnp.float32)
    last = _stepwise_logits(params, cfg, tokens, cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, -1, :]),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_forward(key):
    cfg = ARCHS["whisper-large-v3"].reduced()
    params = zoo.init_params(key, cfg)
    frames = jax.random.normal(key, (B, cfg.num_audio_frames, cfg.d_model))
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = zoo.forward(params, cfg, {"tokens": tokens,
                                               "frames": frames})
    cache = zoo.init_cache(cfg, B, CACHE, dtype=jnp.float32)
    cache = whisper.precompute_cross(params, cfg, frames, cache)
    last = _stepwise_logits(params, cfg, tokens, cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, -1, :]),
                               rtol=2e-3, atol=2e-3)


def test_dense_prefill_matches_stepwise(key):
    """Bulk prefill (one forward emitting the KV cache) == token-by-token."""
    cfg = ARCHS["qwen2-1.5b"].reduced()
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits_bulk, cache_bulk = transformer.prefill(
        params, cfg, tokens, zoo.init_cache(cfg, B, CACHE, dtype=jnp.float32))
    cache = zoo.init_cache(cfg, B, CACHE, dtype=jnp.float32)
    logits_step = _stepwise_logits(params, cfg, tokens, cache)
    np.testing.assert_allclose(np.asarray(logits_bulk),
                               np.asarray(logits_step), rtol=2e-3, atol=2e-3)
    assert int(cache_bulk["pos"]) == S
    # continuing decode from the bulk cache works and matches shapes
    nxt = jnp.argmax(logits_bulk, -1)[:, None].astype(jnp.int32)
    logits2, _ = zoo.decode_step(params, cfg, nxt, cache_bulk)
    assert logits2.shape == (B, cfg.vocab_size)


def test_vlm_patch_prefix(key):
    """VLM logits cover [patches | text] and text-loss slicing is consistent."""
    cfg = ARCHS["llava-next-34b"].reduced()
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    patches = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model))
    logits, _ = zoo.forward(params, cfg, {"tokens": tokens, "patches": patches})
    assert logits.shape == (B, cfg.num_patches + S, cfg.vocab_size)
    # changing a patch changes text logits (the prefix is attended to)
    patches2 = patches.at[:, 0].add(5.0)
    logits2, _ = zoo.forward(params, cfg, {"tokens": tokens,
                                           "patches": patches2})
    assert float(jnp.abs(logits2[:, -1] - logits[:, -1]).max()) > 1e-4


def test_gemma3_local_global_windows():
    cfg = ARCHS["gemma3-1b"]
    w = transformer.layer_windows(cfg, 8192)
    w = np.asarray(w)
    assert (w == 8192).sum() == cfg.num_layers // 6   # every 6th is global
    assert (w == 1024).sum() == cfg.num_layers - cfg.num_layers // 6


def test_sliding_window_changes_attention(key):
    """danube's SWA must actually mask: long-range token influence dies."""
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    # reduced() caps the window at 64 >= S, so shrink it to bite
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=4)
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, 10), 0, cfg.vocab_size)
    logits1, _ = zoo.forward(params, cfg, {"tokens": tokens})
    tokens2 = tokens.at[:, 0].set((tokens[:, 0] + 1) % cfg.vocab_size)
    logits2, _ = zoo.forward(params, cfg, {"tokens": tokens2})
    # position 9 is > window away from position 0: unchanged
    np.testing.assert_allclose(np.asarray(logits1[:, -1]),
                               np.asarray(logits2[:, -1]), atol=1e-5)
    # position 1 IS within the window of position 0: changed
    assert float(jnp.abs(logits1[:, 1] - logits2[:, 1]).max()) > 1e-4


def test_moe_capacity_drops_are_bounded(key):
    from repro.models import moe
    cfg = ARCHS["deepseek-moe-16b"].reduced()
    params = zoo.init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    # generous capacity: result must be close to capacity=huge
    l1, _ = moe.forward(params, cfg, tokens, capacity_factor=8.0)
    l2, _ = moe.forward(params, cfg, tokens, capacity_factor=16.0)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


def test_split_cache_decode_matches_uniform(key):
    """Ring-buffer local caches == the uniform full cache (danube + gemma3)."""
    import dataclasses
    for arch, patch in (("h2o-danube-3-4b", dict(sliding_window=4)),
                        ("gemma3-1b", dict(sliding_window=4))):
        cfg = dataclasses.replace(ARCHS[arch].reduced(), **patch)
        params = zoo.init_params(key, cfg)
        tokens = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
        uni = zoo.init_cache(cfg, 2, CACHE, dtype=jnp.float32)
        spl = transformer.init_split_cache(cfg, 2, CACHE, dtype=jnp.float32)
        last_u = last_s = None
        for t in range(tokens.shape[1]):
            tok = tokens[:, t:t + 1]
            last_u, uni = transformer.decode_step(params, cfg, tok, uni)
            last_s, spl = transformer.decode_step_split(params, cfg, tok, spl)
        np.testing.assert_allclose(np.asarray(last_s), np.asarray(last_u),
                                   rtol=2e-3, atol=2e-3)
