"""shard_map expert-parallel dispatch == local scatter dispatch.

Needs >1 device, so it runs in a subprocess with forced host devices (tests
themselves must keep the 1-device view; see conftest)."""
import os
import subprocess
import sys
import textwrap


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.registry import ARCHS
    from repro.models import moe, model_zoo

    cfg = ARCHS["deepseek-moe-16b"].reduced()      # 4 experts, top-2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = jax.random.PRNGKey(0)
    params = model_zoo.init_params(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    lp = jax.tree.map(lambda a: a[0], params["layers"])   # layer 0

    nodrop = float(cfg.num_experts / cfg.experts_per_token)
    y_local, aux_local = jax.jit(
        lambda lp, x: moe.moe_ffn(lp, cfg, x, nodrop))(lp, x)
    with mesh:
        y_shard, aux_shard = jax.jit(
            lambda lp, x: moe.moe_ffn_sharded(lp, cfg, x, nodrop, mesh))(lp, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_shard),
                               rtol=2e-4, atol=2e-4)
    # aux differs by design: per-data-shard load-balance stats averaged via
    # pmean vs one global statistic (Jensen gap) — still O(1) and same scale
    np.testing.assert_allclose(float(aux_local), float(aux_shard),
                               rtol=0.05, atol=0.05)
    print("MOE_SHARDED_OK")
""")


def test_moe_sharded_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert "MOE_SHARDED_OK" in out.stdout, out.stdout + out.stderr
