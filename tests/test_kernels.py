"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# hi_gate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", ["max_prob", "margin", "entropy"])
@pytest.mark.parametrize("n,c", [(8, 10), (33, 7), (64, 101), (16, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hi_gate_sweep(metric, n, c, dtype):
    logits = jnp.asarray(RNG.normal(size=(n, c)) * 3).astype(dtype)
    conf_k, pred_k, off_k = ops.hi_gate(logits, 0.55, metric)
    conf_r, pred_r, off_r = ref.hi_gate_ref(logits, 0.55, metric)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(conf_k, conf_r, rtol=tol, atol=tol)
    # argmax/threshold can differ only at exact ties — none with random data
    assert (pred_k == pred_r).all()
    assert (off_k == off_r).all()


def test_hi_gate_threshold_semantics():
    logits = jnp.asarray([[10.0, -10.0], [0.1, 0.0]])
    conf, pred, off = ops.hi_gate(logits, 0.9, "max_prob")
    assert off[0] == 0 and off[1] == 1       # confident kept, uncertain offloads
    assert pred[0] == 0


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,k,d", [
    (1, 128, 4, 1, 16), (2, 256, 8, 2, 32), (2, 192, 6, 6, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, k, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d))).astype(dtype)
    ck = jnp.asarray(RNG.normal(size=(b, s, k, d))).astype(dtype)
    cv = jnp.asarray(RNG.normal(size=(b, s, k, d))).astype(dtype)
    pos = s // 3
    valid = jnp.arange(s) <= pos
    out = ops.decode_attention(q, ck, cv, valid, block_s=64)
    outr = ref.decode_attention_ref(q, ck, cv, valid)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(outr, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_sliding_window():
    """A window mask must exactly drop old positions."""
    b, s, h, k, d = 1, 128, 2, 1, 16
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(b, s, k, d)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(b, s, k, d)), jnp.float32)
    pos, win = 100, 16
    kpos = jnp.arange(s)
    valid = (kpos <= pos) & (pos - kpos < win)
    out = ops.decode_attention(q, ck, cv, valid, block_s=32)
    outr = ref.decode_attention_ref(q, ck, cv, valid)
    np.testing.assert_allclose(out, outr, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 64, 2, 8, 4, 16), (2, 96, 4, 16, 8, 32), (1, 80, 3, 8, 16, 16),
])
def test_ssd_kernel_vs_chunked_ref(b, l, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, l, h)), jnp.float32) * 0.5
    A = -jnp.asarray(RNG.random(h), jnp.float32) - 0.2
    B = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
    y_k, hT_k = ops.ssd(x, dt, A, B, C, chunk=chunk)
    y_r, hT_r = ref.ssd_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y_k, y_r, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hT_k, hT_r, rtol=3e-4, atol=3e-4)


def test_ssd_chunked_matches_naive_recurrence():
    """The chunked algorithm IS the recurrence (state-space duality)."""
    b, l, h, p, n = 2, 48, 2, 8, 4
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, l, h)), jnp.float32) * 0.5
    A = -jnp.asarray(RNG.random(h), jnp.float32) - 0.2
    B = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
    y_r, _ = ref.ssd_ref(x, dt, A, B, C, chunk=16)
    y_n = ref.ssd_naive_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_n),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_independence():
    """Chunk size must not change the result."""
    b, l, h, p, n = 1, 64, 2, 8, 4
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, l, h)), jnp.float32) * 0.5
    A = -jnp.asarray(RNG.random(h), jnp.float32) - 0.5
    B = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, l, n)), jnp.float32)
    y16, _ = ops.ssd(x, dt, A, B, C, chunk=16)
    y64, _ = ops.ssd(x, dt, A, B, C, chunk=64)
    np.testing.assert_allclose(y16, y64, rtol=3e-4, atol=3e-4)


def test_streamed_decode_matches_sdpa():
    """The jnp streaming decode path (local serving) == full-row attention."""
    from repro.models import layers as L
    b, s, h, k, d = 2, 8192, 8, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
    ck = jnp.asarray(RNG.normal(size=(b, s, k, d)), jnp.float32)
    cv = jnp.asarray(RNG.normal(size=(b, s, k, d)), jnp.float32)
    valid = jnp.arange(s) <= 5000
    out_s = L._decode_attn_streamed(q, ck, cv, valid, 2048)
    out_f = L._sdpa(q, ck, cv, valid[None, None, :])
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_f),
                               rtol=3e-5, atol=3e-5)


def test_decode_attention_paged_matches_gather_oracle():
    """The paged kernel (block table as scalar-prefetch, physical pages DMA'd
    by the index_map) == gathering the pages and running dense attention."""
    from repro.models import layers as L
    rng = np.random.default_rng(3)
    b, h, k, d, page, npg, P = 3, 4, 2, 16, 8, 4, 13
    q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((P, page, k, d)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((P, page, k, d)), jnp.float32)
    block = jnp.asarray(rng.integers(0, P, (b, npg)), jnp.int32)
    pos = jnp.asarray([5, 17, 31], jnp.int32)       # per-slot depths
    valid = jnp.arange(npg * page)[None, :] <= pos[:, None]
    out = ops.decode_attention_paged(q, pk, pv, block, valid)
    kk = pk[block].reshape(b, npg * page, k, d)
    vv = pv[block].reshape(b, npg * page, k, d)
    ref_out = L._sdpa(q, kk, vv, valid[:, None, :])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=3e-5, atol=3e-5)
