"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(2 layers, d_model<=512, <=4 experts) and runs one forward/train step + one
decode step on CPU, asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS
from repro.models import model_zoo as zoo
from repro.optim import adamw
from repro.training import trainer

ALL_ARCHS = sorted(ARCHS)
B, S = 2, 16


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_audio_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_limits(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch, key):
    cfg = ARCHS[arch].reduced()
    params = zoo.init_params(key, cfg)
    batch = _batch(cfg, key)

    logits, aux = jax.jit(lambda p, b: zoo.forward(p, cfg, b))(params, batch)
    exp_s = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert jnp.isfinite(logits).all()

    tcfg = TrainConfig(grad_accum=1, bf16_state=False, remat=False)
    opt = adamw.init_state(params, tcfg)
    step = jax.jit(trainer.make_train_step(cfg, tcfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, params2))
    assert moved > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch, key):
    cfg = ARCHS[arch].reduced()
    params = zoo.init_params(key, cfg)
    cache = zoo.init_cache(cfg, B, 32)
    if cfg.family == "encdec":
        from repro.models import whisper
        frames = jax.random.normal(key, (B, cfg.num_audio_frames, cfg.d_model))
        cache = whisper.precompute_cross(params, cfg, frames, cache)
    token = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = jax.jit(
        lambda p, t, c: zoo.decode_step(p, cfg, t, c))(params, token, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert int(cache2["pos"]) == 1
