"""KVPool: page alloc/free invariants and no cross-request page leakage
after slot reuse."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.kv_pool import KVPool


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["qwen2-1.5b"].reduced()


def test_alloc_free_roundtrip(cfg):
    pool = KVPool(cfg, num_slots=4, max_context=32, page_size=8)
    total = pool.free_pages
    assert total == 4 * 4          # 4 slots x 4 pages each (+ null excluded)
    pool.alloc(0, 20)              # ceil(20/8) = 3 pages
    assert pool.free_pages == total - 3
    assert len(pool.owned(0)) == 3
    # unallocated logical pages point at the null page
    assert (pool.block[0][3:] == 0).all()
    assert (pool.block[0][:3] > 0).all()
    pool.check_invariants()
    pool.free(0)
    assert pool.free_pages == total
    assert (pool.block[0] == 0).all()
    pool.check_invariants()


def test_owned_pages_disjoint_across_slots(cfg):
    pool = KVPool(cfg, num_slots=4, max_context=32, page_size=8)
    for slot in range(4):
        pool.alloc(slot, 32)
    owned = [p for s in range(4) for p in pool.owned(s)]
    assert len(set(owned)) == len(owned)          # no page owned twice
    assert 0 not in owned                          # null page never allocated
    assert pool.free_pages == 0
    pool.check_invariants()


def test_exhaustion_and_misuse_raise(cfg):
    pool = KVPool(cfg, num_slots=2, max_context=16, page_size=8,
                  num_pages=3)                     # null + 2 usable pages
    pool.alloc(0, 16)                              # takes both pages
    with pytest.raises(ValueError, match="exhausted"):
        pool.alloc(1, 8)
    with pytest.raises(ValueError, match="already holds"):
        pool.alloc(0, 8)
    pool.free(0)
    with pytest.raises(ValueError, match="holds no pages"):
        pool.free(0)
    with pytest.raises(ValueError, match="per-slot maximum"):
        pool.alloc(0, 999)


def test_free_hardening(cfg):
    """Double frees, frees of never-allocated slots, and out-of-range slots
    raise clear errors instead of corrupting the free list; a manually
    corrupted refcount is caught as a foreign free rather than silently
    double-freeing the page."""
    pool = KVPool(cfg, num_slots=3, max_context=32, page_size=8)
    pool.alloc(0, 32)
    pool.free(0)
    with pytest.raises(ValueError, match="[Dd]ouble free"):
        pool.free(0)
    with pytest.raises(ValueError, match="holds no pages"):
        pool.free(1)                               # never allocated
    with pytest.raises(ValueError, match="out of range"):
        pool.free(7)
    with pytest.raises(ValueError, match="out of range"):
        pool.alloc(-1, 8)
    pool.alloc(2, 16)
    pool._refs[pool.owned(2)[0]] = 0               # simulate corruption
    with pytest.raises(ValueError, match="foreign free"):
        pool.free(2)


def test_refcount_conservation_invariant(cfg):
    """check_invariants enforces refcount conservation: every page's
    refcount equals its slot references + index retentions, and live + free
    pages partition the pool."""
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8)
    pool.alloc(0, 24)
    pool.alloc(1, 16)
    pool.check_invariants()
    # simulate a leaked reference
    pool._refs[pool.owned(0)[0]] += 1
    with pytest.raises(AssertionError, match="refcount conservation"):
        pool.check_invariants()
    pool._refs[pool.owned(0)[0]] -= 1
    pool.check_invariants()
    # simulate a page that is free AND owned
    pool._free.append(pool.owned(1)[0])
    with pytest.raises(AssertionError):
        pool.check_invariants()


def test_prefix_admission_aliases_and_refcounts(cfg):
    """admit_prefix: a repeated prompt aliases the retained pages (refcount
    bumps, no fresh allocation for the prefix), a shared-prefix prompt gets a
    partial hit, and frees return pages only when the last reference drops."""
    from repro.serving.batcher import prompt_hashes
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8,
                  num_pages=24, prefix_entries=2)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    t2 = np.concatenate([t1[:16], rng.integers(0, cfg.vocab_size, 8)
                         .astype(np.int32)])
    h1, f1 = prompt_hashes(t1, 8)
    h2, f2 = prompt_hashes(t2, 8)

    plan1 = pool.admit_prefix(0, 28, 24, h1, f1, tick=0)
    assert plan1.start == 0 and plan1.save_row >= 0
    pool.check_invariants()
    prompt_pages = pool.owned(0)[:3]

    # identical prompt, later tick -> full restore aliasing every prompt page
    plan2 = pool.admit_prefix(1, 28, 24, h1, f1, tick=1)
    assert plan2.is_restore and plan2.start == 24
    assert pool.owned(1)[:3] == prompt_pages       # aliased, not copied
    assert (pool._refs[prompt_pages] >= 3).all()   # slot+slot+index refs
    pool.check_invariants()

    pool.free(1)
    assert set(pool.owned(0)) >= set(prompt_pages)  # survivor keeps pages
    pool.check_invariants()

    pool.free(0)
    # index retention keeps the prompt pages out of the free list
    assert not (set(prompt_pages) & set(pool._free))
    pool.check_invariants()

    # shared 2-page prefix, different tail -> partial hit at start=16
    plan3 = pool.admit_prefix(0, 28, 24, h2, f2, tick=2)
    assert not plan3.is_restore and plan3.start == 16
    assert pool.owned(0)[:2] == prompt_pages[:2]
    pool.check_invariants()


def test_failed_eviction_preserves_index(cfg):
    """When every pool page is held by live slots, a failed admission must
    NOT wipe the prefix index: evicting entries whose pages are all
    slot-referenced frees nothing, so they are kept for when the slots
    drain."""
    from repro.serving.batcher import prompt_hashes
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8,
                  num_pages=5, prefix_entries=2)    # null + 4 usable
    rng = np.random.default_rng(2)
    t1 = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    t2 = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    h1, f1 = prompt_hashes(t1, 8)
    h2, f2 = prompt_hashes(t2, 8)
    pool.admit_prefix(0, 32, 32, h1, f1, tick=0)    # slot 0 holds ALL pages
    idx_before = len(pool._page_index)
    full_before = len(pool._full_index)
    assert pool.admit_prefix(1, 32, 32, h2, f2, tick=1) is None  # no pages
    assert len(pool._page_index) == idx_before      # retention intact
    assert len(pool._full_index) == full_before
    pool.check_invariants()
    pool.free(0)
    # with the slot drained the retained prompt still full-restores
    plan = pool.admit_prefix(1, 32, 32, h1, f1, tick=2)
    assert plan.is_restore
    pool.check_invariants()


def test_prefix_eviction_reclaims_index_pages(cfg):
    """When the free list runs dry, LRU index entries are evicted to satisfy
    admission; pages still referenced by live slots survive eviction."""
    from repro.serving.batcher import prompt_hashes
    pool = KVPool(cfg, num_slots=2, max_context=64, page_size=8,
                  num_pages=9, prefix_entries=2)   # null + 8 usable
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    t2 = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    h1, f1 = prompt_hashes(t1, 8)
    h2, f2 = prompt_hashes(t2, 8)
    pool.admit_prefix(0, 32, 32, h1, f1, tick=0)   # 4 pages + retention
    pool.free(0)
    pool.check_invariants()
    assert pool.free_pages == 4                    # 4 retained by the index
    # a different prompt needs 8 pages -> evicts t1's retained entries
    plan = pool.admit_prefix(0, 64, 32, h2, f2, tick=1)
    assert plan is not None and plan.start == 0
    assert pool.stats["evictions"] > 0
    pool.check_invariants()
    # t1's entries are gone: admitting it again is a miss
    pool.free(0)
    plan = pool.admit_prefix(1, 32, 32, h1, f1, tick=2)
    assert not plan.is_restore
    pool.check_invariants()


def test_slot_reuse_recycles_pages(cfg):
    """Freed pages are reusable and the new owner's block row never aliases
    a live slot's pages (the allocator half of the no-leakage guarantee —
    the serving half is test_scheduler's fresh-vs-reused equivalence)."""
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8)
    pool.alloc(0, 32)
    first = set(pool.owned(0))
    pool.alloc(1, 32)
    pool.free(0)
    pool.alloc(0, 32)                              # LIFO: gets pages back
    assert set(pool.owned(0)) == first
    assert not set(pool.owned(0)) & set(pool.owned(1))
    pool.check_invariants()


def test_no_stale_reads_after_slot_reuse():
    """Serving request B in a slot previously used by a LONGER request A must
    give bit-identical output to serving B on a fresh engine: stale page
    contents (never scrubbed) must be unobservable through the positional
    mask + block table."""
    from repro.configs.registry import ARCHS
    from repro.serving.batcher import Request
    from repro.serving.engine import build_engine

    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)   # keep everything on S
    rng = np.random.default_rng(11)
    long_req = Request(0, rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                       max_new_tokens=3)
    short_req = Request(1, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=3)

    eng = build_engine(cfg, hi, max_new_tokens=3, cache_len=64)
    eng.serve_stream([long_req], buckets=(8, 32), num_slots=1, page_size=8)
    reused = eng.serve_stream([short_req], buckets=(8, 32), num_slots=1,
                              page_size=8)

    fresh_eng = build_engine(cfg, hi, max_new_tokens=3, cache_len=64)
    fresh = fresh_eng.serve_stream([short_req], buckets=(8, 32), num_slots=1,
                                   page_size=8)
    np.testing.assert_array_equal(reused[1]["tokens"], fresh[1]["tokens"])
    assert reused[1]["confidence"] == fresh[1]["confidence"]
