"""KVPool: page alloc/free invariants and no cross-request page leakage
after slot reuse."""
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.configs.registry import ARCHS
from repro.serving.kv_pool import KVPool


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["qwen2-1.5b"].reduced()


def test_alloc_free_roundtrip(cfg):
    pool = KVPool(cfg, num_slots=4, max_context=32, page_size=8)
    total = pool.free_pages
    assert total == 4 * 4          # 4 slots x 4 pages each (+ null excluded)
    pool.alloc(0, 20)              # ceil(20/8) = 3 pages
    assert pool.free_pages == total - 3
    assert len(pool.owned(0)) == 3
    # unallocated logical pages point at the null page
    assert (pool.block[0][3:] == 0).all()
    assert (pool.block[0][:3] > 0).all()
    pool.check_invariants()
    pool.free(0)
    assert pool.free_pages == total
    assert (pool.block[0] == 0).all()
    pool.check_invariants()


def test_owned_pages_disjoint_across_slots(cfg):
    pool = KVPool(cfg, num_slots=4, max_context=32, page_size=8)
    for slot in range(4):
        pool.alloc(slot, 32)
    owned = [p for s in range(4) for p in pool.owned(s)]
    assert len(set(owned)) == len(owned)          # no page owned twice
    assert 0 not in owned                          # null page never allocated
    assert pool.free_pages == 0
    pool.check_invariants()


def test_exhaustion_and_misuse_raise(cfg):
    pool = KVPool(cfg, num_slots=2, max_context=16, page_size=8,
                  num_pages=3)                     # null + 2 usable pages
    pool.alloc(0, 16)                              # takes both pages
    with pytest.raises(ValueError, match="exhausted"):
        pool.alloc(1, 8)
    with pytest.raises(ValueError, match="already holds"):
        pool.alloc(0, 8)
    pool.free(0)
    with pytest.raises(ValueError, match="holds no pages"):
        pool.free(0)
    with pytest.raises(ValueError, match="per-slot maximum"):
        pool.alloc(0, 999)


def test_slot_reuse_recycles_pages(cfg):
    """Freed pages are reusable and the new owner's block row never aliases
    a live slot's pages (the allocator half of the no-leakage guarantee —
    the serving half is test_scheduler's fresh-vs-reused equivalence)."""
    pool = KVPool(cfg, num_slots=2, max_context=32, page_size=8)
    pool.alloc(0, 32)
    first = set(pool.owned(0))
    pool.alloc(1, 32)
    pool.free(0)
    pool.alloc(0, 32)                              # LIFO: gets pages back
    assert set(pool.owned(0)) == first
    assert not set(pool.owned(0)) & set(pool.owned(1))
    pool.check_invariants()


def test_no_stale_reads_after_slot_reuse():
    """Serving request B in a slot previously used by a LONGER request A must
    give bit-identical output to serving B on a fresh engine: stale page
    contents (never scrubbed) must be unobservable through the positional
    mask + block table."""
    from repro.configs.registry import ARCHS
    from repro.serving.batcher import Request
    from repro.serving.engine import build_engine

    cfg = ARCHS["qwen2-1.5b"].reduced()
    hi = HIConfig(theta=0.0, capacity_factor=1.0)   # keep everything on S
    rng = np.random.default_rng(11)
    long_req = Request(0, rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                       max_new_tokens=3)
    short_req = Request(1, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=3)

    eng = build_engine(cfg, hi, max_new_tokens=3, cache_len=64)
    eng.serve_stream([long_req], buckets=(8, 32), num_slots=1, page_size=8)
    reused = eng.serve_stream([short_req], buckets=(8, 32), num_slots=1,
                              page_size=8)

    fresh_eng = build_engine(cfg, hi, max_new_tokens=3, cache_len=64)
    fresh = fresh_eng.serve_stream([short_req], buckets=(8, 32), num_slots=1,
                                   page_size=8)
    np.testing.assert_array_equal(reused[1]["tokens"], fresh[1]["tokens"])
    assert reused[1]["confidence"] == fresh[1]["confidence"]
