"""End-to-end behaviour tests: the paper's central claims hold on the
synthetic reproductions of its three use cases (trained tiers, calibrated
threshold, full cascade)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HIConfig
from repro.core.calibrate import brute_force_theta
from repro.core.cascade import classifier_cascade
from repro.core.confidence import confidence
from repro.data import images, vibration as vib
from repro.models import cnn
from repro.training.cnn_trainer import accuracy, predict_logits, train_cnn


@pytest.fixture(scope="module")
def tiers():
    """Quickly-trained S/L CNNs on the CIFAR-10 stand-in (module-scoped)."""
    # patch_amp=0.7 speeds up the L-tier's take-off on the strong cue so the
    # fixture stays CPU-cheap (measured: S~0.72, L~0.93 at this budget)
    x_tr, y_tr = images.make_dataset(5000, seed=0, patch_amp=0.7)
    x_te, y_te = images.make_dataset(1200, seed=5, patch_amp=0.7)
    ps = train_cnn(cnn.SML_CIFAR, x_tr, y_tr, epochs=2, batch=128)
    pl = train_cnn(cnn.LML_CIFAR, x_tr, y_tr, epochs=4, batch=128)
    return ps, pl, x_te, y_te


def test_sml_worse_than_lml(tiers):
    ps, pl, x_te, y_te = tiers
    s_acc = accuracy(ps, cnn.SML_CIFAR, x_te, y_te)
    l_acc = accuracy(pl, cnn.LML_CIFAR, x_te, y_te)
    assert l_acc > s_acc + 0.03, (s_acc, l_acc)
    assert s_acc > 0.4          # better than chance by far


def test_confidence_correlates_with_correctness(tiers):
    """The property HI relies on (paper Fig. 6): high-p samples are right
    more often than low-p samples."""
    ps, _, x_te, y_te = tiers
    logits = predict_logits(ps, cnn.SML_CIFAR, x_te)
    conf = np.asarray(confidence(jnp.asarray(logits)))
    ok = logits.argmax(-1) == y_te
    hi_mask = conf >= np.median(conf)
    assert ok[hi_mask].mean() > ok[~hi_mask].mean() + 0.1


def test_hi_beats_both_extremes_on_cost(tiers):
    """Paper Table 1 structure: with calibrated theta*, HI cost < full-offload
    cost and <= no-offload cost for mid-range beta; accuracy lands between."""
    ps, pl, x_te, y_te = tiers
    beta = 0.5
    s_logits = predict_logits(ps, cnn.SML_CIFAR, x_te)
    l_logits = predict_logits(pl, cnn.LML_CIFAR, x_te)
    conf = np.asarray(confidence(jnp.asarray(s_logits)))
    s_ok = s_logits.argmax(-1) == y_te
    l_ok = l_logits.argmax(-1) == y_te
    theta, _ = brute_force_theta(conf, s_ok, beta, l_correct=l_ok)

    hi = HIConfig(theta=float(theta), capacity_factor=1.0)
    casc = classifier_cascade(
        lambda p, x: cnn.apply_cnn(p, cnn.SML_CIFAR, x),
        lambda p, x: cnn.apply_cnn(p, cnn.LML_CIFAR, x), hi)
    out = casc.infer(ps, pl, jnp.asarray(x_te))
    pred = np.asarray(out["pred"])
    served = np.asarray(out["served_remote"])
    n = len(y_te)

    hi_wrong = (pred != y_te)
    hi_cost = served.sum() * beta + hi_wrong.sum()
    full_cost = n * beta + (~l_ok).sum()
    local_cost = (~s_ok).sum()
    assert hi_cost < full_cost, (hi_cost, full_cost)
    assert hi_cost <= local_cost + 1e-9, (hi_cost, local_cost)

    hi_acc = (pred == y_te).mean()
    assert s_ok.mean() - 0.02 <= hi_acc <= l_ok.mean() + 0.02
    assert 0.0 < served.mean() < 1.0          # a genuine cascade


def _balanced_binary(x, y, seed=0):
    """Oversample the positive class to 50% (the filter must be trained
    recall-oriented; with a 10% prior the tiny net collapses to majority)."""
    b = images.binary_labels(y)
    pos = np.flatnonzero(b == 1)
    neg = np.flatnonzero(b == 0)
    rng = np.random.default_rng(seed)
    pos_up = rng.choice(pos, size=len(neg), replace=True)
    idx = rng.permutation(np.concatenate([pos_up, neg]))
    return x[idx], b[idx]


def test_binary_filter_use_case(tiers):
    """§5 structure: relevance filter keeps most dogs, drops most non-dogs."""
    _, _, x_te, y_te = tiers
    x_tr, y_tr = images.make_dataset(2500, seed=1, patch_amp=0.7)
    xb, bb = _balanced_binary(x_tr, y_tr)
    pb = train_cnn(cnn.SML_BINARY, xb, bb, epochs=2)
    p = 1 / (1 + np.exp(-predict_logits(pb, cnn.SML_BINARY, x_te)[:, 0]))
    offload = p >= 0.5
    dogs = images.binary_labels(y_te) == 1
    recall = (offload & dogs).sum() / max(dogs.sum(), 1)
    offload_frac = offload.mean()
    assert recall > 0.6, (recall, offload_frac)
    assert offload_frac < 0.6          # most irrelevant images stay local


def test_reb_end_to_end():
    """§3: threshold S-ML separates perfectly; HI saves ~all bandwidth when
    machines are mostly normal."""
    _, labels, means = vib.make_dataset(25, seed=11, normal_fraction=0.95)
    offload = vib.threshold_sml(means, 0.07)
    assert (offload == (labels != 0)).all()
    assert offload.mean() < 0.2
