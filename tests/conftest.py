"""Shared fixtures.  NOTE: no unconditional XLA_FLAGS here — tests must see
the real 1-device CPU by default; only launch/dryrun.py forces 512 host
devices.

Opt-in multi-device CPU (mesh tests): setting ``REPRO_MULTI_DEVICE=1`` in the
environment forces 8 host devices BEFORE the first jax import, so data>1
serving meshes are constructible in plain CPU CI.  Tests that need it either
run in a subprocess that sets the variable themselves (the established
tests/test_tier_split.py pattern) or are launched under
``REPRO_MULTI_DEVICE=1 pytest -m multi_device``.
"""
import os

if os.environ.get("REPRO_MULTI_DEVICE") == "1":
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402  (XLA_FLAGS must be set before this import)
import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multi_device: needs >1 CPU devices (run under REPRO_MULTI_DEVICE=1)")


def pytest_collection_modifyitems(config, items):
    if len(jax.devices()) > 1:
        return
    skip = pytest.mark.skip(
        reason="needs multiple devices: run under REPRO_MULTI_DEVICE=1")
    for item in items:
        if "multi_device" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
