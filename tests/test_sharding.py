"""Sharding rules: specs are valid (divisibility), rank-correct, and the
1-device debug mesh runs a sharded train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import ARCHS, shape_applicable
from repro.models import model_zoo as zoo
from repro.sharding import specs as sh


class FakeMesh:
    """Mesh stand-in exposing only .shape (rules need nothing else)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


PROD = FakeMesh(data=16, model=16)
PROD_MP = FakeMesh(pod=2, data=16, model=16)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [PROD, PROD_MP], ids=["single", "multi"])
def test_param_specs_divisible_and_rank_correct(arch, mesh):
    cfg = ARCHS[arch]
    tree = zoo.init_params_spec(cfg)
    spec_tree = sh.param_specs(tree, mesh, fsdp=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(specs)
    for (path, leaf), spec in zip(leaves, specs):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (path, spec, leaf.shape)


def test_data_spec_divisibility():
    assert sh.data_spec(PROD, 256, 2) == P(("data",), None)
    assert sh.data_spec(PROD_MP, 256, 2) == P(("pod", "data"), None)
    # batch=1 cannot shard
    assert sh.data_spec(PROD, 1, 2) == P(None, None)


@pytest.mark.parametrize("arch", ["gemma3-1b", "mamba2-370m", "zamba2-2.7b"])
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape):
    cfg, shp = ARCHS[arch], SHAPES[shape]
    ok, _ = shape_applicable(cfg, shp)
    if not ok:
        pytest.skip("assignment skip rule")
    mesh = PROD
    tree = sh.shape_tree(cfg, shp)
    spec_tree = sh.cache_specs(cfg, mesh, shp)
    for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (path, spec, leaf.shape)


def test_one_device_mesh_train_step(key):
    """The sharded code path runs on the real 1-device mesh."""
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import adamw
    from repro.training import trainer
    cfg = ARCHS["qwen2-1.5b"].reduced()
    mesh = make_debug_mesh(1, 1)
    params = zoo.init_params(key, cfg)
    tcfg = TrainConfig(grad_accum=2, remat=True, bf16_state=False)
    opt = adamw.init_state(params, tcfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    }
    with mesh:
        p_sh = sh.param_shardings(params, mesh, fsdp=True)
        step = jax.jit(trainer.make_train_step(cfg, tcfg),
                       in_shardings=(p_sh, None, None))
        params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])


def test_activation_constraint_noop_outside_mesh():
    from repro.sharding import act
    x = jnp.ones((4, 8, 16))
    y = act.shard_hidden(x)            # no ambient mesh -> identity
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
